"""L1 correctness gate: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes and length patterns; every case
must match ``kernels.ref`` to float32 tolerance.  This is the CORE
correctness signal for the AOT artifacts — the same kernel code lowers
into the HLO modules Rust serves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention, vmem_footprint_bytes
from compile.kernels.prefill_attention import prefill_attention

RTOL = 2e-5
ATOL = 2e-5


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

class TestDecodeBasics:
    def test_single_row_full_length(self):
        q, k, v = _rand(0, (1, 16)), _rand(1, (1, 64, 16)), _rand(2, (1, 64, 16))
        lens = jnp.array([64], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=32)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_length_one(self):
        """len=1 reduces to v[0] exactly (softmax over one element)."""
        q, k, v = _rand(0, (2, 8)), _rand(1, (2, 32, 8)), _rand(2, (2, 32, 8))
        lens = jnp.array([1, 1], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=16)
        np.testing.assert_allclose(got, v[:, 0, :], rtol=RTOL, atol=ATOL)

    def test_heterogeneous_lengths(self):
        """The exact scenario the paper studies: mixed lengths in a batch."""
        r, s, d = 8, 256, 32
        q, k, v = _rand(3, (r, d)), _rand(4, (r, s, d)), _rand(5, (r, s, d))
        lens = jnp.array([1, 5, 32, 64, 100, 128, 200, 256], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=64)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_block_not_dividing_seq(self):
        """S not a multiple of block_k exercises the padding path."""
        r, s, d = 3, 100, 16
        q, k, v = _rand(6, (r, d)), _rand(7, (r, s, d)), _rand(8, (r, s, d))
        lens = jnp.array([100, 37, 64], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=64)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_block_larger_than_seq(self):
        r, s, d = 2, 24, 8
        q, k, v = _rand(9, (r, d)), _rand(10, (r, s, d)), _rand(11, (r, s, d))
        lens = jnp.array([24, 7], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=512)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_extreme_logits_no_overflow(self):
        """Large-magnitude scores must not overflow the online softmax."""
        r, s, d = 2, 64, 8
        q = 100.0 * _rand(12, (r, d))
        k = 100.0 * _rand(13, (r, s, d))
        v = _rand(14, (r, s, d))
        lens = jnp.array([64, 30], jnp.int32)
        got = decode_attention(q, k, v, lens, block_k=16)
        want = ref.decode_attention_ref(q, k, v, lens)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_softmax_invariance_to_padding_content(self):
        """Garbage beyond `lengths` must not affect the output."""
        r, s, d = 4, 128, 16
        q, k, v = _rand(15, (r, d)), _rand(16, (r, s, d)), _rand(17, (r, s, d))
        lens = jnp.array([10, 50, 90, 128], jnp.int32)
        base = decode_attention(q, k, v, lens, block_k=32)
        # Poison the padded region.
        pos = jnp.arange(s)[None, :, None]
        poisoned_k = jnp.where(pos < lens[:, None, None], k, 1e4)
        poisoned_v = jnp.where(pos < lens[:, None, None], v, -1e4)
        got = decode_attention(q, poisoned_k, poisoned_v, lens, block_k=32)
        np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)

    def test_vmem_footprint_structural_budget(self):
        """DESIGN.md §6: one grid step holds 2 tiles + q row + state."""
        d, bk = 64, 128
        assert vmem_footprint_bytes(d, bk) == 4 * (2 * bk * d + 3 * d + 2)
        # A [128, 64] f32 tile pair is 64 KiB — far under any VMEM budget.
        assert vmem_footprint_bytes(d, bk) < 16 * 1024 * 1024


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 6),
    s=st.integers(1, 160),
    d=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_hypothesis_sweep(r, s, d, block_k, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (r, d), jnp.float32)
    k = jax.random.normal(kk, (r, s, d), jnp.float32)
    v = jax.random.normal(kv, (r, s, d), jnp.float32)
    lens = jax.random.randint(kl, (r,), 1, s + 1).astype(jnp.int32)
    got = decode_attention(q, k, v, lens, block_k=block_k)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# Prefill attention
# ---------------------------------------------------------------------------

class TestPrefillBasics:
    def test_full_length_causal(self):
        r, t, d = 2, 64, 16
        q, k, v = _rand(20, (r, t, d)), _rand(21, (r, t, d)), _rand(22, (r, t, d))
        lens = jnp.array([64, 64], jnp.int32)
        got = prefill_attention(q, k, v, lens, block_q=32, block_k=32)
        want = ref.prefill_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_ragged_lengths_valid_region_only(self):
        r, t, d = 4, 96, 8
        q, k, v = _rand(23, (r, t, d)), _rand(24, (r, t, d)), _rand(25, (r, t, d))
        lens = jnp.array([1, 17, 50, 96], jnp.int32)
        got = prefill_attention(q, k, v, lens, block_q=32, block_k=16)
        want = ref.prefill_attention_ref(q, k, v, lens)
        for i in range(r):
            L = int(lens[i])
            np.testing.assert_allclose(got[i, :L], want[i, :L],
                                       rtol=RTOL, atol=ATOL)

    def test_first_position_is_v0(self):
        """Position 0 attends only to itself."""
        r, t, d = 3, 32, 8
        q, k, v = _rand(26, (r, t, d)), _rand(27, (r, t, d)), _rand(28, (r, t, d))
        lens = jnp.array([32, 10, 5], jnp.int32)
        got = prefill_attention(q, k, v, lens, block_q=8, block_k=8)
        np.testing.assert_allclose(got[:, 0, :], v[:, 0, :], rtol=RTOL, atol=ATOL)

    def test_unequal_block_shapes(self):
        r, t, d = 2, 80, 16
        q, k, v = _rand(29, (r, t, d)), _rand(30, (r, t, d)), _rand(31, (r, t, d))
        lens = jnp.array([80, 40], jnp.int32)
        got = prefill_attention(q, k, v, lens, block_q=64, block_k=16)
        want = ref.prefill_attention_ref(q, k, v, lens)
        for i in range(r):
            L = int(lens[i])
            np.testing.assert_allclose(got[i, :L], want[i, :L],
                                       rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    r=st.integers(1, 4),
    t=st.integers(2, 96),
    d=st.sampled_from([8, 16]),
    block_q=st.sampled_from([16, 32]),
    block_k=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_hypothesis_sweep(r, t, d, block_q, block_k, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (r, t, d), jnp.float32)
    k = jax.random.normal(kk, (r, t, d), jnp.float32)
    v = jax.random.normal(kv, (r, t, d), jnp.float32)
    lens = jax.random.randint(kl, (r,), 1, t + 1).astype(jnp.int32)
    got = prefill_attention(q, k, v, lens, block_q=block_q, block_k=block_k)
    want = ref.prefill_attention_ref(q, k, v, lens)
    for i in range(r):
        L = int(lens[i])
        np.testing.assert_allclose(got[i, :L], want[i, :L],
                                   rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# Oracle self-checks (the refs must satisfy softmax identities themselves)
# ---------------------------------------------------------------------------

def test_ref_decode_is_convex_combination():
    """Output lies in the convex hull of valid V rows (softmax weights)."""
    r, s, d = 3, 40, 4
    q, k = _rand(32, (r, d)), _rand(33, (r, s, d))
    v = jnp.ones((r, s, d), jnp.float32)
    lens = jnp.array([40, 13, 1], jnp.int32)
    out = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, jnp.ones((r, d)), rtol=1e-6, atol=1e-6)


def test_ref_prefill_row0_equals_decode_len1():
    r, t, d = 2, 16, 8
    q, k, v = _rand(34, (r, t, d)), _rand(35, (r, t, d)), _rand(36, (r, t, d))
    lens = jnp.array([16, 16], jnp.int32)
    pre = ref.prefill_attention_ref(q, k, v, lens)
    dec = ref.decode_attention_ref(q[:, 0, :], k, v, jnp.array([1, 1], jnp.int32))
    np.testing.assert_allclose(pre[:, 0, :], dec, rtol=1e-6, atol=1e-6)
