"""L2 correctness: the GPT model's prefill/decode semantics and param ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, max_seq=48)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=7)


def _prompt(b, t, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, (b, t)), jnp.int32)


class TestParamABI:
    def test_order_is_deterministic(self):
        assert M.param_order(CFG) == M.param_order(CFG)

    def test_roundtrip_list(self, params):
        flat = M.params_to_list(CFG, params)
        back = M.list_to_params(CFG, flat)
        for name, _ in M.param_order(CFG):
            np.testing.assert_array_equal(params[name], back[name])

    def test_init_deterministic(self):
        a = M.init_params(CFG, seed=3)
        b = M.init_params(CFG, seed=3)
        for name, _ in M.param_order(CFG):
            np.testing.assert_array_equal(a[name], b[name])

    def test_param_count_matches_layers(self):
        assert len(M.param_order(CFG)) == 2 + CFG.n_layers * 12 + 2


class TestPrefill:
    def test_shapes(self, params):
        b, t = 3, 16
        logits, kc, vc = M.prefill(params, CFG, _prompt(b, t), jnp.array([4, 9, 16], jnp.int32))
        r = b * CFG.n_heads
        assert logits.shape == (b, CFG.vocab)
        assert kc.shape == (CFG.n_layers, r, CFG.max_seq, CFG.head_dim)
        assert vc.shape == kc.shape

    def test_logits_depend_only_on_valid_prefix(self, params):
        """Tokens past `length` must not influence the logits."""
        b, t = 2, 12
        toks = _prompt(b, t, seed=1)
        lens = jnp.array([5, 8], jnp.int32)
        base, _, _ = M.prefill(params, CFG, toks, lens)
        # Scramble the padding region only.
        pos = jnp.arange(t)[None, :]
        scrambled = jnp.where(pos < lens[:, None], toks, (toks + 13) % CFG.vocab)
        got, _, _ = M.prefill(params, CFG, scrambled, lens)
        np.testing.assert_allclose(base, got, rtol=1e-5, atol=1e-5)

    def test_cache_zero_beyond_prefill_window(self, params):
        b, t = 2, 8
        _, kc, vc = M.prefill(params, CFG, _prompt(b, t), jnp.array([8, 3], jnp.int32))
        assert np.all(np.asarray(kc[:, :, t:, :]) == 0.0)
        assert np.all(np.asarray(vc[:, :, t:, :]) == 0.0)


class TestDecodeStep:
    def test_consistency_with_prefill(self, params):
        """prefill(n) == prefill(n-1) + decode_step(token n)."""
        b, t = 3, 16
        toks = _prompt(b, t, seed=2)
        lens = jnp.array([6, 11, 16], jnp.int32)
        want, _, _ = M.prefill(params, CFG, toks, lens)
        logits0, kc, vc = M.prefill(params, CFG, toks, lens - 1)
        last = jnp.take_along_axis(toks, (lens - 1)[:, None], axis=1)[:, 0]
        got, _, _, new_lens = M.decode_step(params, CFG, last, kc, vc, lens - 1)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
        np.testing.assert_array_equal(new_lens, lens)

    def test_lengths_monotone(self, params):
        b = 2
        toks = _prompt(b, 8, seed=3)
        lens = jnp.array([4, 8], jnp.int32)
        _, kc, vc = M.prefill(params, CFG, toks, lens)
        cur = lens
        for _ in range(3):
            _, kc, vc, nxt = M.decode_step(
                params, CFG, jnp.zeros((b,), jnp.int32), kc, vc, cur)
            assert (np.asarray(nxt) == np.asarray(cur) + 1).all()
            cur = nxt

    def test_rows_independent(self, params):
        """Changing row 1's token must not change row 0's logits."""
        b = 2
        toks = _prompt(b, 8, seed=4)
        lens = jnp.array([5, 7], jnp.int32)
        _, kc, vc = M.prefill(params, CFG, toks, lens)
        t_a = jnp.array([3, 9], jnp.int32)
        t_b = jnp.array([3, 42], jnp.int32)
        la, _, _, _ = M.decode_step(params, CFG, t_a, kc, vc, lens)
        lb, _, _, _ = M.decode_step(params, CFG, t_b, kc, vc, lens)
        np.testing.assert_allclose(la[0], lb[0], rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(la[1]), np.asarray(lb[1]))


class TestGenerate:
    def test_deterministic(self, params):
        toks = _prompt(2, 8, seed=5)
        lens = jnp.array([4, 8], jnp.int32)
        a = M.reference_generate(params, CFG, toks, lens, 6)
        b = M.reference_generate(params, CFG, toks, lens, 6)
        np.testing.assert_array_equal(a, b)

    def test_tokens_in_vocab(self, params):
        toks = _prompt(2, 8, seed=6)
        out = M.reference_generate(params, CFG, toks, jnp.array([8, 8], jnp.int32), 4)
        arr = np.asarray(out)
        assert ((arr >= 0) & (arr < CFG.vocab)).all()


class TestFlatWrappers:
    def test_prefill_fn_matches_dict_api(self, params):
        fn = M.make_prefill_fn(CFG)
        toks = _prompt(2, 8, seed=8)
        lens = jnp.array([3, 8], jnp.int32)
        flat = M.params_to_list(CFG, params)
        got = fn(*flat, toks, lens)
        want = M.prefill(params, CFG, toks, lens)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)

    def test_decode_fn_matches_dict_api(self, params):
        toks0 = _prompt(2, 8, seed=9)
        lens = jnp.array([3, 8], jnp.int32)
        _, kc, vc = M.prefill(params, CFG, toks0, lens)
        fn = M.make_decode_fn(CFG)
        flat = M.params_to_list(CFG, params)
        step_toks = jnp.array([1, 2], jnp.int32)
        got = fn(*flat, step_toks, kc, vc, lens)
        want = M.decode_step(params, CFG, step_toks, kc, vc, lens)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)
