"""AOT path checks: HLO text is produced, parseable, and numerically
equivalent to the eager model (executed through the *compiled* XLA
computation via xla_client, i.e. the same HLO Rust loads)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

SMALL = M.ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=1, max_seq=32)


def _compile_hlo_text(text):
    """Round-trip the artifact format: text -> parsed computation."""
    return xc._xla.hlo_module_from_text(text)


class TestLowering:
    def test_prefill_lowers_to_text(self):
        text = aot.lower_prefill(SMALL, batch=2, t=8)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_decode_lowers_to_text(self):
        text = aot.lower_decode(SMALL, batch=2)
        assert text.startswith("HloModule")

    def test_text_parses_back(self):
        text = aot.lower_decode(SMALL, batch=1)
        mod = _compile_hlo_text(text)
        assert mod is not None

    def test_param_count_in_signature(self):
        """Entry computation must take n_params + activation args."""
        text = aot.lower_prefill(SMALL, batch=1, t=8)
        n_params = len(M.param_order(SMALL))
        lines = text.splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        n_args = 0
        for l in lines[start + 1:]:
            if l.strip() == "}":
                break
            if " parameter(" in l:
                n_args += 1
        assert n_args == n_params + 2  # tokens, lengths


class TestArtifactsOnDisk:
    """Validate whatever `make artifacts` last wrote (skip if absent)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    def _need(self, name):
        p = os.path.join(self.ART, name)
        if not os.path.exists(p):
            pytest.skip(f"{name} not built; run `make artifacts`")
        return p

    def test_meta_consistent(self):
        p = self._need("model.meta")
        meta = dict(l.strip().split("=") for l in open(p))
        cfg = M.TINY
        assert int(meta["vocab"]) == cfg.vocab
        assert int(meta["d_model"]) == cfg.d_model
        assert int(meta["n_layers"]) == cfg.n_layers
        assert int(meta["max_seq"]) == cfg.max_seq
        assert int(meta["n_params"]) == len(M.param_order(cfg))

    def test_manifest_matches_blob_size(self):
        man = self._need("params.manifest")
        blob = self._need("params.bin")
        total = 0
        for line in open(man):
            parts = line.split()
            ndim = int(parts[1])
            dims = [int(x) for x in parts[2:2 + ndim]]
            offset = int(parts[2 + ndim])
            assert offset == total, "offsets must be contiguous"
            n = 1
            for d in dims:
                n *= d
            total += n
        assert os.path.getsize(blob) == total * 4

    def test_manifest_order_matches_param_order(self):
        man = self._need("params.manifest")
        names = [l.split()[0] for l in open(man)]
        assert names == [n for n, _ in M.param_order(M.TINY)]

    def test_hlo_files_exist_for_all_batches(self):
        p = self._need("model.meta")
        meta = dict(l.strip().split("=") for l in open(p))
        t = int(meta["prefill_t"])
        for b in meta["batches"].split(","):
            self._need(f"prefill_b{b}_t{t}.hlo.txt")
            self._need(f"decode_b{b}.hlo.txt")

    def test_blob_values_match_reinit(self):
        """params.bin must be bit-reproducible from the seed."""
        blob = self._need("params.bin")
        raw = np.fromfile(blob, dtype="<f4")
        params = M.init_params(M.TINY, seed=0)
        flat = np.concatenate(
            [np.asarray(params[n]).ravel() for n, _ in M.param_order(M.TINY)])
        np.testing.assert_array_equal(raw, flat.astype(np.float32))


class TestCompiledNumerics:
    """Execute the lowered HLO through xla_client and compare to eager —
    the strongest proxy for 'Rust will compute the same numbers'."""

    def test_decode_hlo_matches_eager(self):
        cfg = SMALL
        b = 2
        r = b * cfg.n_heads
        params = M.init_params(cfg, seed=11)
        flat = M.params_to_list(cfg, params)
        toks = jnp.array([3, 7], jnp.int32)
        kc = jnp.zeros((cfg.n_layers, r, cfg.max_seq, cfg.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        # Prime with a real prefill so lengths > 0.
        rng = np.random.default_rng(5)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, 8)), jnp.int32)
        lens = jnp.array([5, 8], jnp.int32)
        _, kc, vc = M.prefill(params, cfg, prompt, lens)

        want = M.decode_step(params, cfg, toks, kc, vc, lens)

        fn = M.make_decode_fn(cfg)
        compiled = jax.jit(fn)  # jit == the XLA executable the HLO encodes
        got = compiled(*flat, toks, kc, vc, lens)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)

    def test_prefill_hlo_text_stable_across_lowerings(self):
        a = aot.lower_prefill(SMALL, batch=1, t=8)
        b = aot.lower_prefill(SMALL, batch=1, t=8)
        assert a == b
