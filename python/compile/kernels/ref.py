"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest + hypothesis
sweeps (python/tests/).  They are deliberately written in the most
obvious way possible — full materialized score matrices, explicit masks —
so that reviewing them is trivial.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths, scale=None):
    """Masked single-token decode attention.

    Args:
      q: [R, D] query rows (R = batch * heads, one new token each).
      k: [R, S, D] key cache (padded to S).
      v: [R, S, D] value cache.
      lengths: [R] int32, valid KV length per row (0 < len <= S).
      scale: softmax scale; defaults to 1/sqrt(D).

    Returns:
      [R, D] attention output.
    """
    r, s, d = k.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("rd,rsd->rs", q, k) * scale  # [R, S]
    pos = jnp.arange(s)[None, :]
    mask = pos < lengths[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask  # kill padded lanes exactly
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    return jnp.einsum("rs,rsd->rd", probs, v)


def prefill_attention_ref(q, k, v, lengths, scale=None):
    """Masked causal self-attention over a padded prefix.

    Args:
      q: [R, T, D] query rows (R = batch * heads).
      k: [R, T, D], v: [R, T, D].
      lengths: [R] int32 valid prefix length per row.

    Returns:
      [R, T, D]; rows at positions >= length are unspecified-but-finite.
    """
    r, t, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("rtd,rsd->rts", q, k) * scale  # [R, T, S=T]
    pos = jnp.arange(t)
    causal = pos[None, :, None] >= pos[None, None, :]  # q >= k
    valid = pos[None, None, :] < lengths[:, None, None]
    mask = causal & valid
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    return jnp.einsum("rts,rsd->rtd", probs, v)
