"""L1 Pallas kernel: length-masked batched decode attention.

This is the hot-spot CascadeInfer schedules around (§2.3 of the paper):
one new query token per sequence attends over a padded KV cache whose
*valid* length differs per row.  The paper measures this kernel on CUDA
(FlashAttention / FlashDecoding); here it is re-thought for a TPU-style
memory hierarchy per DESIGN.md §2:

* Grid = (rows, kv_chunks).  Each grid step streams one
  ``(BLOCK_K, head_dim)`` tile of K and V from HBM into VMEM via
  ``BlockSpec`` — the HBM↔VMEM schedule that CUDA kernels express with
  threadblocks.
* Online (flash) softmax state — running max ``m``, denominator ``l`` and
  the unnormalized accumulator — lives in the output refs, which stay
  VMEM-resident across the sequential ``j`` dimension because their index
  map ignores ``j``.
* Rows whose length ends before a chunk are masked, so compute cost
  tracks the *true* sequence length — the exact per-row imbalance the
  paper attributes to inter-SM load imbalance carries over to grid-step
  imbalance here.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness is what the build-time pytest gate checks.
Real-TPU efficiency is estimated structurally (DESIGN.md §6).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                   block_k: int, scale: float):
    """One (row, kv-chunk) grid step of flash decode attention."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[0, 0]
    pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
    mask = pos < length

    q = q_ref[0, :]                      # [D]     (VMEM-resident)
    k = k_ref[0, :, :]                   # [Bk, D] (streamed tile)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [Bk]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    # exp() of an all-masked chunk underflows to exactly 0, so fully
    # padded chunks contribute nothing (alpha == 1, p == 0).
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * mask.astype(s.dtype)  # [Bk]

    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p)
    o_ref[0, :] = o_ref[0, :] * alpha + jnp.dot(
        p, v_ref[0, :, :], preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0, :] = o_ref[0, :] / jnp.maximum(l_ref[0, 0], 1e-30)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lengths, block_k: int = DEFAULT_BLOCK_K):
    """Flash decode attention over a padded per-row KV cache.

    Args:
      q: [R, D] float32 — one query per row (R = batch * heads).
      k: [R, S, D] float32 key cache, padded to S.
      v: [R, S, D] float32 value cache.
      lengths: [R] int32 valid KV length per row, 1 <= len <= S.
      block_k: KV tile size (the VMEM streaming granule).

    Returns:
      [R, D] float32 attention output; matches
      :func:`kernels.ref.decode_attention_ref`.
    """
    r, s, d = k.shape
    assert q.shape == (r, d) and v.shape == (r, s, d)
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        s += pad
    scale = 1.0 / (d ** 0.5)
    lens2d = lengths.reshape(r, 1).astype(jnp.int32)

    grid = (r, s // block_k)
    out, _m, _l = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=True,
    )(lens2d, q, k, v)
    return out


def vmem_footprint_bytes(d: int, block_k: int = DEFAULT_BLOCK_K,
                         bytes_per_el: int = 4) -> int:
    """Structural VMEM estimate for one grid step (DESIGN.md §6 target).

    One K tile + one V tile + the q row + accumulator/m/l state.
    """
    return bytes_per_el * (2 * block_k * d + 3 * d + 2)
