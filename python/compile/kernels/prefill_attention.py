"""L1 Pallas kernel: length-masked causal prefill attention.

Prefill ingests the whole prompt at once (O(T^2), compute-bound — paper
§2.1).  The kernel is a blockwise flash-attention forward pass:

* Grid = (rows, q_chunks, kv_chunks); the kv dimension is innermost and
  sequential, so the running max/denominator/accumulator state for one
  ``(row, q_chunk)`` stays VMEM-resident across kv steps.
* Causality is enforced per (q_pos, kv_pos) pair; fully-future kv chunks
  are masked out entirely (their exp() underflows to 0), mirroring how a
  CUDA flash kernel would simply not launch those tiles.
* Rows shorter than the padded T produce garbage *above* their length;
  the L2 model never reads those positions.

``interpret=True`` always — see decode_attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _prefill_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                    block_q: int, block_k: int, scale: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[0, 0]
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = (k_pos <= q_pos) & (k_pos < length)  # [Bq, Bk]

    q = q_ref[0, :, :]  # [Bq, D]
    k = k_ref[0, :, :]  # [Bk, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, :, 0]                       # [Bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)               # [Bq]
    p = jnp.exp(s - m_new[:, None]) * mask.astype(s.dtype)  # [Bq, Bk]

    l_ref[0, :, 0] = l_ref[0, :, 0] * alpha + jnp.sum(p, axis=1)
    o_ref[0, :, :] = o_ref[0, :, :] * alpha[:, None] + jnp.dot(
        p, v_ref[0, :, :], preferred_element_type=jnp.float32)
    m_ref[0, :, 0] = m_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, :, :] = o_ref[0, :, :] / jnp.maximum(
            l_ref[0, :, 0], 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(q, k, v, lengths,
                      block_q: int = DEFAULT_BLOCK_Q,
                      block_k: int = DEFAULT_BLOCK_K):
    """Blockwise causal flash attention over padded prefixes.

    Args:
      q, k, v: [R, T, D] float32 (R = batch * heads).
      lengths: [R] int32 valid prefix lengths (1 <= len <= T).

    Returns:
      [R, T, D] float32; positions >= length hold unspecified finite
      values.  Matches :func:`kernels.ref.prefill_attention_ref` below
      each row's length.
    """
    r, t, d = q.shape
    assert k.shape == (r, t, d) and v.shape == (r, t, d)
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    pad_q = (-t) % block_q
    pad_k = (-t) % block_k
    pad = max(pad_q, pad_k)
    tp = t + pad
    # Pad T so both tilings divide; padded q rows are masked by causality
    # against `length` and simply produce garbage rows we slice off.
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    scale = 1.0 / (d ** 0.5)
    lens2d = lengths.reshape(r, 1).astype(jnp.int32)

    grid = (r, tp // block_q, tp // block_k)
    out, _m, _l = pl.pallas_call(
        functools.partial(_prefill_kernel, block_q=block_q,
                          block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, iq, jk: (i, 0)),
            pl.BlockSpec((1, block_q, d), lambda i, iq, jk: (i, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, iq, jk: (i, jk, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, iq, jk: (i, jk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, iq, jk: (i, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda i, iq, jk: (i, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda i, iq, jk: (i, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, tp, d), jnp.float32),
            jax.ShapeDtypeStruct((r, tp, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, tp, 1), jnp.float32),
        ],
        interpret=True,
    )(lens2d, q, k, v)
    return out[:, :t, :]
