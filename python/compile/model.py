"""L2 JAX model: a small GPT-style decoder with an explicit KV cache.

This is the *served* model of the reproduction: CascadeInfer (the L3 Rust
coordinator) schedules requests across instances, and each instance runs
this model's AOT-compiled prefill / decode-step executables through PJRT.
Both entry points call the L1 Pallas kernels
(:mod:`compile.kernels.prefill_attention`,
:mod:`compile.kernels.decode_attention`) so the kernels lower into the
same HLO modules Rust loads.

Everything here is *build-time only* — ``aot.py`` lowers the two jitted
functions once to HLO text plus a flat parameter blob, and Python never
runs again on the request path.

Conventions
-----------
* Shapes are static: ``B`` (batch rows per instance step), ``T`` (prefill
  chunk), ``S`` (max KV length per row), layers ``L``, model dim ``D``,
  heads ``H`` with head dim ``Dh = D // H``, vocab ``V``.
* The KV cache is a pair of arrays ``[L, R, S, Dh]`` with ``R = B * H``
  (one row per (sequence, head)); ``lengths: [B] int32`` counts the valid
  tokens per sequence.  Functional updates return the new cache; Rust
  round-trips the buffers between executable calls.
* Parameters travel as a flat, deterministically-ordered list (see
  :func:`param_order`) so the Rust side can feed them positionally.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.decode_attention import decode_attention
from compile.kernels.prefill_attention import prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture of the served GPT."""

    vocab: int = 256          # byte-level vocabulary
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    max_seq: int = 128        # S: per-row KV capacity
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def mlp_dim(self) -> int:
        return self.d_model * self.mlp_mult


# The canonical small config served by examples/serve_real.rs.  Chosen so
# interpret-mode Pallas on CPU PJRT stays fast while still exercising a
# multi-layer, multi-head transformer (~100k params).
TINY = ModelConfig()


def param_order(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the ABI between aot.py and Rust.

    Rust reads ``artifacts/params.manifest`` (written from this function)
    and feeds the parameter literals positionally before the activations.
    """
    d, s, v, m = cfg.d_model, cfg.max_seq, cfg.vocab, cfg.mlp_dim
    order: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        order += [
            (p + "ln1_scale", (d,)),
            (p + "ln1_bias", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_scale", (d,)),
            (p + "ln2_bias", (d,)),
            (p + "w1", (d, m)),
            (p + "b1", (m,)),
            (p + "w2", (m, d)),
            (p + "b2", (d,)),
        ]
    order += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return order


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic parameter init (same seed ⇒ same bytes in the blob)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_order(cfg):
        if name.endswith("_scale"):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_bias", "b1", "b2")):
            arr = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0]
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def params_to_list(cfg: ModelConfig, params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[name] for name, _ in param_order(cfg)]


def list_to_params(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    order = param_order(cfg)
    assert len(flat) == len(order), (len(flat), len(order))
    out = {}
    for (name, shape), arr in zip(order, flat):
        assert tuple(arr.shape) == shape, (name, arr.shape, shape)
        out[name] = arr
    return out


def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, T, D] -> [B*H, T, Dh] (row-major over (b, h))."""
    b, t, _ = x.shape
    x = x.reshape(b, t, cfg.n_heads, cfg.head_dim)
    x = x.transpose(0, 2, 1, 3)  # [B, H, T, Dh]
    return x.reshape(b * cfg.n_heads, t, cfg.head_dim)


def _merge_heads(x: jax.Array, cfg: ModelConfig, b: int) -> jax.Array:
    """[B*H, T, Dh] -> [B, T, D]."""
    t = x.shape[1]
    x = x.reshape(b, cfg.n_heads, t, cfg.head_dim).transpose(0, 2, 1, 3)
    return x.reshape(b, t, cfg.d_model)


def _mlp(x: jax.Array, p: Dict[str, jax.Array], prefix: str) -> jax.Array:
    h = jnp.dot(x, p[prefix + "w1"]) + p[prefix + "b1"]
    h = jax.nn.gelu(h)
    return jnp.dot(h, p[prefix + "w2"]) + p[prefix + "b2"]


def prefill(
    params: Dict[str, jax.Array],
    cfg: ModelConfig,
    tokens: jax.Array,   # [B, T] int32 (padded with anything past lengths)
    lengths: jax.Array,  # [B] int32, 1 <= len <= T
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ingest prompts; return next-token logits and the primed KV cache.

    Returns:
      logits:   [B, V] at each row's last valid position.
      k_cache:  [L, B*H, S, Dh] — keys written at [0, T), zero elsewhere.
      v_cache:  [L, B*H, S, Dh].
    """
    b, t = tokens.shape
    s = cfg.max_seq
    assert t <= s
    h = params["tok_emb"][tokens] + params["pos_emb"][:t][None, :, :]

    # Per-head valid lengths for the pallas kernel: [B*H]
    row_lens = jnp.repeat(lengths.astype(jnp.int32), cfg.n_heads)

    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        x = _layer_norm(h, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = _split_heads(jnp.dot(x, params[p + "wq"]), cfg)
        k = _split_heads(jnp.dot(x, params[p + "wk"]), cfg)
        v = _split_heads(jnp.dot(x, params[p + "wv"]), cfg)
        att = prefill_attention(q, k, v, row_lens)          # L1 kernel
        att = _merge_heads(att, cfg, b)
        h = h + jnp.dot(att, params[p + "wo"])
        x2 = _layer_norm(h, params[p + "ln2_scale"], params[p + "ln2_bias"])
        h = h + _mlp(x2, params, p)
        pad = s - t
        k_caches.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0))))

    hf = _layer_norm(h, params["lnf_scale"], params["lnf_bias"])
    logits_all = jnp.dot(hf, params["tok_emb"].T)           # tied head [B,T,V]
    last = jnp.clip(lengths - 1, 0, t - 1)
    logits = jnp.take_along_axis(
        logits_all, last[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(
    params: Dict[str, jax.Array],
    cfg: ModelConfig,
    tokens: jax.Array,    # [B] int32 — the tokens produced last step
    k_cache: jax.Array,   # [L, B*H, S, Dh]
    v_cache: jax.Array,
    lengths: jax.Array,   # [B] int32 — valid KV entries *before* this step
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One autoregressive step over the whole batch.

    The new token is written into the cache at position ``lengths`` and
    attention runs over ``lengths + 1`` valid entries — the L1 decode
    kernel sees exactly the per-row heterogeneity the paper studies.

    Returns ``(logits [B, V], k_cache', v_cache', lengths + 1)``.
    """
    b = tokens.shape[0]
    pos = jnp.clip(lengths, 0, cfg.max_seq - 1)
    h = params["tok_emb"][tokens] + params["pos_emb"][pos]   # [B, D]
    h = h[:, None, :]                                        # [B, 1, D]

    row_lens = jnp.repeat((lengths + 1).astype(jnp.int32), cfg.n_heads)
    row_pos = jnp.repeat(pos.astype(jnp.int32), cfg.n_heads)  # [B*H]

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        x = _layer_norm(h, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = _split_heads(jnp.dot(x, params[p + "wq"]), cfg)[:, 0, :]  # [R, Dh]
        k = _split_heads(jnp.dot(x, params[p + "wk"]), cfg)[:, 0, :]
        v = _split_heads(jnp.dot(x, params[p + "wv"]), cfg)[:, 0, :]
        # Scatter this step's K/V into the cache at each row's position.
        kc = jax.vmap(lambda c, kk, pp: jax.lax.dynamic_update_slice(
            c, kk[None, :], (pp, 0)))(k_cache[i], k, row_pos)
        vc = jax.vmap(lambda c, vv, pp: jax.lax.dynamic_update_slice(
            c, vv[None, :], (pp, 0)))(v_cache[i], v, row_pos)
        att = decode_attention(q, kc, vc, row_lens)           # L1 kernel
        att = _merge_heads(att[:, None, :], cfg, b)           # [B, 1, D]
        h = h + jnp.dot(att, params[p + "wo"])
        x2 = _layer_norm(h, params[p + "ln2_scale"], params[p + "ln2_bias"])
        h = h + _mlp(x2, params, p)
        new_k.append(kc)
        new_v.append(vc)

    hf = _layer_norm(h[:, 0, :], params["lnf_scale"], params["lnf_bias"])
    logits = jnp.dot(hf, params["tok_emb"].T)                 # [B, V]
    return logits, jnp.stack(new_k), jnp.stack(new_v), lengths + 1


# ---------------------------------------------------------------------------
# Flat-argument wrappers: the exact signatures lowered to HLO by aot.py.
# Params come first (in param_order), then activations, so the Rust side
# can keep one parameter-literal vector per executable.
# ---------------------------------------------------------------------------

def make_prefill_fn(cfg: ModelConfig):
    n_params = len(param_order(cfg))

    def fn(*args):
        flat, (tokens, lengths) = list(args[:n_params]), args[n_params:]
        params = list_to_params(cfg, flat)
        return prefill(params, cfg, tokens, lengths)

    return fn


def make_decode_fn(cfg: ModelConfig):
    n_params = len(param_order(cfg))

    def fn(*args):
        flat = list(args[:n_params])
        tokens, k_cache, v_cache, lengths = args[n_params:]
        params = list_to_params(cfg, flat)
        return decode_step(params, cfg, tokens, k_cache, v_cache, lengths)

    return fn


def reference_generate(
    params: Dict[str, jax.Array],
    cfg: ModelConfig,
    prompt: jax.Array,    # [B, T0] int32
    lengths: jax.Array,   # [B] int32
    steps: int,
) -> jax.Array:
    """Greedy generation through prefill + decode_step (test oracle)."""
    logits, kc, vc = prefill(params, cfg, prompt, lengths)
    lens = lengths
    toks = []
    for _ in range(steps):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(nxt)
        logits, kc, vc, lens = decode_step(params, cfg, nxt, kc, vc, lens)
    return jnp.stack(toks, axis=1)  # [B, steps]
