"""AOT compile path: lower the L2 model to HLO **text** + parameter blob.

Run once by ``make artifacts``; the Rust runtime
(`rust/src/runtime/`) then loads the artifacts via
``HloModuleProto::from_text_file`` and serves with no Python anywhere on
the request path.

Interchange format is HLO *text*, NOT ``lowered.compile().serialize()``
and NOT a serialized ``HloModuleProto``: jax >= 0.5 emits protos with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written (all under ``--out-dir``, default ``artifacts/``):

* ``prefill_b{B}_t{T}.hlo.txt``  — prefill executable per batch variant
* ``decode_b{B}.hlo.txt``        — decode-step executable per batch variant
* ``params.bin``                 — little-endian f32 blob, params in order
* ``params.manifest``            — text ABI: ``name ndim dims... offset``
* ``model.meta``                 — key=value model geometry for Rust

Batch variants cover the batch sizes the Rust engine actually forms
(powers of two); Rust pads a short batch up to the nearest variant with
inert rows and ignores their outputs.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# Batch variants compiled ahead of time.  The engine picks the smallest
# variant >= live batch and pads with inert rows.
DEFAULT_BATCHES = (1, 2, 4, 8)
DEFAULT_PREFILL_T = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.ModelConfig, batch: int, t: int) -> str:
    fn = M.make_prefill_fn(cfg)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_order(cfg)]
    specs.append(jax.ShapeDtypeStruct((batch, t), jnp.int32))       # tokens
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))         # lengths
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    fn = M.make_decode_fn(cfg)
    r = batch * cfg.n_heads
    cache = (cfg.n_layers, r, cfg.max_seq, cfg.head_dim)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_order(cfg)]
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))         # tokens
    specs.append(jax.ShapeDtypeStruct(cache, jnp.float32))          # k_cache
    specs.append(jax.ShapeDtypeStruct(cache, jnp.float32))          # v_cache
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))         # lengths
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_params(cfg: M.ModelConfig, out_dir: str, seed: int) -> None:
    params = M.init_params(cfg, seed=seed)
    order = M.param_order(cfg)
    blob_path = os.path.join(out_dir, "params.bin")
    man_path = os.path.join(out_dir, "params.manifest")
    offset = 0
    with open(blob_path, "wb") as blob, open(man_path, "w") as man:
        for name, shape in order:
            arr = np.asarray(params[name], dtype="<f4")
            assert tuple(arr.shape) == shape
            blob.write(arr.tobytes())
            dims = " ".join(str(d) for d in shape)
            man.write(f"{name} {len(shape)} {dims} {offset}\n")
            offset += arr.size
    print(f"wrote {blob_path} ({offset * 4} bytes), {man_path}")


def write_meta(cfg: M.ModelConfig, out_dir: str, batches, prefill_t: int) -> None:
    path = os.path.join(out_dir, "model.meta")
    with open(path, "w") as f:
        f.write(f"vocab={cfg.vocab}\n")
        f.write(f"d_model={cfg.d_model}\n")
        f.write(f"n_heads={cfg.n_heads}\n")
        f.write(f"n_layers={cfg.n_layers}\n")
        f.write(f"max_seq={cfg.max_seq}\n")
        f.write(f"head_dim={cfg.head_dim}\n")
        f.write(f"prefill_t={prefill_t}\n")
        f.write("batches=" + ",".join(str(b) for b in batches) + "\n")
        f.write(f"n_params={len(M.param_order(cfg))}\n")
    print(f"wrote {path}")


def write_goldens(cfg: M.ModelConfig, out_dir: str, seed: int, prefill_t: int) -> None:
    """Golden generations for the Rust end-to-end numerics test.

    Format, one request per line:
    ``prompt_csv|prompt_len|steps|expected_csv`` where expected tokens
    come from greedy decoding through the same prefill/decode functions
    that were lowered to HLO.
    """
    import numpy as _np

    params = M.init_params(cfg, seed=seed)
    rng = _np.random.default_rng(1234)
    path = os.path.join(out_dir, "golden.txt")
    steps = 16
    cases = [(4, 3), (12, 4), (20, 2), (prefill_t, 1)]  # (prompt_len, batch)
    with open(path, "w") as f:
        for plen, batch in cases:
            prompts = rng.integers(0, cfg.vocab, (batch, prefill_t)).astype("int32")
            lens = jnp.full((batch,), plen, jnp.int32)
            toks = M.reference_generate(params, cfg, jnp.asarray(prompts), lens, steps)
            toks = _np.asarray(toks)
            for b in range(batch):
                prompt_csv = ",".join(str(x) for x in prompts[b, :plen])
                exp_csv = ",".join(str(x) for x in toks[b])
                f.write(f"{prompt_csv}|{plen}|{steps}|{exp_csv}\n")
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="legacy sentinel path; implies --out-dir dirname")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default=",".join(map(str, DEFAULT_BATCHES)))
    ap.add_argument("--prefill-t", type=int, default=DEFAULT_PREFILL_T)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    cfg = M.TINY
    batches = tuple(int(b) for b in args.batches.split(","))

    for b in batches:
        text = lower_prefill(cfg, b, args.prefill_t)
        p = os.path.join(out_dir, f"prefill_b{b}_t{args.prefill_t}.hlo.txt")
        with open(p, "w") as f:
            f.write(text)
        print(f"wrote {p} ({len(text)} chars)")

        text = lower_decode(cfg, b)
        p = os.path.join(out_dir, f"decode_b{b}.hlo.txt")
        with open(p, "w") as f:
            f.write(text)
        print(f"wrote {p} ({len(text)} chars)")

    write_params(cfg, out_dir, args.seed)
    write_meta(cfg, out_dir, batches, args.prefill_t)
    write_goldens(cfg, out_dir, args.seed, args.prefill_t)

    # Sentinel consumed by the Makefile's staleness check.
    if args.out:
        with open(args.out, "w") as f:
            f.write("ok\n")


if __name__ == "__main__":
    main()
