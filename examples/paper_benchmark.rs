//! Paper-style sweep: one model, several arrival rates, four systems —
//! the shape of Figs. 6, 7 and 10 in one table.
//!
//! ```bash
//! cargo run --release --example paper_benchmark [requests_per_rate]
//! ```

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::workload::{generate, ShareGptLike};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(800);
    let rates = [8.0, 16.0, 32.0, 48.0];
    let systems = [
        SchedulerKind::Cascade,
        SchedulerKind::RoundRobin,
        SchedulerKind::SgLangLike,
        SchedulerKind::LlumnixLike,
    ];
    println!(
        "{:<6} {:<14} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "rate", "system", "TTFT", "p95TTFT", "TPOT", "p95TPOT", "tok/s"
    );
    for rate in rates {
        let reqs = generate(&ShareGptLike::default(), rate, n, 42);
        for k in systems {
            let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 16, k);
            if k == SchedulerKind::LlumnixLike {
                cfg.engine_speed = 1.25;
            }
            let (r, _) = run_experiment(cfg, &reqs);
            println!(
                "{:<6.1} {:<14} {:>9.4}s {:>9.4}s {:>9.5}s {:>9.5}s {:>11.1}",
                rate,
                k.name(),
                r.mean_ttft(),
                r.p95_ttft(),
                r.mean_tpot(),
                r.p95_tpot(),
                r.throughput_tokens_per_s()
            );
        }
    }
}
