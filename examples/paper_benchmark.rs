//! Paper-style sweep: one model, several arrival rates, four systems —
//! the shape of Figs. 6, 7 and 10 in one table.  Same grid the
//! `cascade-infer sweep` subcommand runs, here via the library API.
//!
//! ```bash
//! cargo run --release --example paper_benchmark [requests_per_rate]
//! ```

use cascade_infer::experiment::Experiment;
use cascade_infer::workload::{generate, ShareGptLike};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(800);
    let rates = [8.0, 16.0, 32.0, 48.0];
    // Registry names; `llumnix` carries its faster engine speed.
    let systems = ["cascade", "vllm", "sglang", "llumnix"];
    println!(
        "{:<6} {:<14} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "rate", "system", "TTFT", "p95TTFT", "TPOT", "p95TPOT", "tok/s"
    );
    for rate in rates {
        let reqs = generate(&ShareGptLike::default(), rate, n, 42);
        for name in systems {
            let (r, _) = Experiment::builder()
                .model("Llama-3.2-3B")
                .gpu("H20")
                .instances(16)
                .scheduler(name)
                .trace(reqs.clone())
                .build()
                .expect("experiment builds")
                .run();
            println!(
                "{:<6.1} {:<14} {:>9.4}s {:>9.4}s {:>9.5}s {:>9.5}s {:>11.1}",
                rate,
                name,
                r.mean_ttft(),
                r.p95_ttft(),
                r.mean_tpot(),
                r.p95_tpot(),
                r.throughput_tokens_per_s()
            );
        }
    }
}
