//! Trace explorer: generate (or load) a workload trace, show its
//! length distribution (the Fig. 1 shape), and print the pipeline the
//! planner would build for it.
//!
//! ```bash
//! cargo run --release --example trace_explorer [trace.csv]
//! ```

use cascade_infer::coordinator::plan::{MigrationCost, Planner};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::qoe::profile_and_fit;
use cascade_infer::workload::{self, LengthHistogram, ShareGptLike};

fn main() {
    let reqs = match std::env::args().nth(1) {
        Some(path) => workload::load_csv(&path).expect("readable trace"),
        None => workload::generate(&ShareGptLike::default(), 10.0, 10_000, 42),
    };
    println!("{} requests", reqs.len());

    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    println!("\nfinal-length distribution (log buckets):");
    let max = *hist.count.iter().max().unwrap() as f64;
    let mut lo = 0u64;
    for (k, &hi) in hist.bounds.iter().enumerate() {
        if hist.count[k] > 0 {
            let bar = "#".repeat((hist.count[k] as f64 / max * 50.0).ceil() as usize);
            println!("[{lo:>7},{hi:>7}) {:>6}  {bar}", hist.count[k]);
        }
        lo = hi;
    }

    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let (qoe, _) = profile_and_fit(&am, 64, 131_072, 512);
    let planner = Planner::new(
        qoe,
        MigrationCost::new(LLAMA_3B.kv_bytes_per_token() as f64, 450e9),
    );
    let pipe = planner.plan_dp(&hist, 16);
    println!("\nplanned pipeline for 16 instances:");
    for (i, s) in pipe.stages.iter().enumerate() {
        println!("  stage {i}: [{:>7}, {:>7})  x{} instances", s.lo, s.hi, s.n_instances);
    }
}
