//! Predictor-robustness sweep: how cascade's QoE degrades as length
//! prediction gets worse, and what the mid-flight recovery machinery
//! (misprediction re-routes, admission escalations) does about it.
//!
//! Runs the heavy-tail workload under every predictor family — the
//! exact oracle, mean-preserving lognormal noise at growing CV,
//! bucket-classifier confusion, and a rank-only (`ltr`) predictor —
//! and prints the QoE-vs-accuracy table behind
//! `sweep --predictors "oracle;noisy:0.2;noisy:0.5;bucket:0.7;ltr:0.8"`.
//!
//! ```bash
//! cargo run --release --example predictor_robustness
//! ```

use cascade_infer::experiment::Experiment;
use cascade_infer::metrics::Slo;
use cascade_infer::workload::{generate, ShareGptLike};

const PREDICTORS: [&str; 6] =
    ["oracle", "noisy:0.2", "noisy:0.5", "noisy:0.8", "bucket:0.7", "ltr:0.8"];

fn main() {
    let requests = generate(&ShareGptLike::heavy_tail(), 24.0, 800, 42);
    let slo = Slo { ttft: 1.0, tpot: 0.1 };
    println!(
        "workload: {} heavy-tail requests over {:.1}s, 8 instances, cascade",
        requests.len(),
        requests.last().unwrap().arrival
    );
    println!(
        "\n{:<12} {:>7} {:>11} {:>11} {:>9} {:>9} {:>9} {:>9}",
        "predictor", "SLO%", "mean TTFT", "norm lat.", "migr", "mispred", "reroute", "escal"
    );
    for p in PREDICTORS {
        let (report, stats) = Experiment::builder()
            .instances(8)
            .scheduler("cascade")
            .predictor(p)
            .trace(requests.clone())
            .build()
            .expect("experiment builds")
            .run();
        println!(
            "{:<12} {:>6.1}% {:>10.4}s {:>8.5}s/t {:>9} {:>9} {:>9} {:>9}",
            p,
            100.0 * report.slo_attainment(slo),
            report.mean_ttft(),
            report.mean_normalized_latency(),
            stats.migrations,
            stats.mispredictions,
            stats.predict_reroutes,
            stats.predict_escalations
        );
    }
    println!(
        "\nThe oracle row is the legacy simulator bit-for-bit; rising CV \
         degrades SLO attainment while re-routes recover sequences that \
         outgrew their predicted stage mid-flight."
    );
}
