//! Quickstart: build experiments with the `Experiment` builder and
//! compare CascadeInfer against a round-robin baseline — plus one
//! ad-hoc `custom:` policy the closed scheduler enum could never
//! express.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cascade_infer::experiment::Experiment;
use cascade_infer::workload::{generate, ShareGptLike};

fn main() {
    // 1. A ShareGPT-like workload: skewed lengths, Poisson arrivals.
    //    Generated once and shared so every system sees the same trace.
    let requests = generate(&ShareGptLike::default(), 24.0, 800, 42);
    println!("workload: {} requests over {:.1}s", requests.len(),
             requests.last().unwrap().arrival);

    // 2. Three systems through the one construction path.  Scheduler
    //    names go through the policy registry, so ad-hoc axis combos
    //    work exactly like built-ins.
    let systems = [
        "cascade",
        "vllm",
        "custom:layout=planned,refine=memory,balance=rrintra",
    ];
    println!("\n{:<46} {:>12} {:>12} {:>14}", "scheduler", "mean TTFT", "mean TPOT", "throughput");
    let mut cascade_stats = None;
    for name in systems {
        let (report, stats) = Experiment::builder()
            .model("Llama-3.2-3B")
            .gpu("H20")
            .instances(8)
            .scheduler(name)
            .trace(requests.clone())
            .build()
            .expect("experiment builds")
            .run();
        println!(
            "{:<46} {:>11.4}s {:>11.5}s {:>10.1} tok/s",
            name,
            report.mean_ttft(),
            report.mean_tpot(),
            report.throughput_tokens_per_s()
        );
        if name == "cascade" {
            cascade_stats = Some(stats);
        }
    }
    let stats = cascade_stats.unwrap();
    println!(
        "\nCascadeInfer: {} stages, {} migrations, boundaries {:?}",
        stats.stages.len(),
        stats.migrations,
        stats.final_boundaries
    );
}
