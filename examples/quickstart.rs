//! Quickstart: plan a length-aware pipeline and simulate a small
//! CascadeInfer cluster against a round-robin baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::workload::{generate, ShareGptLike};

fn main() {
    // 1. A ShareGPT-like workload: skewed lengths, Poisson arrivals.
    let requests = generate(&ShareGptLike::default(), 24.0, 800, 42);
    println!("workload: {} requests over {:.1}s", requests.len(),
             requests.last().unwrap().arrival);

    // 2. CascadeInfer on 8 simulated H20 instances.
    let cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 8, SchedulerKind::Cascade);
    let (cascade, stats) = run_experiment(cfg, &requests);

    // 3. The same workload through a round-robin load balancer.
    let cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 8, SchedulerKind::RoundRobin);
    let (rr, _) = run_experiment(cfg, &requests);

    println!("\n{:<14} {:>12} {:>12} {:>14}", "scheduler", "mean TTFT", "mean TPOT", "throughput");
    for (name, r) in [("CascadeInfer", &cascade), ("RoundRobin", &rr)] {
        println!(
            "{:<14} {:>11.4}s {:>11.5}s {:>10.1} tok/s",
            name,
            r.mean_ttft(),
            r.mean_tpot(),
            r.throughput_tokens_per_s()
        );
    }
    println!(
        "\nCascadeInfer: {} stages, {} migrations, boundaries {:?}",
        stats.stages.len(),
        stats.migrations,
        stats.final_boundaries
    );
}
