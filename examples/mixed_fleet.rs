//! Mixed-fleet comparison: CascadeInfer vs round-robin on a
//! heterogeneous `h20:6,h100:2` fleet under the heavy-tail workload,
//! plus a tensor-parallel variant serving Llama-70B on mixed
//! TP2/TP4 H20 slices.
//!
//! Shows the fleet axis end to end: the experiment builder parses the
//! fleet string, the planner partitions over per-instance capacity
//! (and, for TP fleets, KV feasibility + collective premiums),
//! capacity-normalized routing/bidding shifts load toward the fast
//! instances, and the per-instance report tags each instance with its
//! GPU and TP degree.
//!
//! ```bash
//! cargo run --release --example mixed_fleet
//! ```

use cascade_infer::experiment::Experiment;
use cascade_infer::models::llama_70b;
use cascade_infer::workload::{generate, ShareGptLike};

const FLEET: &str = "h20:6,h100:2";
const TP_FLEET: &str = "h20:4,tp=2,h20:2,tp=4";

fn main() {
    // Heavy-tail traffic (8% of prompts on a fat Pareto tail) — the
    // regime where length-aware stages matter most, now spread over a
    // fleet where two instances are much faster than the other six.
    let requests = generate(&ShareGptLike::heavy_tail(), 24.0, 800, 42);
    println!(
        "workload: {} heavy-tail requests over {:.1}s on fleet {FLEET}",
        requests.len(),
        requests.last().unwrap().arrival
    );

    println!(
        "\n{:<12} {:>12} {:>12} {:>14} {:>12}",
        "scheduler", "mean TTFT", "norm lat.", "throughput", "migrations"
    );
    let mut cascade_stats = None;
    for name in ["cascade", "vllm"] {
        let (report, stats) = Experiment::builder()
            .model("Llama-3.2-3B")
            .fleet(FLEET)
            .scheduler(name)
            .trace(requests.clone())
            .build()
            .expect("experiment builds")
            .run();
        // QoE here is the paper's quality metric: normalized latency
        // (end-to-end seconds per output token; lower is better).
        println!(
            "{:<12} {:>11.4}s {:>9.5}s/t {:>10.1} tok/s {:>12}",
            name,
            report.mean_ttft(),
            report.mean_normalized_latency(),
            report.throughput_tokens_per_s(),
            stats.migrations
        );
        if name == "cascade" {
            cascade_stats = Some(stats);
        }
    }

    // Per-instance view of the cascade run: the H100s sit on the
    // long-sequence stages and carry a disproportionate share of the
    // steady-state token load.
    let stats = cascade_stats.unwrap();
    println!(
        "\ncascade pipeline: {} stages {:?}, boundaries {:?}",
        stats.stages.len(),
        stats.stages.iter().map(|s| s.len()).collect::<Vec<_>>(),
        stats.final_boundaries
    );
    println!("\nper-instance (cascade):");
    println!(
        "{:<4} {:<6} {:>9} {:>16} {:>14}",
        "id", "gpu", "capacity", "mean token load", "out tokens"
    );
    for i in 0..stats.instance_gpus.len() {
        println!(
            "{:<4} {:<6} {:>9.3} {:>16.0} {:>14}",
            i,
            stats.instance_gpus[i],
            stats.instance_capacity[i],
            stats.mean_token_load.get(i).copied().unwrap_or(0.0),
            stats.counters.output_tokens.get(&i).unwrap_or(&0)
        );
    }

    // --- Tensor-parallel variant: Llama-70B, a model no single H20
    // serves at FP16, on mixed TP2/TP4 slices.  The TP-aware planner
    // puts the long-sequence stage on the TP4 slices (they stream
    // weights/KV 2x faster than TP2 and pool the deepest KV), and the
    // per-instance view shows the load concentrating there.
    let tp_requests = generate(&ShareGptLike::heavy_tail(), 12.0, 400, 42);
    println!(
        "\n=== tensor-parallel fleet {TP_FLEET}, Llama-3.1-70B, {} requests ===",
        tp_requests.len()
    );
    let (report, stats) = Experiment::builder()
        .model_profile(llama_70b(1))
        .fleet(TP_FLEET)
        .scheduler("cascade")
        .trace(tp_requests)
        .build()
        .expect("tp experiment builds")
        .run();
    println!(
        "cascade: mean TTFT {:.4}s, norm lat {:.5}s/t, throughput {:.1} tok/s, {} migrations",
        report.mean_ttft(),
        report.mean_normalized_latency(),
        report.throughput_tokens_per_s(),
        stats.migrations
    );
    println!(
        "pipeline: {} stages {:?}, boundaries {:?}",
        stats.stages.len(),
        stats.stages.iter().map(|s| s.len()).collect::<Vec<_>>(),
        stats.final_boundaries
    );
    println!("\nper-instance (cascade, tp fleet):");
    println!(
        "{:<4} {:<6} {:<4} {:>9} {:>16} {:>14}",
        "id", "gpu", "tp", "capacity", "mean token load", "out tokens"
    );
    for i in 0..stats.instance_gpus.len() {
        println!(
            "{:<4} {:<6} {:<4} {:>9.3} {:>16.0} {:>14}",
            i,
            stats.instance_gpus[i],
            stats.instance_tp[i],
            stats.instance_capacity[i],
            stats.mean_token_load.get(i).copied().unwrap_or(0.0),
            stats.counters.output_tokens.get(&i).unwrap_or(&0)
        );
    }
}
