//! End-to-end driver (the DESIGN.md §E2E experiment): serve a batched
//! Poisson workload through the REAL three-layer stack —
//!
//!   L1 Pallas kernels -> L2 JAX model -> HLO text -> L3 Rust PJRT
//!
//! on a multi-stage CascadeInfer pipeline with live KV migration, and
//! report latency/throughput. Python is not involved at any point;
//! only `artifacts/` is read.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_real
//! ```

use cascade_infer::server::{ServeRequest, Server, ServerConfig};
use cascade_infer::sim::{Exponential, Rng};
use std::time::{Duration, Instant};

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);

    // A 3-stage length pipeline over the tiny GPT's 128-token window.
    let mut cfg = ServerConfig::new(
        std::env::var("CASCADE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    cfg.stage_boundaries = vec![48, 80];
    cfg.max_batch = 8;
    println!(
        "starting {} instances ({} stages); compiling executables...",
        cfg.n_instances(),
        cfg.stage_boundaries.len() + 1
    );
    let t0 = Instant::now();
    let mut server = Server::start(cfg).expect("server starts (run `make artifacts`)");
    println!("started in {:.1}s", t0.elapsed().as_secs_f64());

    // Poisson arrivals of byte-token prompts with skewed lengths.
    let mut rng = Rng::new(7);
    let gap = Exponential::new(40.0);
    let t0 = Instant::now();
    let mut submitted = 0;
    for id in 0..n_requests {
        let plen = if rng.next_f64() < 0.25 {
            20 + rng.next_range(12) as usize // "long" prompts
        } else {
            4 + rng.next_range(12) as usize
        };
        let prompt: Vec<i32> = (0..plen).map(|_| rng.next_range(256) as i32).collect();
        let max_new = 16 + rng.next_range(48) as usize;
        server.submit(ServeRequest { id: id as u64, prompt, max_new_tokens: max_new });
        submitted += 1;
        std::thread::sleep(Duration::from_secs_f64(gap.sample(&mut rng).min(0.05)));
    }

    let responses = server.collect(submitted);
    let wall = t0.elapsed().as_secs_f64();

    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let migrated = responses.iter().filter(|r| r.served_by.len() > 1).count();
    let mut ttfts: Vec<f64> = responses.iter().map(|r| r.ttft().as_secs_f64()).collect();
    let mut e2es: Vec<f64> = responses.iter().map(|r| r.e2e().as_secs_f64()).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    e2es.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let p95 = |v: &[f64]| v[(v.len() as f64 * 0.95) as usize % v.len()];

    println!("\n=== serve_real results (real PJRT path) ===");
    println!("requests        {submitted}");
    println!("output tokens   {total_tokens}");
    println!("wall time       {wall:.2}s");
    println!("throughput      {:.1} tok/s", total_tokens as f64 / wall);
    println!("TTFT            mean {:.3}s  p95 {:.3}s", mean(&ttfts), p95(&ttfts));
    println!("E2E             mean {:.3}s  p95 {:.3}s", mean(&e2es), p95(&e2es));
    println!("migrated        {migrated} requests crossed a stage boundary");
    server.shutdown();
}
