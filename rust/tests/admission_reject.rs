//! Admission-rejection regression (the PR 5 deadlock hazard): on a
//! fleet whose per-instance KV pool is smaller than a request's final
//! length (70B at TP2 on H100 pools only ~28K tokens), the oversized
//! request must be rejected at router admission with a diagnostic —
//! not parked at the FCFS queue head where it wedges the instance and
//! the run forever.

use cascade_infer::experiment::Experiment;
use cascade_infer::workload::Request;

fn trace_with_oversized(oversized_final: u64) -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: 256 + i * 8,
            output_len: 64,
        })
        .collect();
    // One sequence whose *final* length can never fit the TP2 slice's
    // pool, arriving in the middle of the normal traffic.
    reqs.push(Request {
        id: 1000,
        arrival: 0.4,
        input_len: oversized_final - 10_000,
        output_len: 10_000,
    });
    reqs
}

fn run(reqs: &[Request]) -> (cascade_infer::metrics::Report, cascade_infer::cluster::RunStats) {
    Experiment::builder()
        .fleet("h100:2,tp=2")
        .model("llama70b")
        .scheduler("cascade")
        .trace(reqs.to_vec())
        .build()
        .expect("70B TP2 experiment builds")
        .run()
}

#[test]
fn oversized_request_is_rejected_not_wedged() {
    let reqs = trace_with_oversized(100_000);
    let (report, stats) = run(&reqs);

    assert_eq!(stats.rejected, 1, "exactly the oversized request is rejected");
    assert_eq!(stats.rejections.len(), 1);
    let rej = stats.rejections[0];
    assert_eq!(rej.request, 1000);
    assert_eq!(rej.final_len, 100_000);
    assert!(
        rej.pool_tokens < rej.final_len,
        "diagnostic records a pool ({}) the sequence ({}) cannot fit",
        rej.pool_tokens,
        rej.final_len
    );

    // Every normal request still completes: the run terminates (this
    // test hanging forever was the failure mode) and no head-of-line
    // request starves behind the oversized one.
    assert_eq!(report.records.len(), reqs.len() - 1);
    assert!(report.records.iter().all(|r| r.id != 1000));
}

#[test]
fn rejection_path_is_run_to_run_deterministic() {
    let reqs = trace_with_oversized(100_000);
    let (r1, s1) = run(&reqs);
    let (r2, s2) = run(&reqs);
    assert_eq!(r1.fingerprint(), r2.fingerprint());
    assert_eq!(s1.rejected, s2.rejected);
    assert_eq!(s1.rejections, s2.rejections);
}

#[test]
fn fitting_requests_are_not_rejected() {
    // Same fleet, all requests within the pool: nothing is rejected
    // and every request completes.
    let reqs: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: 256 + i * 8,
            output_len: 64,
        })
        .collect();
    let (report, stats) = run(&reqs);
    assert_eq!(stats.rejected, 0);
    assert!(stats.rejections.is_empty());
    assert_eq!(stats.admit_reroutes, 0, "every pool fits: the reroute scan never fires");
    assert_eq!(report.records.len(), reqs.len());
}

#[test]
fn homogeneous_fleet_still_rejects_with_no_reroute() {
    // All-TP2 fleet: nothing can hold the 100K request, so the
    // reroute scan finds no feasible alternative and the rejection
    // path is unchanged.
    let reqs = trace_with_oversized(100_000);
    let (_, stats) = run(&reqs);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.admit_reroutes, 0);
}

#[test]
fn mixed_tp_fleet_reroutes_instead_of_rejecting() {
    // Instances 0-1 are 70B TP2 (~28K-token pools), instance 2 is TP4
    // (~2x that): ~39K-final requests round-robined onto a TP2
    // instance must re-route to the TP4 instance instead of being
    // rejected.  Three oversized arrivals lead the trace so at least
    // two of them hit a TP2 slot whatever the counter phase.
    let mut reqs: Vec<Request> = (0..3u64)
        .map(|i| Request {
            id: i,
            arrival: 0.3 + i as f64 * 0.05,
            input_len: 39_000,
            output_len: 200,
        })
        .collect();
    reqs.extend((10..40u64).map(|i| Request {
        id: i,
        arrival: 0.3 + i as f64 * 0.05,
        input_len: 256 + i * 8,
        output_len: 64,
    }));
    let (report, stats) = Experiment::builder()
        .fleet("h100:2,tp=2,h100:1,tp=4")
        .model("llama70b")
        .scheduler("vllm")
        .trace(reqs.clone())
        .build()
        .expect("mixed 70B TP2/TP4 experiment builds")
        .run();
    assert_eq!(
        stats.rejected, 0,
        "the TP4 pool fits every request: {:?}",
        stats.rejections
    );
    assert!(
        stats.admit_reroutes >= 2,
        "round-robin must have preferred an infeasible TP2 target at least twice \
         (got {} reroutes)",
        stats.admit_reroutes
    );
    assert_eq!(report.records.len(), reqs.len(), "every request completes");
}
