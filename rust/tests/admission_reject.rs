//! Admission-rejection regression (the PR 5 deadlock hazard): on a
//! fleet whose per-instance KV pool is smaller than a request's final
//! length (70B at TP2 on H100 pools only ~28K tokens), the oversized
//! request must be rejected at router admission with a diagnostic —
//! not parked at the FCFS queue head where it wedges the instance and
//! the run forever.

use cascade_infer::experiment::Experiment;
use cascade_infer::workload::Request;

fn trace_with_oversized(oversized_final: u64) -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: 256 + i * 8,
            output_len: 64,
        })
        .collect();
    // One sequence whose *final* length can never fit the TP2 slice's
    // pool, arriving in the middle of the normal traffic.
    reqs.push(Request {
        id: 1000,
        arrival: 0.4,
        input_len: oversized_final - 10_000,
        output_len: 10_000,
    });
    reqs
}

fn run(reqs: &[Request]) -> (cascade_infer::metrics::Report, cascade_infer::cluster::RunStats) {
    Experiment::builder()
        .fleet("h100:2,tp=2")
        .model("llama70b")
        .scheduler("cascade")
        .trace(reqs.to_vec())
        .build()
        .expect("70B TP2 experiment builds")
        .run()
}

#[test]
fn oversized_request_is_rejected_not_wedged() {
    let reqs = trace_with_oversized(100_000);
    let (report, stats) = run(&reqs);

    assert_eq!(stats.rejected, 1, "exactly the oversized request is rejected");
    assert_eq!(stats.rejections.len(), 1);
    let rej = stats.rejections[0];
    assert_eq!(rej.request, 1000);
    assert_eq!(rej.final_len, 100_000);
    assert!(
        rej.pool_tokens < rej.final_len,
        "diagnostic records a pool ({}) the sequence ({}) cannot fit",
        rej.pool_tokens,
        rej.final_len
    );

    // Every normal request still completes: the run terminates (this
    // test hanging forever was the failure mode) and no head-of-line
    // request starves behind the oversized one.
    assert_eq!(report.records.len(), reqs.len() - 1);
    assert!(report.records.iter().all(|r| r.id != 1000));
}

#[test]
fn rejection_path_is_run_to_run_deterministic() {
    let reqs = trace_with_oversized(100_000);
    let (r1, s1) = run(&reqs);
    let (r2, s2) = run(&reqs);
    assert_eq!(r1.fingerprint(), r2.fingerprint());
    assert_eq!(s1.rejected, s2.rejected);
    assert_eq!(s1.rejections, s2.rejections);
}

#[test]
fn fitting_requests_are_not_rejected() {
    // Same fleet, all requests within the pool: nothing is rejected
    // and every request completes.
    let reqs: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: 256 + i * 8,
            output_len: 64,
        })
        .collect();
    let (report, stats) = run(&reqs);
    assert_eq!(stats.rejected, 0);
    assert!(stats.rejections.is_empty());
    assert_eq!(report.records.len(), reqs.len());
}
