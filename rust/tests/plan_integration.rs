//! Planner integration: realistic traces, DP-vs-heuristic quality, and
//! the §6.5 complexity claim at reduced scale.

use cascade_infer::coordinator::plan::{MigrationCost, Planner};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::qoe::profile_and_fit;
use cascade_infer::workload::{generate, LengthHistogram, ShareGptLike};

fn planner() -> Planner {
    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let (qoe, _) = profile_and_fit(&am, 64, 131_072, 512);
    Planner::new(qoe, MigrationCost::new(LLAMA_3B.kv_bytes_per_token() as f64, 450e9))
}

#[test]
fn paper_config_plans_4_to_6_stages() {
    // §6.1: "CascadeInfer constructs pipelines of 4 to 6 stages ...
    // each stage comprising 1 to 4 instances" at 16 instances.
    let p = planner();
    let reqs = generate(&ShareGptLike::default(), 10.0, 8000, 42);
    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    let pipe = p.plan_dp(&hist, 16);
    assert!(
        (2..=8).contains(&pipe.stages.len()),
        "stage count {} out of plausible range: {:?}",
        pipe.stages.len(),
        pipe.stages
    );
    assert_eq!(pipe.total_instances(), 16);
    // Our synthetic trace concentrates more mass in the short bucket
    // than ShareGPT proper, so the head stage can get a bigger share
    // than the paper's 1-4; every stage must still be non-degenerate.
    assert!(pipe.stages.iter().all(|s| (1..=15).contains(&s.n_instances)), "{:?}", pipe.stages);
}

#[test]
fn optimized_planner_is_fast_at_cluster_scale() {
    // §6.5: optimized partitioning finishes in ~0.06 s at (16, 128K).
    // Target: well under 0.5 s here (different hardware, same order).
    let p = planner();
    let reqs = generate(&ShareGptLike::default(), 10.0, 8000, 43);
    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    let t0 = std::time::Instant::now();
    let _ = p.plan_dp(&hist, 16);
    let dt = t0.elapsed();
    assert!(dt.as_secs_f64() < 0.5, "DP took {dt:?}");
    let t0 = std::time::Instant::now();
    let _ = p.plan_heuristic(&hist, 16);
    let dt = t0.elapsed();
    assert!(dt.as_secs_f64() < 0.5, "heuristic took {dt:?}");
}

#[test]
fn fine_dp_cost_grows_quadratically_with_cuts() {
    // The naive DP's runtime grows ~quadratically in the number of cut
    // points — the mechanism behind the paper's 51-hour estimate.
    let p = planner();
    let reqs: Vec<(u64, u64)> = generate(&ShareGptLike::default(), 10.0, 1000, 44)
        .iter()
        .map(|r| (r.input_len, r.final_len()))
        .collect();
    let time_at = |granularity: u64| {
        let t0 = std::time::Instant::now();
        let _ = p.plan_exact_fine(&reqs, 4, 32_768, granularity);
        t0.elapsed().as_secs_f64()
    };
    let coarse = time_at(2048); // 16 cuts
    let fine = time_at(512); // 64 cuts
    assert!(
        fine > 4.0 * coarse,
        "expected superlinear growth: coarse {coarse}s fine {fine}s"
    );
}

#[test]
fn refinement_tracks_distribution_shift() {
    use cascade_infer::coordinator::refine::{RangeRefiner, RefineConfig};
    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let (qoe, _) = profile_and_fit(&am, 64, 131_072, 512);
    let mut r = RangeRefiner::new(qoe, 8192, RefineConfig { ema_alpha: 0.5, min_requests: 5 });
    // Workload drifts shorter: boundary should drift down.
    let local: Vec<(u64, u64)> = (0..40).map(|i| (50 + i, 100 + 2 * i)).collect();
    let succ: Vec<Vec<(u64, u64)>> = vec![(0..10).map(|i| (400, 900 + 10 * i)).collect()];
    let mut prev = r.boundary;
    for _ in 0..10 {
        let b = r.refine(&local, &succ);
        assert!(b <= prev + 1, "boundary should be non-increasing, {b} > {prev}");
        prev = b;
    }
    assert!(prev < 4000, "boundary converged to the data, got {prev}");
}
