//! End-to-end numerics: the Rust-served HLO must reproduce, token for
//! token, the greedy generations computed in Python through the same
//! prefill/decode functions (artifacts/golden.txt).
//!
//! This is the strongest cross-language signal in the repo: it proves
//! L1 (Pallas kernels) -> L2 (JAX model) -> AOT (HLO text) -> L3 (Rust
//! PJRT runtime) compose with exact agreement.
//!
//! Requires the `pjrt` feature (real XLA bindings) and `make artifacts`.
#![cfg(feature = "pjrt")]

use cascade_infer::runtime::Runtime;

struct GoldenCase {
    prompt: Vec<i32>,
    steps: usize,
    expected: Vec<i32>,
}

fn load_goldens() -> Vec<GoldenCase> {
    let text = std::fs::read_to_string("artifacts/golden.txt")
        .expect("artifacts/golden.txt missing — run `make artifacts`");
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|line| {
            let parts: Vec<&str> = line.split('|').collect();
            assert_eq!(parts.len(), 4, "bad golden line: {line}");
            let prompt: Vec<i32> =
                parts[0].split(',').map(|s| s.parse().unwrap()).collect();
            let plen: usize = parts[1].parse().unwrap();
            assert_eq!(prompt.len(), plen);
            let steps: usize = parts[2].parse().unwrap();
            let expected: Vec<i32> =
                parts[3].split(',').map(|s| s.parse().unwrap()).collect();
            assert_eq!(expected.len(), steps);
            GoldenCase { prompt, steps, expected }
        })
        .collect()
}

/// Greedy-generate through the runtime, one sequence at a time.
fn generate(rt: &Runtime, prompt: &[i32], steps: usize) -> Vec<i32> {
    let t = rt.meta.prefill_t;
    let mut tokens = vec![0i32; t];
    tokens[..prompt.len()].copy_from_slice(prompt);
    let out = rt.prefill(&tokens, &[prompt.len() as i32]).expect("prefill");
    let mut produced = Vec::with_capacity(steps);
    let mut next = rt.argmax_tokens(&out.logits)[0];
    produced.push(next);
    let mut k = out.k_cache;
    let mut v = out.v_cache;
    let mut lens = vec![prompt.len() as i32];
    for _ in 1..steps {
        let d = rt.decode(&[next], &k, &v, &lens).expect("decode");
        next = rt.argmax_tokens(&d.logits)[0];
        produced.push(next);
        k = d.k_cache;
        v = d.v_cache;
        lens = d.lengths;
    }
    produced
}

#[test]
fn rust_served_tokens_match_python_goldens() {
    let rt = Runtime::load("artifacts").expect("artifacts compile");
    let cases = load_goldens();
    assert!(!cases.is_empty());
    for (i, c) in cases.iter().enumerate() {
        let got = generate(&rt, &c.prompt, c.steps);
        assert_eq!(
            got, c.expected,
            "case {i}: rust generation diverged from python golden"
        );
    }
}

#[test]
fn batched_decode_matches_single_row() {
    // Greedy decoding must be batch-size invariant: running two
    // sequences through the b=2 variant gives the same tokens as each
    // alone through b=1. This validates the padding/masking path.
    let rt = Runtime::load("artifacts").expect("artifacts compile");
    let cases = load_goldens();
    let a = &cases[0];
    let b = cases.iter().find(|c| c.prompt.len() != a.prompt.len()).unwrap_or(&cases[1]);
    let t = rt.meta.prefill_t;

    // Batched prefill of both prompts.
    let mut tokens = vec![0i32; 2 * t];
    tokens[..a.prompt.len()].copy_from_slice(&a.prompt);
    tokens[t..t + b.prompt.len()].copy_from_slice(&b.prompt);
    let lens = vec![a.prompt.len() as i32, b.prompt.len() as i32];
    let out = rt.prefill(&tokens, &lens).expect("prefill");
    let mut next = rt.argmax_tokens(&out.logits);
    let mut got_a = vec![next[0]];
    let mut got_b = vec![next[1]];
    let mut k = out.k_cache;
    let mut v = out.v_cache;
    let mut cur = lens.clone();
    let steps = a.steps.min(b.steps);
    for _ in 1..steps {
        let d = rt.decode(&next, &k, &v, &cur).expect("decode");
        next = rt.argmax_tokens(&d.logits);
        got_a.push(next[0]);
        got_b.push(next[1]);
        k = d.k_cache;
        v = d.v_cache;
        cur = d.lengths;
    }
    assert_eq!(got_a, a.expected[..steps].to_vec(), "row 0 diverged in batch");
    assert_eq!(got_b, b.expected[..steps].to_vec(), "row 1 diverged in batch");
}

#[test]
fn padded_variant_matches_exact_variant() {
    // Running 3 live rows through the b=4 variant (one inert pad row)
    // must not disturb the live rows.
    let rt = Runtime::load("artifacts").expect("artifacts compile");
    let cases = load_goldens();
    let picks: Vec<&GoldenCase> = cases.iter().take(3).collect();
    let t = rt.meta.prefill_t;
    let mut tokens = vec![0i32; 3 * t];
    let mut lens = Vec::new();
    for (i, c) in picks.iter().enumerate() {
        tokens[i * t..i * t + c.prompt.len()].copy_from_slice(&c.prompt);
        lens.push(c.prompt.len() as i32);
    }
    let out = rt.prefill(&tokens, &lens).expect("prefill");
    let next = rt.argmax_tokens(&out.logits);
    for (i, c) in picks.iter().enumerate() {
        assert_eq!(next[i], c.expected[0], "padded prefill diverged at row {i}");
    }
}
