//! Elastic-fleet fault-injection regression suite.
//!
//! Three layers of protection:
//!
//! 1. **Churn-free bit-identity** (THE gate): an experiment with an
//!    explicit `--churn none` must produce a byte-identical `Report`
//!    to the same experiment with no churn configured at all, for
//!    every scheduler in the `PolicySpec` registry.  The elastic
//!    machinery must be invisible when no faults are scheduled — the
//!    blessed golden checksums in `golden_seed.rs` then extend that
//!    guarantee across commits.
//! 2. **Accounting**: a seeded spot-preemption run must terminate with
//!    every request accounted — completed in the report or counted in
//!    `RunStats::rejected` — never wedged on an evicted sequence.
//! 3. **Run-to-run determinism per fault kind**: each churn event kind
//!    (`CHURN_COVERAGE`, cross-referenced against `ChurnSpec::names()`
//!    by detlint rule D4) must reproduce bit-for-bit under a fixed
//!    (seed, config, trace, churn-spec) tuple.

use cascade_infer::cluster::{ChurnSpec, RunStats};
use cascade_infer::experiment::Experiment;
use cascade_infer::metrics::Report;
use cascade_infer::workload::{generate, Request, ShareGptLike};

/// Churn-kind coverage list, cross-referenced against the
/// `ChurnSpec::names()` registry by detlint rule D4 (and by the
/// assertion test below): a newly registered fault kind must be added
/// here — and thereby to the determinism gate — before it can ship.
const CHURN_COVERAGE: [&str; 4] = ["spot", "drain", "join", "auto"];

/// A concrete spec per fault kind so the coverage gate exercises real
/// (non-degenerate) fault schedules: a mid-run kill, a bounded drain,
/// a scale-out join, and a tight autoscaler loop.
fn churn_instance(kind: &str) -> &'static str {
    match kind {
        "spot" => "spot:2.0@1",
        "drain" => "drain:1.5@2:0.5",
        "join" => "join:2.5",
        "auto" => "auto:0.5:2..6",
        other => panic!("unknown churn kind {other}"),
    }
}

/// Scheduler registry, mirrored from `golden_seed.rs` (which pins it
/// against `PolicySpec::names()`); every entry runs the churn-free
/// identity gate below.
const SCHEDULERS: [&str; 11] = [
    "cascade",
    "vllm",
    "sglang",
    "llumnix",
    "chain",
    "nopipeline",
    "quantity",
    "memory",
    "interstage",
    "rrintra",
    "sjf",
];

fn checksum(r: &Report) -> u64 {
    r.fingerprint()
}

fn stats_fingerprint(s: &RunStats) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    (
        s.spot_kills,
        s.preempted_requests,
        s.recovered,
        s.lost_tokens,
        s.drains_started + s.drains_completed + s.drains_forced,
        s.joins,
        s.autoscale_ticks,
        s.scale_outs + s.scale_ins,
        s.rejected,
    )
}

fn trace() -> Vec<Request> {
    generate(&ShareGptLike::default(), 20.0, 150, 7)
}

#[test]
fn churn_coverage_list_matches_registry() {
    assert_eq!(
        CHURN_COVERAGE.as_slice(),
        ChurnSpec::names(),
        "CHURN_COVERAGE must mirror the ChurnSpec registry exactly \
         (detlint rule D4 cross-references the literals)"
    );
}

#[test]
fn churn_none_is_bit_identical_for_every_registry_scheduler() {
    // The elastic subsystem must cost nothing when unused: an explicit
    // `none` spec and an absent spec must take exactly the same
    // statement path through every scheduler.  Any gate that leaks —
    // an extra event, a reordered tie, a perturbed float sum — fails
    // here by scheduler name.
    let reqs = trace();
    for name in SCHEDULERS {
        let base = Experiment::builder()
            .instances(4)
            .scheduler(name)
            .trace(reqs.clone())
            .plan_sample(300)
            .build()
            .expect("base experiment builds")
            .run();
        let none = Experiment::builder()
            .instances(4)
            .scheduler(name)
            .churn("none")
            .trace(reqs.clone())
            .plan_sample(300)
            .build()
            .expect("churn-none experiment builds")
            .run();
        assert_eq!(
            checksum(&base.0),
            checksum(&none.0),
            "{name}: `--churn none` perturbed the report"
        );
        assert_eq!(
            stats_fingerprint(&base.1),
            stats_fingerprint(&none.1),
            "{name}: `--churn none` perturbed the stats"
        );
    }
}

#[test]
fn churn_none_is_bit_identical_for_every_predictor_family() {
    // Same gate along the predictor axis: seed-derived prediction
    // noise must be consumed in exactly the same order with and
    // without an explicit `none` spec.
    let reqs = trace();
    for p in ["oracle", "noisy:0.5", "bucket:0.7", "ltr:0.8"] {
        let build = || {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .predictor(p)
                .trace(reqs.clone())
                .plan_sample(300)
        };
        let (rb, sb) = build().build().expect("base builds").run();
        let (rn, sn) = build().churn("none").build().expect("churn-none builds").run();
        assert_eq!(checksum(&rb), checksum(&rn), "{p}: `--churn none` perturbed the report");
        assert_eq!(
            stats_fingerprint(&sb),
            stats_fingerprint(&sn),
            "{p}: `--churn none` perturbed the stats"
        );
    }
}

#[test]
fn spot_preemption_accounts_for_every_request() {
    // Kill instance 1 mid-decode.  The run must terminate (no wedged
    // evicted sequence) and every request must end up either completed
    // in the report or counted as rejected after the capped readmit
    // retries — nothing silently dropped.
    let reqs = trace();
    let (r, s) = Experiment::builder()
        .instances(4)
        .scheduler("cascade")
        .churn("spot:2.0@1")
        .trace(reqs.clone())
        .plan_sample(300)
        .build()
        .expect("spot experiment builds")
        .run();
    assert_eq!(s.spot_kills, 1, "the scheduled kill must fire");
    assert_eq!(
        r.records.len() as u64 + s.rejected,
        reqs.len() as u64,
        "every request must be completed or rejected ({} records, {} rejected)",
        r.records.len(),
        s.rejected
    );
    assert!(
        s.recovered + s.rejected >= s.preempted_requests,
        "preempted requests must resolve to recovery or rejection \
         ({} preempted, {} recovered, {} rejected)",
        s.preempted_requests,
        s.recovered,
        s.rejected
    );
}

#[test]
fn drain_resolves_gracefully_or_at_the_deadline() {
    // A tight 0.5s drain deadline under load: the instance either
    // empties in time or is forcibly killed — exactly one of the two,
    // and the evacuated work is still fully accounted.
    let reqs = trace();
    let (r, s) = Experiment::builder()
        .instances(4)
        .scheduler("cascade")
        .churn("drain:1.5@2:0.5")
        .trace(reqs.clone())
        .plan_sample(300)
        .build()
        .expect("drain experiment builds")
        .run();
    assert_eq!(s.drains_started, 1);
    assert_eq!(
        s.drains_completed + s.drains_forced,
        1,
        "a started drain must finish empty or be forced at the deadline"
    );
    assert_eq!(r.records.len() as u64 + s.rejected, reqs.len() as u64);
}

#[test]
fn every_churn_kind_is_run_to_run_bit_identical() {
    // A fixed (seed, config, trace, churn-spec) tuple must reproduce
    // bit-for-bit for every fault kind: boot latencies, drain pumps,
    // readmit backoff, and the autoscaler controller are all simulated
    // time, never wall-clock.
    let reqs = trace();
    for kind in CHURN_COVERAGE {
        let spec = churn_instance(kind);
        let run = || {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .churn(spec)
                .trace(reqs.clone())
                .plan_sample(300)
                .build()
                .expect("churn experiment builds")
                .run()
        };
        let (r1, s1) = run();
        let (r2, s2) = run();
        assert_eq!(checksum(&r1), checksum(&r2), "{spec}: report not bit-identical");
        assert_eq!(stats_fingerprint(&s1), stats_fingerprint(&s2), "{spec}: stats diverged");
        assert_eq!(
            r1.records.len() as u64 + s1.rejected,
            reqs.len() as u64,
            "{spec}: requests leaked"
        );
    }
}

#[test]
fn autoscaler_reacts_and_stays_deterministic_under_bursty_load() {
    // Bursty arrivals against a 2..6 fleet with a fast control period:
    // the controller must actually tick, and two identical runs must
    // agree on every scaling decision (watermarked SLO windows and
    // queue depths are pure functions of simulated state).
    let run = || {
        Experiment::builder()
            .instances(4)
            .scheduler("cascade")
            .churn("auto:0.5:2..6")
            .workload_name("bursty")
            .rate(24.0)
            .requests(200)
            .seed(11)
            .plan_sample(300)
            .build()
            .expect("autoscaler experiment builds")
            .run()
    };
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert!(s1.autoscale_ticks > 0, "the controller must tick");
    assert_eq!(checksum(&r1), checksum(&r2), "autoscaled report not bit-identical");
    assert_eq!(
        (s1.autoscale_ticks, s1.scale_outs, s1.scale_ins, s1.joins, s1.drains_started),
        (s2.autoscale_ticks, s2.scale_outs, s2.scale_ins, s2.joins, s2.drains_started),
        "autoscaler decisions diverged between identical runs"
    );
    assert_eq!(r1.records.len() as u64 + s1.rejected, 200);
}

#[test]
fn join_expands_the_fleet_deterministically() {
    // A scale-out join mid-run: the joiner must go live (after its
    // priced boot latency) and absorb work without perturbing
    // determinism.
    let reqs = trace();
    let run = || {
        Experiment::builder()
            .instances(4)
            .scheduler("cascade")
            .churn("join:2.5")
            .trace(reqs.clone())
            .plan_sample(300)
            .build()
            .expect("join experiment builds")
            .run()
    };
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert_eq!(s1.joins, 1, "the scheduled join must complete boot");
    assert_eq!(checksum(&r1), checksum(&r2), "join report not bit-identical");
    assert_eq!(s1.instance_gpus.len(), 5, "the joiner's slot must exist in the fleet");
    assert_eq!(stats_fingerprint(&s1), stats_fingerprint(&s2));
    assert_eq!(r1.records.len(), reqs.len(), "a pure scale-out must not reject work");
}
