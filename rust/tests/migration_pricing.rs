//! Migration pricing on tensor-parallel fleets.
//!
//! A TP-sliced instance holds `1/tp` of the KV heads per rank, so a
//! live migration out of it moves the *sliced* footprint per token,
//! not the full-model one.  These tests pin (a) the footprint table a
//! mixed fleet installs, (b) that the `MigrationManager` prices
//! transfers from the sender's entry, and (c) that a mixed TP2/TP4
//! cluster run exercising the migration path stays bit-identical
//! run-to-run.

use cascade_infer::coordinator::MigrationManager;
use cascade_infer::experiment::Experiment;
use cascade_infer::fleet::FleetSpec;
use cascade_infer::gpu::LinkKind;
use cascade_infer::models::llama_70b;
use cascade_infer::workload::{generate, Request, ShareGptLike};
use cascade_infer::Tokens;

const MIXED_FLEET: &str = "h20:4,tp=2,h20:2,tp=4";

#[test]
fn mixed_fleet_resolves_per_instance_slice_footprints() {
    let fleet = FleetSpec::parse(MIXED_FLEET).unwrap();
    let base = llama_70b(1);
    let footprints: Vec<u64> = fleet
        .instances
        .iter()
        .map(|spec| spec.model_for(&base).kv_bytes_per_token())
        .collect();
    // Four TP2 slices at half the base footprint, two TP4 at a quarter.
    assert_eq!(footprints.len(), 6);
    assert!(footprints[..4].iter().all(|&f| f == base.kv_bytes_per_token() / 2));
    assert!(footprints[4..].iter().all(|&f| f == base.kv_bytes_per_token() / 4));
}

#[test]
fn transfers_are_priced_from_the_senders_slice() {
    let fleet = FleetSpec::parse(MIXED_FLEET).unwrap();
    let base = llama_70b(1);
    let mut mgr = MigrationManager::new(base.kv_bytes_per_token() as f64);
    mgr.set_instance_footprints(
        fleet
            .instances
            .iter()
            .map(|spec| spec.model_for(&base).kv_bytes_per_token() as f64)
            .collect(),
    );
    // Same sequence, same link, disjoint instance pairs (no bandwidth
    // sharing): one transfer out of a TP2 sender, one out of a TP4
    // sender.  decode rate 0 keeps the schedule a single bulk copy.
    let seq: Tokens = 50_000;
    let t_tp2 = mgr.try_start(0.0, 1, 0, 1, seq, LinkKind::NvLink, 0.0, true).unwrap();
    let t_tp4 = mgr.try_start(0.0, 2, 4, 5, seq, LinkKind::NvLink, 0.0, true).unwrap();
    let dur = |t: &cascade_infer::coordinator::Transfer| {
        t.finish_at - t.started_at - LinkKind::NvLink.latency_s()
    };
    assert!(
        dur(&t_tp4) < dur(&t_tp2),
        "a TP4 sender moves half the bytes of a TP2 sender: {} vs {}",
        dur(&t_tp4),
        dur(&t_tp2)
    );
    // The slice footprints are exact powers-of-two fractions, so the
    // bulk-copy durations sit in an exact 2:1 ratio (up to float eps).
    let ratio = dur(&t_tp2) / dur(&t_tp4);
    assert!((ratio - 2.0).abs() < 1e-9, "expected 2:1 pricing ratio, got {ratio}");
}

/// Outputs that straddle the exponential stage boundaries so cascade's
/// outgrown-sequence path actually migrates on the mixed fleet.
fn growing_trace(n: usize) -> Vec<Request> {
    let mut reqs = generate(&ShareGptLike::default(), 20.0, n, 13);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.input_len = 48 + (i % 96) as Tokens;
        r.output_len = 1200 + (i % 7) as Tokens * 550;
    }
    reqs
}

#[test]
fn mixed_tp_fleet_migrations_stay_bit_identical() {
    let reqs = growing_trace(240);
    let run = || {
        Experiment::builder()
            .fleet(MIXED_FLEET)
            .scheduler("cascade")
            .trace(reqs.clone())
            .plan_sample(300)
            .build()
            .expect("mixed-TP experiment builds")
            .run()
    };
    let (r1, s1) = run();
    assert_eq!(r1.records.len(), reqs.len(), "mixed-TP run dropped requests");
    assert_eq!(s1.instance_tp, vec![2, 2, 2, 2, 4, 4]);
    // The slice-priced transfer path must actually run in this
    // scenario, otherwise the determinism claim below is vacuous.
    assert!(s1.migrations > 0, "no migrations — pricing path unexercised");
    assert!(s1.migration_tokens > 0);
    let (r2, s2) = run();
    assert_eq!(r1.fingerprint(), r2.fingerprint(), "mixed-TP report not bit-identical");
    assert_eq!(
        (s1.migrations, s1.migration_tokens, s1.migrations_skipped, s1.preemptions),
        (s2.migrations, s2.migration_tokens, s2.migrations_skipped, s2.preemptions),
        "mixed-TP migration stats diverged"
    );
}
