//! API-redesign regression suite.
//!
//! 1. **Golden-seed compatibility**: every legacy `SchedulerKind`
//!    name, resolved through the policy registry and run through the
//!    `Experiment` builder, must produce a bit-identical `Report` to
//!    the direct `ClusterConfig::new(kind)` + `run_experiment` path.
//!    (For `llumnix` the direct path applies the 1.25 engine speed the
//!    `sim` subcommand always applied — the registry entry carries it.)
//! 2. **Registry round-trip** and **custom-axis parsing** invariants.
//! 3. **End-to-end custom spec**: an axis combination the closed enum
//!    could not express runs from a CLI-style string.

use cascade_infer::cluster::{
    run_experiment, BalancePolicy, ClusterConfig, DispatchPolicy, Layout, PolicySpec,
    RefinePolicy, SchedulerKind,
};
use cascade_infer::experiment::Experiment;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::workload::{generate, Request, ShareGptLike};

fn trace() -> Vec<Request> {
    generate(&ShareGptLike::default(), 18.0, 150, 42)
}

#[test]
fn every_legacy_scheduler_name_is_bit_identical_through_the_builder() {
    let reqs = trace();
    for kind in SchedulerKind::all() {
        let name = kind.registry_name();

        // Direct legacy path, replicating the old `sim` subcommand
        // (which set Llumnix's engine speed explicitly).
        let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, kind);
        if kind == SchedulerKind::LlumnixLike {
            cfg.engine_speed = 1.25;
        }
        let (direct, direct_stats) = run_experiment(cfg, &reqs);

        // Registry + builder path.
        let (built, built_stats) = Experiment::builder()
            .gpu_profile(GpuProfile::H20)
            .model_profile(LLAMA_3B)
            .instances(4)
            .scheduler(name)
            .trace(reqs.clone())
            .build()
            .unwrap()
            .run();

        assert_eq!(direct.records.len(), reqs.len(), "{name} dropped requests");
        assert_eq!(
            direct.fingerprint(),
            built.fingerprint(),
            "{name}: builder/registry path diverged from the legacy path"
        );
        assert_eq!(direct_stats.migrations, built_stats.migrations, "{name}");
        assert_eq!(direct_stats.final_boundaries, built_stats.final_boundaries, "{name}");
    }
}

#[test]
fn registry_round_trips_and_covers_all_legacy_kinds() {
    for &name in PolicySpec::names() {
        let spec = PolicySpec::resolve(name).expect(name);
        assert_eq!(spec.name, name);
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
    }
    for kind in SchedulerKind::all() {
        assert!(
            PolicySpec::names().contains(&kind.registry_name()),
            "{kind:?} missing from the registry"
        );
    }
}

#[test]
fn custom_axis_parsing_accepts_valid_and_rejects_malformed() {
    let spec = PolicySpec::resolve(
        "custom:layout=planned,refine=memory,balance=rrintra,dispatch=stagerouted,gossip=on",
    )
    .unwrap();
    assert_eq!(spec.layout, Layout::Planned);
    assert_eq!(spec.refine, RefinePolicy::Memory);
    assert_eq!(spec.balance, BalancePolicy::RoundRobinIntra);
    assert_eq!(spec.dispatch, DispatchPolicy::StageRouted);
    assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec, "custom name round-trips");

    for bad in [
        "custom:",
        "custom:layout",
        "custom:layout=pyramid",
        "custom:balance=maybe,layout=planned",
        "custom:speed=quick",
        "custom:turbo=on",
    ] {
        assert!(PolicySpec::resolve(bad).is_err(), "`{bad}` must be rejected");
    }
}

#[test]
fn custom_combo_unexpressible_before_runs_end_to_end() {
    // Planned layout + memory-based refinement + round-robin intra
    // dispatch: no legacy SchedulerKind combines these three.
    let (report, stats) = Experiment::builder()
        .gpu_profile(GpuProfile::H20)
        .model_profile(LLAMA_3B)
        .instances(4)
        .scheduler("custom:layout=planned,refine=memory,balance=rrintra")
        .trace(trace())
        .plan_sample(400)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.records.len(), 150);
    assert!(report.mean_ttft() > 0.0);
    assert!(!stats.stages.is_empty());
}

#[test]
fn sjf_dispatch_runs_and_balances() {
    // The new ShortestFirst axis end to end: flat layout, no bid-ask.
    let reqs = generate(&ShareGptLike::default(), 25.0, 200, 7);
    let (report, stats) = Experiment::builder()
        .gpu_profile(GpuProfile::H20)
        .model_profile(LLAMA_3B)
        .instances(4)
        .scheduler("sjf")
        .trace(reqs)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.records.len(), 200);
    assert_eq!(stats.migrations, 0);
    // Work-aware dispatch must touch every instance under load.
    assert_eq!(stats.counters.output_tokens.len(), 4, "{:?}", stats.counters.output_tokens);
}

#[test]
fn homogeneous_fleet_is_bit_identical_for_every_registry_scheduler() {
    // The heterogeneous-fleet refactor must be invisible when the
    // fleet is uniform: `--fleet h20:4` goes through the per-instance
    // spec list, per-instance backends, capacity normalization, and
    // the weighted planner, and must still reproduce the legacy
    // single-GPU path bit for bit — for every registry name (each
    // exercises a different mix of dispatch/balance/layout axes).
    let reqs = trace();
    for &name in PolicySpec::names() {
        let (legacy, legacy_stats) = Experiment::builder()
            .gpu("H20")
            .model_profile(LLAMA_3B)
            .instances(4)
            .scheduler(name)
            .trace(reqs.clone())
            .build()
            .unwrap()
            .run();
        let (fleet, fleet_stats) = Experiment::builder()
            .model_profile(LLAMA_3B)
            .scheduler(name)
            .fleet("h20:4")
            .trace(reqs.clone())
            .build()
            .unwrap()
            .run();
        assert_eq!(fleet.records.len(), reqs.len(), "{name} dropped requests");
        assert_eq!(
            legacy.fingerprint(),
            fleet.fingerprint(),
            "{name}: homogeneous fleet diverged from the legacy single-GPU path"
        );
        assert_eq!(legacy_stats.migrations, fleet_stats.migrations, "{name}");
        assert_eq!(
            legacy_stats.final_boundaries, fleet_stats.final_boundaries,
            "{name}"
        );
        assert_eq!(fleet_stats.instance_gpus, vec!["H20"; 4], "{name}");
        assert!(
            fleet_stats.instance_capacity.iter().all(|&c| c == 1.0),
            "{name}: homogeneous capacities must normalize to exactly 1.0: {:?}",
            fleet_stats.instance_capacity
        );
    }
}

#[test]
fn builder_is_deterministic_across_invocations() {
    let run = || {
        Experiment::builder()
            .gpu_profile(GpuProfile::H20)
            .model_profile(LLAMA_3B)
            .instances(4)
            .scheduler("cascade")
            .rate(15.0)
            .requests(120)
            .seed(9)
            .build()
            .unwrap()
            .run()
            .0
            .fingerprint()
    };
    assert_eq!(run(), run());
}
