//! Randomized equivalence suite for the tiered event queue.
//!
//! [`EventQueue`] spreads events across a front register, a calendar
//! wheel, and a far heap, but its observable contract is exactly a
//! plain binary heap under the total order (timestamp, insertion seq)
//! with past schedules clamped to `now` and two insertion-seq lanes
//! (normal + front class).  These properties drive random interleavings
//! of schedules and pops through the real queue and through a
//! single-`BinaryHeap` reference model, asserting every pop, peek, and
//! length agrees bit for bit — any tier-routing bug (wrong wheel cell,
//! missed far/near comparison, register displacement mistake) shows up
//! as a divergence with a reproducible seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use cascade_infer::sim::{EventQueue, Rng};
use cascade_infer::testutil::for_all;

/// Reference event: the same total order the tiered queue implements,
/// inverted for Rust's max-heap.
#[derive(Debug)]
struct RefEv {
    at: f64,
    seq: u64,
    payload: u64,
}

impl PartialEq for RefEv {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for RefEv {}
impl PartialOrd for RefEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefEv {
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The specification: one flat heap, a monotone clock, past-clamping,
/// and the two seq lanes (front-class seqs start at 0, normal seqs at
/// `1 << 63`, so front-class events win every same-timestamp tie).
#[derive(Debug)]
struct RefQueue {
    heap: BinaryHeap<RefEv>,
    now: f64,
    seq: u64,
    front_seq: u64,
}

impl RefQueue {
    fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 1 << 63, front_seq: 0 }
    }

    fn insert(&mut self, at: f64, seq: u64, payload: u64) {
        let at = if at < self.now { self.now } else { at };
        self.heap.push(RefEv { at, seq, payload });
    }

    fn schedule(&mut self, at: f64, payload: u64) {
        let seq = self.seq;
        self.seq += 1;
        self.insert(at, seq, payload);
    }

    fn schedule_front_class(&mut self, at: f64, payload: u64) {
        let seq = self.front_seq;
        self.front_seq += 1;
        self.insert(at, seq, payload);
    }

    fn pop(&mut self) -> Option<(f64, u64)> {
        let ev = self.heap.pop()?;
        self.now = ev.at;
        Some((ev.at, ev.payload))
    }

    fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Drive `ops` random operations through both queues, with timestamps
/// drawn by `pick_at(rng, now)`; every observable must agree at every
/// step, including a full drain at the end.
fn run_case(rng: &mut Rng, ops: usize, pick_at: impl Fn(&mut Rng, f64) -> f64) {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut r = RefQueue::new();
    let mut payload = 0u64;
    for op in 0..ops {
        assert_eq!(q.len(), r.len(), "len diverged before op {op}");
        assert_eq!(q.peek_time(), r.peek_time(), "peek diverged before op {op}");
        assert_eq!(q.is_empty(), r.len() == 0);
        let do_pop = !q.is_empty() && rng.next_range(5) < 2;
        if do_pop {
            assert_eq!(q.pop(), r.pop(), "pop diverged at op {op}");
            assert_eq!(q.now(), r.now, "clock diverged at op {op}");
        } else {
            let at = pick_at(rng, r.now);
            if rng.next_range(4) == 0 {
                q.schedule_front_class(at, payload);
                r.schedule_front_class(at, payload);
            } else {
                q.schedule(at, payload);
                r.schedule(at, payload);
            }
            payload += 1;
        }
    }
    loop {
        assert_eq!(q.len(), r.len(), "drain len diverged");
        assert_eq!(q.peek_time(), r.peek_time(), "drain peek diverged");
        let (a, b) = (q.pop(), r.pop());
        assert_eq!(a, b, "drain pop diverged");
        if a.is_none() {
            break;
        }
    }
    assert!(q.is_empty());
}

#[test]
fn random_interleavings_match_heap_reference() {
    // Timestamps across every tier: the register (just past now), the
    // wheel (sub-second deltas, quantized so cells collide), the far
    // heap (multi-second), plus past times that clamp to the clock.
    for_all("calendar-vs-heap", 0x5EED_CA1E, 96, |rng| {
        run_case(rng, 400, |rng, now| {
            let scale = match rng.next_range(10) {
                0 => -0.5,    // past: clamps to now
                1..=4 => 0.002, // same/adjacent wheel cells, frequent ties
                5 | 6 => 0.05,  // mid-wheel
                7 => 0.9,       // near the wheel horizon
                8 => 1.5,       // just beyond the horizon: far heap
                _ => 30.0,      // deep future
            };
            now + scale * rng.next_range(8) as f64
        });
    });
}

#[test]
fn same_instant_storms_keep_two_lane_fifo() {
    // Heavy tie pressure: every event lands on one of four quantized
    // instants, so ordering is decided almost entirely by the seq
    // lanes.  Front-class arrivals must beat normal events scheduled
    // earlier at the same instant and stay FIFO among themselves —
    // exactly what the streaming driver's equivalence proof needs.
    for_all("same-instant-two-lane", 0xF1F0_0123, 96, |rng| {
        run_case(rng, 300, |rng, now| {
            let grid = rng.next_range(4) as f64 * 0.25;
            // Round to the grid at or after `now` so ties recur across
            // the whole case, not just at the start.
            (now / 0.25).ceil() * 0.25 + grid
        });
    });
}

#[test]
fn wheel_rotation_and_far_tier_migration_match_reference() {
    // Long sweeps: the clock crosses many full wheel revolutions, so
    // far-heap events become "near" only in pop-comparison terms (the
    // queue never migrates them) and wheel cells are reused many
    // times.  Skewed pop-heavy mix keeps the queue small while time
    // advances far.
    for_all("wheel-rotation", 0xABCD_EF01, 64, |rng| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut r = RefQueue::new();
        let mut payload = 0u64;
        for _ in 0..30 {
            // Burst of schedules spanning ~6 revolutions of a ~1s
            // wheel, then drain most of it.
            for _ in 0..20 {
                let at = r.now + rng.next_f64() * 6.0;
                if rng.next_range(4) == 0 {
                    q.schedule_front_class(at, payload);
                    r.schedule_front_class(at, payload);
                } else {
                    q.schedule(at, payload);
                    r.schedule(at, payload);
                }
                payload += 1;
            }
            for _ in 0..18 {
                assert_eq!(q.pop(), r.pop());
            }
        }
        while let Some(got) = q.pop() {
            assert_eq!(Some(got), r.pop());
        }
        assert_eq!(r.pop(), None);
    });
}

#[test]
fn zero_delta_and_clamped_past_events_fire_now_in_lane_order() {
    // Deterministic micro-case on top of the random sweeps: after the
    // clock has advanced, zero-delta and past schedules all collapse
    // onto `now` and pop in (lane, insertion) order.
    let mut q: EventQueue<&str> = EventQueue::new();
    q.schedule(1.0, "tick");
    assert_eq!(q.pop(), Some((1.0, "tick")));
    q.schedule(1.0, "n0"); // zero delta, normal lane
    q.schedule(0.2, "n1"); // past: clamps to 1.0
    q.schedule_front_class(0.5, "f0"); // past clamp, front lane
    q.schedule(1.0, "n2");
    q.schedule_front_class(1.0, "f1");
    let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
    assert_eq!(order, vec!["f0", "f1", "n0", "n1", "n2"]);
    assert_eq!(q.now(), 1.0);
}
