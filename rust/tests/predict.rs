//! Length-prediction subsystem integration gates.
//!
//! Three guarantees, in order of importance:
//!
//! 1. **Oracle is the legacy simulator, bit for bit**: for *every*
//!    registry scheduler, an explicit `--predictor oracle` produces a
//!    `Report::fingerprint()` identical to a build that never mentions
//!    predictors, with every misprediction/recovery counter at zero.
//! 2. **Imperfect predictors are wired in**: a noisy predictor changes
//!    routing (different fingerprints), under-predicted sequences
//!    re-route via live migration exactly once per request, and
//!    rank-only (`ltr`) admission escalates deterministically when the
//!    true length can never fit the routed KV pool.
//! 3. **QoE robustness**: cascade's SLO attainment degrades as noisy
//!    prediction error grows, while the recovery counters stay nonzero
//!    — the committed shape of the predictor-accuracy sweep.

use cascade_infer::cluster::PolicySpec;
use cascade_infer::experiment::Experiment;
use cascade_infer::metrics::Slo;
use cascade_infer::workload::{generate, Request, ShareGptLike};
use cascade_infer::Tokens;

const SLO: Slo = Slo { ttft: 1.0, tpot: 0.1 };

#[test]
fn oracle_is_fingerprint_identical_to_the_default_for_every_scheduler() {
    let reqs = generate(&ShareGptLike::default(), 20.0, 150, 7);
    for &name in PolicySpec::names() {
        let build = |predictor: Option<&str>| {
            let mut b = Experiment::builder()
                .instances(4)
                .scheduler(name)
                .trace(reqs.clone())
                .plan_sample(300);
            if let Some(p) = predictor {
                b = b.predictor(p);
            }
            b.build().expect("experiment builds").run()
        };
        let (r_default, s_default) = build(None);
        let (r_oracle, s_oracle) = build(Some("oracle"));
        assert_eq!(
            r_default.fingerprint(),
            r_oracle.fingerprint(),
            "{name}: explicit oracle diverged from the predictor-less default"
        );
        for (label, s) in [("default", &s_default), ("oracle", &s_oracle)] {
            assert_eq!(s.mispredictions, 0, "{name}/{label}: oracle cannot mispredict");
            assert_eq!(s.predict_reroutes, 0, "{name}/{label}: oracle cannot re-route");
            assert_eq!(s.predict_escalations, 0, "{name}/{label}: oracle cannot escalate");
        }
    }
}

#[test]
fn noisy_prediction_actually_reshapes_the_run() {
    // Non-vacuity for everything else in this file: if the predictor
    // were computed but never consulted, oracle and noisy fingerprints
    // would match and the gates above would pass trivially.
    let run = |p: &str| {
        Experiment::builder()
            .instances(8)
            .scheduler("cascade")
            .predictor(p)
            .workload_name("heavytail")
            .rate(24.0)
            .requests(300)
            .seed(42)
            .plan_sample(400)
            .build()
            .expect("experiment builds")
            .run()
    };
    let (r_oracle, _) = run("oracle");
    let (r_noisy, s_noisy) = run("noisy:0.5");
    assert_ne!(
        r_oracle.fingerprint(),
        r_noisy.fingerprint(),
        "noisy:0.5 must change scheduling decisions"
    );
    assert!(s_noisy.mispredictions > 0, "lognormal error must under-predict sometimes");
}

/// Short prompts with outputs that straddle the exponential stage
/// boundaries (2048/4096), so under-predicted sequences outgrow the
/// stage the predictor routed them to.
fn growing_trace(n: usize) -> Vec<Request> {
    let mut reqs = generate(&ShareGptLike::default(), 20.0, n, 9);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.input_len = 48 + (i % 96) as Tokens;
        r.output_len = 1200 + (i % 7) as Tokens * 550;
    }
    reqs
}

#[test]
fn underpredicted_sequences_reroute_once_per_request() {
    let reqs = growing_trace(300);
    let run = || {
        Experiment::builder()
            .instances(8)
            .scheduler("cascade")
            .predictor("noisy:0.5")
            .trace(reqs.clone())
            .plan_sample(300)
            .build()
            .expect("experiment builds")
            .run()
    };
    let (r1, s1) = run();
    assert!(s1.predict_reroutes > 0, "no under-predicted sequence ever re-routed");
    // Once per request: every re-routed request is, by construction,
    // also a misprediction at completion (its length passed the
    // predicted final), so double-counting a request would break this
    // inequality.
    assert!(
        s1.predict_reroutes <= s1.mispredictions,
        "re-routes ({}) exceed mispredictions ({}) — a request was counted twice",
        s1.predict_reroutes,
        s1.mispredictions
    );
    assert!(s1.predict_reroutes as usize <= r1.records.len());
    // And the recovery path is itself deterministic.
    let (r2, s2) = run();
    assert_eq!(r1.fingerprint(), r2.fingerprint());
    assert_eq!(
        (s1.predict_reroutes, s1.mispredictions, s1.migrations),
        (s2.predict_reroutes, s2.mispredictions, s2.migrations)
    );
}

#[test]
fn ltr_admission_escalates_deterministically_on_oversized_requests() {
    // 70B on TP2 H100 slices pools only ~28K KV tokens per instance,
    // so a 60K-token final can never be admitted.  `ltr` has no
    // absolute length — admission checks the prompt — so the oversized
    // requests slip the predicted check and must escalate through the
    // admission-reject recovery path instead of wedging an instance.
    let mut reqs = generate(&ShareGptLike::uniform_short(), 10.0, 60, 3);
    let oversized = 6;
    for r in reqs.iter_mut().take(oversized) {
        r.input_len = 64;
        r.output_len = 60_000;
    }
    let run = |p: &str| {
        Experiment::builder()
            .fleet("h100:2,tp=2")
            .model("llama70b")
            .scheduler("cascade")
            .predictor(p)
            .trace(reqs.clone())
            .plan_sample(200)
            .build()
            .expect("experiment builds")
            .run()
    };
    let (r_oracle, s_oracle) = run("oracle");
    assert_eq!(s_oracle.rejected, oversized as u64, "oracle rejects oversized at admission");
    assert_eq!(s_oracle.predict_escalations, 0);
    assert_eq!(r_oracle.records.len() + s_oracle.rejected as usize, reqs.len());

    let (r_ltr, s_ltr) = run("ltr:0.8");
    assert_eq!(
        s_ltr.rejected, s_oracle.rejected,
        "ltr must reject exactly the requests whose true length can never fit"
    );
    assert_eq!(
        s_ltr.predict_escalations, s_ltr.rejected,
        "every ltr rejection here is an under-prediction escalation"
    );
    assert_eq!(r_ltr.records.len() + s_ltr.rejected as usize, reqs.len());
    // Deterministic escalation: bit-identical on a re-run.
    let (r_ltr2, s_ltr2) = run("ltr:0.8");
    assert_eq!(r_ltr.fingerprint(), r_ltr2.fingerprint());
    assert_eq!(s_ltr.predict_escalations, s_ltr2.predict_escalations);
}

#[test]
fn cascade_qoe_degrades_as_noisy_cv_grows_while_recovery_stays_active() {
    // The committed robustness result behind
    // `sweep --predictors "oracle;noisy:0.2;noisy:0.5;bucket:0.7;ltr:0.8"`:
    // prediction error costs QoE, and the mid-flight recovery machinery
    // (re-routes) keeps running rather than silently absorbing it.
    let run = |p: &str| {
        Experiment::builder()
            .instances(8)
            .scheduler("cascade")
            .predictor(p)
            .workload_name("heavytail")
            .rate(24.0)
            .requests(400)
            .seed(42)
            .plan_sample(400)
            .build()
            .expect("experiment builds")
            .run()
    };
    let (r_oracle, _) = run("oracle");
    let slo_oracle = r_oracle.slo_attainment(SLO);

    let mut slos = Vec::new();
    for cv in ["noisy:0.2", "noisy:0.5", "noisy:0.8"] {
        let (r, s) = run(cv);
        let slo = r.slo_attainment(SLO);
        // Tolerance absorbs small nonmonotone wiggles from discrete
        // re-planning; the trend is the claim.
        assert!(
            slo <= slo_oracle + 0.03,
            "{cv}: SLO {slo:.3} materially beats the oracle's {slo_oracle:.3}"
        );
        assert!(s.mispredictions > 0, "{cv}: no mispredictions recorded");
        if cv != "noisy:0.2" {
            assert!(s.predict_reroutes > 0, "{cv}: recovery re-routes went silent");
        }
        slos.push(slo);
    }
    assert!(
        slos[2] <= slos[0] + 0.03,
        "QoE must trend down as CV grows: slos {slos:?} vs oracle {slo_oracle:.3}"
    );
}
