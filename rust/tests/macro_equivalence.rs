//! Macro-vs-micro driver equivalence — the macro-step hard gate.
//!
//! The macro-stepped simulation core (inline iteration advancement
//! between interesting events, `Engine::run_until` stretches, the
//! event-queue front register) must be a pure *traversal* change:
//! every registry scheduler on every workload family must produce a
//! bit-identical seeded `Report` (and run stats) against the retained
//! `--micro-step` one-event-per-iteration debug path.  A property test
//! additionally interleaves arrivals and periodic timers so macro
//! horizons land on, just before, and just after completion instants.

use cascade_infer::cluster::{Cluster, ClusterConfig, PolicySpec, RunStats, SchedulerKind};
use cascade_infer::experiment::Experiment;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::metrics::Report;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::predict;
use cascade_infer::sim::Rng;
use cascade_infer::testutil::for_all;
use cascade_infer::workload::{Request, WorkloadSpec};
use cascade_infer::Tokens;

/// Macro-equivalence coverage list, cross-referenced against the
/// `PolicySpec` registry by detlint rule D4 (and the assertion test
/// below).  `every_registry_scheduler_is_macro_micro_identical`
/// iterates `PolicySpec::names()` directly, so coverage is live; the
/// literal list exists so the static pass can prove it without
/// executing tests.
const REGISTRY_COVERAGE: [&str; 11] = [
    "cascade",
    "vllm",
    "sglang",
    "llumnix",
    "chain",
    "nopipeline",
    "quantity",
    "memory",
    "interstage",
    "rrintra",
    "sjf",
];

#[test]
fn registry_coverage_list_matches_registry() {
    assert_eq!(
        REGISTRY_COVERAGE.as_slice(),
        PolicySpec::names(),
        "REGISTRY_COVERAGE must mirror the PolicySpec registry exactly \
         (detlint rule D4 cross-references the literals)"
    );
}

/// Predictor-family coverage, cross-referenced against the
/// `predict::names()` registry by detlint rule D4; exercised by
/// `every_registry_predictor_is_macro_micro_identical`.
const PREDICTOR_COVERAGE: [&str; 4] = ["oracle", "noisy", "bucket", "ltr"];

#[test]
fn predictor_coverage_list_matches_registry() {
    assert_eq!(
        PREDICTOR_COVERAGE,
        predict::names(),
        "PREDICTOR_COVERAGE must mirror the predict::names() registry \
         exactly (detlint rule D4 cross-references the literals)"
    );
}

#[test]
fn every_registry_predictor_is_macro_micro_identical() {
    // Prediction reshapes routing, admission, and replanning, but it
    // must stay a *decision* change: the macro-stepped driver and the
    // one-event-per-iteration debug path still see identical decisions,
    // so reports and stats stay bit-identical under every predictor —
    // including the misprediction re-route/escalation recovery paths.
    let wl = WorkloadSpec::parse("heavytail").unwrap();
    for p in ["oracle", "noisy:0.5", "bucket:0.7", "ltr:0.8"] {
        let build = |micro: bool| {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .predictor(p)
                .workload(wl.clone())
                .rate(12.0)
                .requests(140)
                .seed(11)
                .plan_sample(400)
                .micro_step(micro)
                .build()
                .expect("predictor equivalence experiment builds")
                .run()
        };
        let (r_macro, s_macro) = build(false);
        let (r_micro, s_micro) = build(true);
        assert_eq!(
            observables(&r_macro, &s_macro),
            observables(&r_micro, &s_micro),
            "predictor {p}: macro and micro drivers diverged"
        );
        assert_eq!(
            (s_macro.mispredictions, s_macro.predict_reroutes, s_macro.predict_escalations),
            (s_micro.mispredictions, s_micro.predict_reroutes, s_micro.predict_escalations),
            "predictor {p}: recovery counters diverged"
        );
    }
}

/// Everything a run exposes, flattened to a comparable value.
fn observables(report: &Report, stats: &RunStats) -> (u64, usize, Vec<u64>, Vec<Tokens>, usize) {
    (
        report.fingerprint(),
        report.records.len(),
        vec![
            stats.migrations,
            stats.migration_tokens,
            stats.migrations_skipped,
            stats.preemptions,
            stats.refinements,
            stats.engine_iterations,
        ],
        stats.final_boundaries.clone(),
        stats.batch_snapshots.len(),
    )
}

fn run(
    scheduler: &str,
    workload: &WorkloadSpec,
    rate: f64,
    requests: usize,
    seed: u64,
    micro: bool,
) -> (Report, RunStats) {
    Experiment::builder()
        .instances(4)
        .scheduler(scheduler)
        .workload(workload.clone())
        .rate(rate)
        .requests(requests)
        .seed(seed)
        .plan_sample(400)
        .micro_step(micro)
        .build()
        .expect("equivalence experiment builds")
        .run()
}

#[test]
fn every_registry_scheduler_is_macro_micro_identical() {
    let workloads: Vec<(&str, WorkloadSpec, f64)> = vec![
        ("sharegpt", WorkloadSpec::parse("sharegpt").unwrap(), 18.0),
        ("heavytail", WorkloadSpec::parse("heavytail").unwrap(), 12.0),
        ("bursty", WorkloadSpec::parse("bursty").unwrap(), 18.0),
    ];
    for &name in PolicySpec::names() {
        for (wl_name, wl, rate) in &workloads {
            let (r_macro, s_macro) = run(name, wl, *rate, 140, 11, false);
            let (r_micro, s_micro) = run(name, wl, *rate, 140, 11, true);
            assert_eq!(
                observables(&r_macro, &s_macro),
                observables(&r_micro, &s_micro),
                "{name} on {wl_name}: macro and micro drivers diverged"
            );
            // The mark-triggered batch snapshots must match exactly,
            // not just in count — per-iteration sampling near marks is
            // the subtlest part of the macro gating.
            assert_eq!(
                s_macro.batch_snapshots, s_micro.batch_snapshots,
                "{name} on {wl_name}: snapshot marks diverged"
            );
            assert_eq!(
                s_macro.mean_token_load, s_micro.mean_token_load,
                "{name} on {wl_name}: gossip-sampled load diverged"
            );
        }
    }
}

#[test]
fn tp_fleet_scenarios_stay_macro_micro_identical() {
    // The TP axis adds per-instance model slices, TP-derived KV
    // pools, collective-inclusive iteration costs, and the TP-aware
    // DP — all of it must remain a pure cost-model/planning change
    // with zero effect on driver traversal equivalence.  Cover a
    // bid-ask policy (per-iteration hooks) and a macro-stretch policy
    // (no hooks) on mixed-TP fleets.
    for (scheduler, fleet) in
        [("cascade", "h20:2,h20:2,tp=4"), ("sjf", "h20:4,tp=2,h20:2,tp=4")]
    {
        let build = |micro: bool| {
            Experiment::builder()
                .scheduler(scheduler)
                .fleet(fleet)
                .workload(WorkloadSpec::parse("heavytail").unwrap())
                .rate(12.0)
                .requests(120)
                .seed(11)
                .plan_sample(400)
                .micro_step(micro)
                .build()
                .expect("tp equivalence experiment builds")
                .run()
        };
        let (r_macro, s_macro) = build(false);
        let (r_micro, s_micro) = build(true);
        assert_eq!(r_macro.records.len(), 120, "{scheduler} on {fleet} dropped requests");
        assert_eq!(
            observables(&r_macro, &s_macro),
            observables(&r_micro, &s_micro),
            "{scheduler} on {fleet}: macro and micro drivers diverged"
        );
        assert_eq!(
            s_macro.batch_snapshots, s_micro.batch_snapshots,
            "{scheduler} on {fleet}: snapshot marks diverged"
        );
        assert_eq!(
            s_macro.mean_token_load, s_micro.mean_token_load,
            "{scheduler} on {fleet}: gossip-sampled load diverged"
        );
        assert_eq!(s_macro.instance_tp, s_micro.instance_tp);
    }
}

#[test]
fn randomized_horizon_interleavings_stay_identical() {
    // Random rates and refine/replan-interval jitter move the periodic
    // timers (and therefore macro horizons) onto, before, and after
    // completion instants; every draw must stay bit-identical.
    let schedulers = ["cascade", "vllm", "llumnix", "sjf", "rrintra"];
    for_all("macro-horizon-interleavings", 0xCAFE, 8, |rng: &mut Rng| {
        let scheduler = schedulers[rng.next_range(schedulers.len() as u64) as usize];
        let rate = 6.0 + rng.next_range(30) as f64;
        let seed = rng.next_range(1 << 20);
        let refine = 0.3 + rng.next_range(40) as f64 * 0.1;
        let build = |micro: bool| {
            Experiment::builder()
                .instances(4)
                .scheduler(scheduler)
                .rate(rate)
                .requests(90)
                .seed(seed)
                .plan_sample(300)
                .refine_interval(refine)
                .micro_step(micro)
                .build()
                .unwrap()
                .run()
        };
        let (r_macro, s_macro) = build(false);
        let (r_micro, s_micro) = build(true);
        assert_eq!(
            observables(&r_macro, &s_macro),
            observables(&r_micro, &s_micro),
            "{scheduler} rate {rate} seed {seed} refine {refine} diverged"
        );
    });
}

#[test]
fn streaming_driver_is_macro_micro_identical_across_workload_families() {
    // Three drivers over the same spec — materialized macro, streaming
    // macro, streaming micro-step — must all agree, transitively
    // pinning the streaming path to the one-event-per-iteration
    // reference.  Workload families cover every generator stream
    // variant (plain Poisson, bursty phase loop, mixture draws).
    let workloads = [("sharegpt", 18.0), ("heavytail", 12.0), ("bursty", 18.0), ("mix", 14.0)];
    for scheduler in ["cascade", "vllm", "sjf"] {
        for (wl, rate) in workloads {
            let build = |stream: bool, micro: bool| -> (Report, RunStats) {
                let b = Experiment::builder()
                    .instances(4)
                    .scheduler(scheduler)
                    .workload_name(wl)
                    .rate(rate)
                    .requests(120)
                    .seed(11)
                    .plan_sample(400)
                    .micro_step(micro);
                if stream {
                    b.build_streaming()
                        .expect("streaming experiment builds")
                        .run()
                        .expect("streaming run succeeds")
                } else {
                    b.build().expect("experiment builds").run()
                }
            };
            let (r_base, s_base) = build(false, false);
            for (stream, micro) in [(true, false), (true, true)] {
                let (r, s) = build(stream, micro);
                assert_eq!(
                    observables(&r_base, &s_base),
                    observables(&r, &s),
                    "{scheduler} on {wl}: streaming (micro={micro}) diverged"
                );
                assert_eq!(
                    s_base.batch_snapshots, s.batch_snapshots,
                    "{scheduler} on {wl}: streaming snapshot marks diverged"
                );
                assert_eq!(
                    s_base.mean_token_load, s.mean_token_load,
                    "{scheduler} on {wl}: streaming gossip-sampled load diverged"
                );
            }
        }
    }
}

#[test]
fn streaming_replay_of_tie_arrivals_matches_materialized() {
    // The `run_stream` counterpart of the adversarial tie test below:
    // inject arrivals at exact completion instants (± 1 ns), stable-
    // sort by arrival (preserving same-instant trace order, which is
    // what the front-class seq lane reproduces), and replay the sorted
    // trace both materialized and as a lazy iterator straight into the
    // cluster driver.
    let base = Experiment::builder()
        .instances(4)
        .scheduler("cascade")
        .rate(20.0)
        .requests(80)
        .seed(5)
        .plan_sample(200)
        .build()
        .unwrap();
    let (first, _) = base.clone().run();
    let mut reqs = base.requests.clone();
    let mut id = 20_000u64;
    for rec in first.records.iter().take(24) {
        for arrival in [rec.completion, rec.completion - 1e-9, rec.completion + 1e-9] {
            reqs.push(Request {
                id,
                arrival: arrival.max(0.0),
                input_len: 64 + id % 512,
                output_len: 16 + id % 64,
            });
            id += 1;
        }
    }
    reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));

    let cfg = || {
        let mut c = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, SchedulerKind::Cascade);
        c.plan_sample = 200;
        c
    };
    let (r_mat, s_mat) = Cluster::new(cfg(), &reqs).run(&reqs);
    let (r_str, s_str) = Cluster::new(cfg(), &reqs).run_stream(reqs.iter().copied(), reqs.len());
    assert_eq!(r_mat.records.len(), reqs.len());
    assert_eq!(
        observables(&r_mat, &s_mat),
        observables(&r_str, &s_str),
        "tie-arrival streaming replay diverged from the materialized driver"
    );
}

#[test]
fn arrivals_at_exact_completion_instants_stay_identical() {
    // Adversarial tie construction: take completion timestamps from a
    // first run and inject new arrivals at *exactly* those instants
    // (plus one just before and one just after), so the macro horizon
    // logic faces `end == next event` ties that FIFO order must
    // resolve identically to the event-queue path.
    let base = Experiment::builder()
        .instances(4)
        .scheduler("cascade")
        .rate(20.0)
        .requests(80)
        .seed(5)
        .plan_sample(200)
        .build()
        .unwrap();
    let (first, _) = base.clone().run();

    let mut reqs = base.requests.clone();
    let mut id = 10_000u64;
    for rec in first.records.iter().take(24) {
        for arrival in [rec.completion, rec.completion - 1e-9, rec.completion + 1e-9] {
            reqs.push(Request {
                id,
                arrival: arrival.max(0.0),
                input_len: 64 + id % 512,
                output_len: 16 + id % 64,
            });
            id += 1;
        }
    }

    let run_trace = |micro: bool| {
        Experiment::builder()
            .instances(4)
            .scheduler("cascade")
            .plan_sample(200)
            .trace(reqs.clone())
            .micro_step(micro)
            .build()
            .unwrap()
            .run()
    };
    let (r_macro, s_macro) = run_trace(false);
    let (r_micro, s_micro) = run_trace(true);
    assert_eq!(r_macro.records.len(), reqs.len());
    assert_eq!(
        observables(&r_macro, &s_macro),
        observables(&r_micro, &s_micro),
        "tie-arrival trace diverged between macro and micro drivers"
    );
}
