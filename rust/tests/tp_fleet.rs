//! Tensor-parallel fleet suite — the TP-axis hard gates.
//!
//! 1. **Bit-identity**: a `tp=1` fleet must reproduce the legacy
//!    no-TP path fingerprint-for-fingerprint across every registry
//!    scheduler — the TP refactor threads per-instance model slices,
//!    TP-derived KV pools, and the TP-aware DP through construction,
//!    and all of it must be invisible when nothing shards.
//! 2. **Mixed-TP acceptance**: on a `tp=2 x4 + tp=4 x4` 70B fleet
//!    under heavytail, the TP4 slices own the longest stage and carry
//!    the top token-load share.
//! 3. **Randomized DP properties**: on random histograms and fleets,
//!    `plan_dp_instances` never beats (and matches) the exhaustive
//!    reference partition, predicted quality degrades monotonically
//!    as TP communication cost grows, and per-stage capacities stay
//!    positive.

use cascade_infer::cluster::PolicySpec;
use cascade_infer::coordinator::plan::{MigrationCost, PlanInstance, Planner};
use cascade_infer::experiment::Experiment;
use cascade_infer::fleet::InstanceSpec;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::{llama_70b, LLAMA_3B};
use cascade_infer::qoe::QoeModel;
use cascade_infer::sim::Rng;
use cascade_infer::testutil::for_all;
use cascade_infer::workload::{generate, LengthHistogram, Request, ShareGptLike};

fn heavytail(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    generate(&ShareGptLike::heavy_tail(), rate, n, seed)
}

// ---------------------------------------------------------------- 1.

#[test]
fn tp1_fleet_is_bit_identical_to_legacy_for_every_registry_scheduler() {
    // `tp=1` spelled explicitly must take the exact legacy code paths:
    // same resolved model, same KV derivation, same planner entry
    // point — enforced per registry name because each exercises a
    // different mix of layout/dispatch/balance axes.
    let reqs = generate(&ShareGptLike::default(), 18.0, 150, 42);
    for &name in PolicySpec::names() {
        let (legacy, legacy_stats) = Experiment::builder()
            .gpu("H20")
            .model_profile(LLAMA_3B)
            .instances(4)
            .scheduler(name)
            .trace(reqs.clone())
            .build()
            .unwrap()
            .run();
        let (tp, tp_stats) = Experiment::builder()
            .model_profile(LLAMA_3B)
            .scheduler(name)
            .fleet("h20:4,tp=1")
            .trace(reqs.clone())
            .build()
            .unwrap()
            .run();
        assert_eq!(tp.records.len(), reqs.len(), "{name} dropped requests");
        assert_eq!(
            legacy.fingerprint(),
            tp.fingerprint(),
            "{name}: tp=1 fleet diverged from the legacy no-TP path"
        );
        assert_eq!(legacy_stats.migrations, tp_stats.migrations, "{name}");
        assert_eq!(legacy_stats.final_boundaries, tp_stats.final_boundaries, "{name}");
        assert_eq!(legacy_stats.preemptions, tp_stats.preemptions, "{name}");
        assert_eq!(tp_stats.instance_tp, vec![1; 4], "{name}");
    }
}

// ---------------------------------------------------------------- 2.

/// Mean of a per-instance statistic over instances with TP degree `tp`.
fn mean_for_tp(values: &[f64], tps: &[u32], tp: u32) -> f64 {
    let mut sum = 0.0;
    let mut n = 0.0;
    for (v, t) in values.iter().zip(tps.iter()) {
        if *t == tp {
            sum += *v;
            n += 1.0;
        }
    }
    assert!(n > 0.0, "no tp={tp} instances in {tps:?}");
    sum / n
}

#[test]
fn mixed_tp_70b_fleet_long_stage_lands_on_tp4_slices() {
    // The scenario the repo could not express before: a 70B model on
    // single-GPU-memory instances, servable only as TP slices.  The
    // TP4 slices are roughly twice as fast as the TP2 slices (per-GPU
    // weight/KV traffic shrink 2x more, minus the bigger all-reduce
    // ring), so the TP-aware DP must plan the long-sequence end of
    // the pipeline onto them, and the steady-state token load must
    // concentrate there.
    let reqs = heavytail(300, 12.0, 17);
    let (report, stats) = Experiment::builder()
        .model_profile(llama_70b(1))
        .scheduler("cascade")
        .fleet("h20:4,tp=2,h20:4,tp=4")
        .trace(reqs.clone())
        .plan_sample(300)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.records.len(), reqs.len(), "mixed-TP fleet dropped requests");
    assert_eq!(stats.instance_tp, vec![2, 2, 2, 2, 4, 4, 4, 4]);
    assert!(stats.stages.len() > 1, "expected a pipeline: {:?}", stats.stages);
    // TP4 capacity outranks TP2 (sublinearly — the ring premium).
    let cap2 = mean_for_tp(&stats.instance_capacity, &stats.instance_tp, 2);
    let cap4 = mean_for_tp(&stats.instance_capacity, &stats.instance_tp, 4);
    assert!(cap4 > cap2, "tp4 capacity {cap4} must outrank tp2 {cap2}");
    // The longest stage is owned by TP4 slices only.
    let last = stats.stages.last().unwrap();
    assert!(
        last.iter().all(|&i| stats.instance_tp[i] == 4),
        "long stage members {last:?} must all be tp4 (tps {:?}, stages {:?})",
        stats.instance_tp,
        stats.stages
    );
    // ...and they carry the top steady-state token-load share.
    assert_eq!(stats.mean_token_load.len(), 8, "cascade gossips, so load is sampled");
    let load2 = mean_for_tp(&stats.mean_token_load, &stats.instance_tp, 2);
    let load4 = mean_for_tp(&stats.mean_token_load, &stats.instance_tp, 4);
    assert!(
        load4 > load2,
        "tp4 mean token load ({load4:.0}) should exceed tp2's ({load2:.0}); \
         loads {:?}",
        stats.mean_token_load
    );
}

#[test]
fn mixed_tp_run_is_deterministic() {
    let reqs = heavytail(150, 10.0, 23);
    let run = || {
        Experiment::builder()
            .model_profile(llama_70b(1))
            .scheduler("cascade")
            .fleet("h20:2,tp=2,h20:2,tp=4")
            .trace(reqs.clone())
            .plan_sample(150)
            .build()
            .unwrap()
            .run()
            .0
            .fingerprint()
    };
    assert_eq!(run(), run());
}

#[test]
fn tp_slicing_multiplies_derived_kv_headroom() {
    // A TP4 slice splits both weights and per-token KV across 4 GPUs:
    // from the same device memory its per-instance pool must derive
    // *more* than 4x the tokens (weights shrink too).
    let base = llama_70b(2);
    let gpu = GpuProfile::H20;
    let kv_tokens = |spec: InstanceSpec| {
        let m = spec.model_for(&base);
        m.kv_capacity_tokens(m.kv_budget_bytes(gpu.mem_bytes, 0.9))
    };
    let t2 = kv_tokens(InstanceSpec::new(gpu));
    let t4 = kv_tokens(InstanceSpec::new(gpu).with_tp(4));
    assert!(t2 > 131_072, "a TP2 70B slice must hold full-context KV on an H20: {t2}");
    assert!(t4 > 2 * t2, "tp4 pool {t4} must more-than-double the tp2 pool {t2}");
}

// ---------------------------------------------------------------- 3.

/// A QoE model shaped like real fits (same coefficients as the plan.rs
/// unit suite).
fn qoe() -> QoeModel {
    QoeModel::new([5e-3, 2e-4, 1e-6, 1e-11, 2e-6])
}

/// Random small histogram over exponential-ish bounds.
fn random_hist(rng: &mut Rng) -> LengthHistogram {
    let all_bounds: [u64; 6] = [512, 2048, 8192, 32_768, 65_536, 131_072];
    let k = 2 + rng.next_range(4) as usize; // 2..=5 buckets
    let bounds: Vec<u64> = all_bounds[all_bounds.len() - k..].to_vec();
    let mut h = LengthHistogram::new(bounds);
    let n = 30 + rng.next_range(200);
    for _ in 0..n {
        let input = 1 + rng.next_range(100_000);
        let output = 1 + rng.next_range(4_000);
        h.push(input, (input + output).min(131_072));
    }
    h
}

/// Random small TP fleet: 2..=4 instances with mixed caps, KV pools,
/// and collective premiums.
fn random_insts(rng: &mut Rng) -> Vec<PlanInstance> {
    let e = 2 + rng.next_range(3) as usize;
    (0..e)
        .map(|_| PlanInstance {
            cap: 0.3 + rng.next_f64() * 1.7,
            kv_tokens: match rng.next_range(4) {
                0 => 2_000.0,
                1 => 50_000.0,
                2 => 1.0e9,
                _ => f64::INFINITY,
            },
            comm_s_per_token: if rng.next_range(2) == 0 {
                0.0
            } else {
                rng.next_f64() * 1e-4
            },
        })
        .collect()
}

#[test]
fn tp_dp_matches_and_never_beats_the_exhaustive_reference() {
    for_all("tp-dp-vs-exhaustive", 0x7B4, 32, |rng: &mut Rng| {
        let h = random_hist(rng);
        let insts = random_insts(rng);
        let p = Planner::new(qoe(), MigrationCost::free());
        let dp = p.plan_dp_instances(&h, &insts);
        let ex = p.plan_exhaustive_instances(&h, &insts);
        let tol = 1e-9 * dp.predicted_quality.abs().max(1.0);
        // Optimality, both directions: the DP can never beat a true
        // exhaustive optimum, and being exact it cannot lose to it
        // either.
        assert!(
            dp.predicted_quality >= ex.predicted_quality - tol,
            "DP {} beats the exhaustive optimum {} on {insts:?}",
            dp.predicted_quality,
            ex.predicted_quality
        );
        assert!(
            dp.predicted_quality <= ex.predicted_quality + tol,
            "DP {} lost to the exhaustive optimum {} on {insts:?}",
            dp.predicted_quality,
            ex.predicted_quality
        );
        // Structural invariants: every instance owned, contiguous
        // ascending ranges, positive per-stage capacity.
        assert_eq!(dp.total_instances(), insts.len());
        let mut start = 0usize;
        for (i, s) in dp.stages.iter().enumerate() {
            assert!(s.n_instances >= 1);
            let members = &insts[start..start + s.n_instances];
            let cap_sum: f64 = members.iter().map(|m| m.cap).sum();
            assert!(
                cap_sum > 0.0 && cap_sum.is_finite(),
                "stage {i} capacity {cap_sum} must stay positive"
            );
            start += s.n_instances;
            if i > 0 {
                assert_eq!(dp.stages[i - 1].hi, s.lo);
            }
            assert!(s.lo < s.hi, "{:?}", dp.stages);
        }
    });
}

#[test]
fn tp_dp_quality_degrades_monotonically_in_comm_cost_randomized() {
    for_all("tp-dp-comm-monotone", 0xC0111, 16, |rng: &mut Rng| {
        let h = random_hist(rng);
        let base = random_insts(rng);
        let p = Planner::new(qoe(), MigrationCost::free());
        let mut last = f64::NEG_INFINITY;
        for scale in [0.0, 0.5, 1.0, 2.0, 8.0] {
            let insts: Vec<PlanInstance> = base
                .iter()
                .map(|i| PlanInstance {
                    comm_s_per_token: i.comm_s_per_token * scale,
                    ..*i
                })
                .collect();
            let q = p.plan_dp_instances(&h, &insts).predicted_quality;
            assert!(q.is_finite(), "{insts:?}");
            assert!(
                q >= last - 1e-12,
                "quality improved as comm grew: {q} after {last} at scale {scale}"
            );
            last = q;
        }
    });
}
