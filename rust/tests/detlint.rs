//! detlint self-coverage: each rule D1–D4 must fire on its seeded
//! fixture (`tests/lint_fixtures/`), the allow grammar must suppress
//! (and reject malformed annotations), and the live tree must be
//! lint-clean with every allow annotation earning its keep.

use cascade_infer::lint::{check_crate, check_registry_coverage, check_source, sim_scoped, Rule};
use std::path::Path;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

#[test]
fn d1_fixture_flags_iteration_sites() {
    let rep = check_source("cluster/fixture.rs", &fixture("d1_hashmap_iter.rs"));
    assert_eq!(rep.findings.len(), 2, "{:#?}", rep.findings);
    assert!(rep.findings.iter().all(|f| f.rule == Rule::D1));
    assert!(rep.findings[0].message.contains("loads.values()"));
    assert!(rep.findings[1].message.contains("for .. in loads"));
}

#[test]
fn d2_fixture_flags_call_site_not_definition() {
    let rep = check_source("sim/fixture.rs", &fixture("d2_partial_cmp.rs"));
    assert_eq!(rep.findings.len(), 1, "{:#?}", rep.findings);
    assert_eq!(rep.findings[0].rule, Rule::D2);
}

#[test]
fn d3_fixture_flags_clock_read_and_respects_exemptions() {
    let src = fixture("d3_wallclock.rs");
    let rep = check_source("workload.rs", &src);
    assert_eq!(rep.findings.len(), 1, "{:#?}", rep.findings);
    assert_eq!(rep.findings[0].rule, Rule::D3);
    // The same source under an exempt path is clean.
    assert!(check_source("main.rs", &src).findings.is_empty());
    assert!(check_source("bin/tool.rs", &src).findings.is_empty());
}

#[test]
fn d4_fixture_flags_uncovered_registry_name() {
    let policy = fixture("d4_policy.rs");
    let covered = fixture("d4_covered.rs");
    let missing = fixture("d4_missing.rs");
    let clean = check_registry_coverage(
        "cluster/policy.rs",
        &policy,
        &[("d4_covered.rs", &covered), ("also_covered.rs", &covered)],
    );
    assert!(clean.is_empty(), "{clean:#?}");
    let findings = check_registry_coverage(
        "cluster/policy.rs",
        &policy,
        &[("d4_covered.rs", &covered), ("d4_missing.rs", &missing)],
    );
    assert_eq!(findings.len(), 1, "{findings:#?}");
    assert_eq!(findings[0].rule, Rule::D4);
    assert!(findings[0].message.contains("newpolicy"));
    assert!(findings[0].message.contains("d4_missing.rs"));
}

#[test]
fn simulation_core_modules_are_sim_scoped_for_d1_d3() {
    // The planet-scale core (event queue, arena storage, streaming
    // workloads) is load-bearing for bit-identity, so its modules must
    // be inside sim scope: a hash iteration, a partial_cmp, or a clock
    // read slipped into any of them has to fail detlint by path.
    for rel in
        ["sim/mod.rs", "sim/arena.rs", "cluster/driver.rs", "cluster/elastic.rs", "workload.rs"]
    {
        assert!(sim_scoped(rel), "{rel} must be sim-scoped");
    }
    let src = fixture("sim_scope_arena_stream.rs");
    for rel in ["sim/arena.rs", "sim/mod.rs", "cluster/elastic.rs", "workload.rs"] {
        let rep = check_source(rel, &src);
        let mut rules: Vec<&str> = rep.findings.iter().map(|f| f.rule.id()).collect();
        rules.sort_unstable();
        assert_eq!(rules, ["D1", "D2", "D3"], "{rel}: {:#?}", rep.findings);
    }
    // Outside sim scope only the crate-wide wall-clock rule applies.
    let rep = check_source("cli.rs", &src);
    assert_eq!(rep.findings.len(), 1, "{:#?}", rep.findings);
    assert_eq!(rep.findings[0].rule, Rule::D3);
}

#[test]
fn justified_allow_suppresses() {
    let rep = check_source("cluster/fixture.rs", &fixture("allow_ok.rs"));
    assert!(rep.findings.is_empty(), "{:#?}", rep.findings);
    assert_eq!(rep.allows.len(), 1);
    assert!(rep.allows[0].used, "the allow must be credited as used");
}

#[test]
fn reasonless_allow_is_a_finding_and_does_not_suppress() {
    let rep = check_source("cluster/fixture.rs", &fixture("allow_missing_reason.rs"));
    let mut rules: Vec<&str> = rep.findings.iter().map(|f| f.rule.id()).collect();
    rules.sort_unstable();
    assert_eq!(rules, ["D1", "allow"], "{:#?}", rep.findings);
}

#[test]
fn live_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = check_crate(root).expect("lint the live tree");
    assert!(
        report.findings.is_empty(),
        "unsuppressed detlint findings in the live tree:\n{}",
        report.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    assert!(!report.allows.is_empty(), "the triaged tree carries justified allows");
    let stale: Vec<String> = report
        .allows
        .iter()
        .filter(|a| !a.used)
        .map(|a| format!("{}:{}: allow({})", a.file, a.line, a.rule))
        .collect();
    assert!(stale.is_empty(), "stale allow annotations (suppress nothing):\n{}", stale.join("\n"));
}
