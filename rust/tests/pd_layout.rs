//! Prefill/decode disaggregation regressions (`--layout pd`).
//!
//! Three contracts: (1) colocated layouts are bit-identical and take
//! zero PD code paths for every registry scheduler and predictor
//! family now that the PD machinery exists; (2) PD handoff accounting
//! is airtight — every completed request either handed its KV off to
//! the decode pool exactly once or completed on the prefill pool;
//! (3) on prefill-heavy traffic with heavy decode residency, PD beats
//! the colocated cascade on TTFT (the LAPS claim: prefill instances
//! never stall behind decode batches, and TTFT is stamped at prefill
//! completion).

use cascade_infer::experiment::Experiment;
use cascade_infer::workload::Request;

/// Every name in the scheduler registry (`PolicySpec::resolve`).
const SCHEDULERS: &[&str] = &[
    "cascade",
    "vllm",
    "sglang",
    "llumnix",
    "chain",
    "nopipeline",
    "quantity",
    "memory",
    "interstage",
    "rrintra",
    "sjf",
];

fn small_trace(n: u64) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.04,
            input_len: 128 + (i % 13) * 96,
            output_len: 16 + (i % 7) * 24,
        })
        .collect()
}

/// Prefill-heavy arrivals with substantial decode residency: long-ish
/// prompts and 300-token outputs keep every colocated instance's
/// batches decode-dominated, which is exactly the interference PD
/// removes from the prefill path.
fn prefill_heavy_trace(n: u64) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.04,
            input_len: if i % 3 == 0 { 1200 + (i % 5) * 300 } else { 300 + (i % 11) * 40 },
            output_len: 300,
        })
        .collect()
}

fn run_colocated(
    scheduler: &str,
    predictor: Option<&str>,
    trace: &[Request],
) -> (cascade_infer::metrics::Report, cascade_infer::cluster::RunStats) {
    let mut b = Experiment::builder().instances(4).scheduler(scheduler).trace(trace.to_vec());
    if let Some(p) = predictor {
        b = b.predictor(p);
    }
    b.build().expect("colocated experiment builds").run()
}

fn run_pd(
    layout: &str,
    trace: &[Request],
) -> (cascade_infer::metrics::Report, cascade_infer::cluster::RunStats) {
    Experiment::builder()
        .instances(4)
        .scheduler("cascade")
        .layout(layout)
        .trace(trace.to_vec())
        .build()
        .expect("pd experiment builds")
        .run()
}

#[test]
fn colocated_layouts_take_zero_pd_paths_and_stay_deterministic() {
    let trace = small_trace(30);
    for sched in SCHEDULERS {
        let (r1, s1) = run_colocated(sched, None, &trace);
        let (r2, s2) = run_colocated(sched, None, &trace);
        assert_eq!(
            r1.fingerprint(),
            r2.fingerprint(),
            "{sched}: colocated runs must be bit-identical"
        );
        assert_eq!(s1.pd_handoffs, 0, "{sched}: no PD handoff may fire colocated");
        assert_eq!(s1.pd_handoff_tokens, 0, "{sched}");
        assert_eq!(s1.pd_local_completions, 0, "{sched}");
        assert_eq!(s1.pd_reallocations, 0, "{sched}");
        assert_eq!(s2.pd_handoffs, 0, "{sched}");
    }
}

#[test]
fn colocated_predictor_families_take_zero_pd_paths() {
    let trace = small_trace(30);
    for pred in ["noisy:0.4", "bucket:0.7", "ltr:0.8"] {
        let (r1, s1) = run_colocated("cascade", Some(pred), &trace);
        let (r2, _) = run_colocated("cascade", Some(pred), &trace);
        assert_eq!(
            r1.fingerprint(),
            r2.fingerprint(),
            "{pred}: colocated runs must be bit-identical"
        );
        assert_eq!(
            s1.pd_handoffs + s1.pd_local_completions + s1.pd_reallocations,
            0,
            "{pred}: no PD counter may move colocated"
        );
    }
}

#[test]
fn pd_handoff_accounting_is_airtight() {
    // Mixed outputs including single-token requests, which complete
    // *on* the prefill pool (reaped at prefill, no handoff).
    let trace: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: 200 + (i % 9) * 150,
            output_len: if i % 5 == 0 { 1 } else { 32 + (i % 4) * 16 },
        })
        .collect();
    let (report, stats) = run_pd("pd:2/2", &trace);
    assert_eq!(report.records.len(), trace.len(), "every request completes under PD");
    let singles = trace.iter().filter(|r| r.output_len == 1).count() as u64;
    assert_eq!(stats.pd_local_completions, singles, "output_len==1 completes at prefill");
    assert_eq!(
        stats.pd_handoffs + stats.pd_local_completions,
        report.records.len() as u64,
        "every completion either handed off exactly once or finished at prefill"
    );
    assert!(stats.pd_handoff_tokens > 0, "handoffs moved KV tokens");
    assert_eq!(stats.migrations, 0, "PD transfers are handoffs, not migrations");
    assert_eq!(stats.rejected, 0);
    // Reporting shows both pools; no request is lost to either.
    assert_eq!(stats.stages.len(), 2, "stats stages = [prefill pool, decode pool]");
    assert_eq!(stats.stages[0].len() + stats.stages[1].len(), 4);
}

#[test]
fn pd_runs_are_deterministic() {
    let trace = prefill_heavy_trace(60);
    for layout in ["pd", "pd:2/2", "pd:1/3:256:0"] {
        let (r1, s1) = run_pd(layout, &trace);
        let (r2, s2) = run_pd(layout, &trace);
        assert_eq!(r1.fingerprint(), r2.fingerprint(), "{layout}: PD runs are deterministic");
        assert_eq!(s1.pd_handoffs, s2.pd_handoffs, "{layout}");
        assert_eq!(s1.pd_handoff_tokens, s2.pd_handoff_tokens, "{layout}");
        assert_eq!(r1.records.len(), trace.len(), "{layout}: every request completes");
    }
}

#[test]
fn pd_beats_colocated_cascade_ttft_on_prefill_heavy_traffic() {
    let trace = prefill_heavy_trace(100);
    let (colo, _) = run_colocated("cascade", None, &trace);
    let (pd, pd_stats) = run_pd("pd:2/2", &trace);
    assert_eq!(pd.records.len(), trace.len());
    assert!(pd_stats.pd_handoffs > 0, "the PD run actually disaggregated");
    assert!(
        pd.mean_ttft() < colo.mean_ttft(),
        "PD prefill pool must beat colocated cascade TTFT on prefill-heavy traffic: \
         pd {:.4}s vs colocated {:.4}s",
        pd.mean_ttft(),
        colo.mean_ttft()
    );
}
