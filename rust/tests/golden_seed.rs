//! Golden-seed cluster regression.
//!
//! Two layers of protection:
//!
//! 1. **Run-to-run bit-identity** (always enforced): a fixed (seed,
//!    config, workload) triple must produce byte-for-byte identical
//!    `Report`s and stats on repeated runs in the same build.  This
//!    catches nondeterminism (hash-order float sums, unordered event
//!    ties) but NOT a refactor that deterministically changes results.
//! 2. **Blessed checksums** (enforced once blessed): per-scheduler
//!    report checksums are compared against `tests/golden/seed42.txt`.
//!    If the file does not exist yet, the test writes it and passes —
//!    commit the generated file to pin the current behavior; any later
//!    change to event ordering or float summation then fails here.
//!    To re-bless after an *intentional* behavior change, delete the
//!    file, re-run, and commit the regenerated copy.

use cascade_infer::cluster::{run_experiment, ClusterConfig, PolicySpec, RunStats, SchedulerKind};
use cascade_infer::experiment::Experiment;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::metrics::Report;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::predict;
use cascade_infer::workload::{generate, Request, ShareGptLike};
use std::path::Path;

const GOLDEN_PATH: &str = "tests/golden/seed42.txt";

/// Seeded-coverage list, cross-referenced against the `PolicySpec`
/// registry by detlint rule D4 (and by the assertion test below): a
/// newly registered scheduler must be added here — and thereby to the
/// run-to-run bit-identity gate — before it can ship.
const REGISTRY_COVERAGE: [&str; 11] = [
    "cascade",
    "vllm",
    "sglang",
    "llumnix",
    "chain",
    "nopipeline",
    "quantity",
    "memory",
    "interstage",
    "rrintra",
    "sjf",
];

/// Predictor-family coverage, cross-referenced against the
/// `predict::names()` registry by detlint rule D4: a newly registered
/// predictor must be added here — and to the bit-identity gate below —
/// before it can ship.
const PREDICTOR_COVERAGE: [&str; 4] = ["oracle", "noisy", "bucket", "ltr"];

/// A concrete parametrisation for each predictor family, so the
/// coverage gate exercises real (non-degenerate) prediction noise.
fn predictor_instance(family: &str) -> &'static str {
    match family {
        "oracle" => "oracle",
        "noisy" => "noisy:0.5",
        "bucket" => "bucket:0.7",
        "ltr" => "ltr:0.8",
        other => panic!("unknown predictor family {other}"),
    }
}

/// Stable FNV-style fingerprint over every record's exact bit patterns
/// (shared with the builder-compat regression in `experiment_api.rs`).
fn checksum(r: &Report) -> u64 {
    r.fingerprint()
}

fn stats_fingerprint(s: &RunStats) -> (u64, u64, u64, u64, Vec<u64>) {
    (
        s.migrations,
        s.migration_tokens,
        s.migrations_skipped,
        s.preemptions,
        s.final_boundaries.clone(),
    )
}

const SCHEDULERS: [SchedulerKind; 4] = [
    SchedulerKind::Cascade,
    SchedulerKind::RoundRobin,
    SchedulerKind::LlumnixLike,
    SchedulerKind::CascadeRoundRobinIntra,
];

fn cfg8(k: SchedulerKind) -> ClusterConfig {
    let mut c = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 8, k);
    c.plan_sample = 400;
    c
}

fn trace() -> Vec<Request> {
    generate(&ShareGptLike::default(), 24.0, 400, 42)
}

#[test]
fn seeded_runs_are_bit_identical_across_schedulers() {
    let reqs = trace();
    for k in SCHEDULERS {
        let (r1, s1) = run_experiment(cfg8(k), &reqs);
        let (r2, s2) = run_experiment(cfg8(k), &reqs);
        assert_eq!(r1.records.len(), reqs.len(), "{k:?} dropped requests");
        assert_eq!(checksum(&r1), checksum(&r2), "{k:?} report not bit-identical");
        assert_eq!(stats_fingerprint(&s1), stats_fingerprint(&s2), "{k:?} stats diverged");
    }
}

#[test]
fn report_checksums_match_blessed_golden_file() {
    let reqs = trace();
    let lines: Vec<String> = SCHEDULERS
        .iter()
        .map(|&k| {
            let (r, _) = run_experiment(cfg8(k), &reqs);
            format!("{} {:#018x}", k.name(), checksum(&r))
        })
        .collect();
    let current = lines.join("\n") + "\n";
    let path = Path::new(GOLDEN_PATH);
    if path.exists() {
        let blessed = std::fs::read_to_string(path).expect("golden file readable");
        assert_eq!(
            blessed, current,
            "seeded Report diverged from the blessed golden checksums \
             ({GOLDEN_PATH}). If this change is intentional, delete the \
             file, re-run the test, and commit the regenerated copy."
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
        std::fs::write(path, &current).expect("write golden file");
        eprintln!(
            "blessed new golden checksums at {GOLDEN_PATH} — commit this \
             file to pin the current seeded behavior"
        );
    }
}

#[test]
fn golden_seed_checksum_is_order_sensitive() {
    // Sanity-check the fingerprint itself: permuting records or
    // perturbing one bit must change it, otherwise the regressions
    // above could pass vacuously.
    let reqs = trace();
    let (r, _) = run_experiment(cfg8(SchedulerKind::Cascade), &reqs);
    let base = checksum(&r);
    let mut permuted = r.records.clone();
    permuted.swap(0, 1);
    let permuted = Report::from_records(permuted);
    assert_ne!(base, checksum(&permuted));
    let mut bumped = r.records.clone();
    bumped[0].completion += 1e-9;
    let bumped = Report::from_records(bumped);
    assert_ne!(base, checksum(&bumped));
}

#[test]
fn registry_coverage_list_matches_registry() {
    assert_eq!(
        REGISTRY_COVERAGE.as_slice(),
        PolicySpec::names(),
        "REGISTRY_COVERAGE must mirror the PolicySpec registry exactly \
         (detlint rule D4 cross-references the literals)"
    );
}

#[test]
fn every_registry_scheduler_is_run_to_run_bit_identical() {
    // The named-scheduler counterpart of the SchedulerKind loop above:
    // every registry entry (including axis-spec composites without a
    // SchedulerKind) must be deterministic under its string name.
    let reqs = generate(&ShareGptLike::default(), 20.0, 150, 7);
    for name in REGISTRY_COVERAGE {
        let run = || {
            Experiment::builder()
                .instances(4)
                .scheduler(name)
                .trace(reqs.clone())
                .plan_sample(300)
                .build()
                .expect("registry experiment builds")
                .run()
        };
        let (r1, s1) = run();
        let (r2, s2) = run();
        assert_eq!(r1.records.len(), reqs.len(), "{name} dropped requests");
        assert_eq!(checksum(&r1), checksum(&r2), "{name} report not bit-identical");
        assert_eq!(stats_fingerprint(&s1), stats_fingerprint(&s2), "{name} stats diverged");
    }
}

#[test]
fn predictor_coverage_list_matches_registry() {
    assert_eq!(
        PREDICTOR_COVERAGE,
        predict::names(),
        "PREDICTOR_COVERAGE must mirror the predict::names() registry \
         exactly (detlint rule D4 cross-references the literals)"
    );
}

#[test]
fn every_registry_predictor_is_run_to_run_bit_identical() {
    // Prediction noise is seed-derived, so a fixed (seed, config,
    // trace, predictor) quadruple must reproduce bit-for-bit — reports
    // *and* the misprediction/recovery counters.
    let reqs = generate(&ShareGptLike::default(), 20.0, 150, 7);
    for family in PREDICTOR_COVERAGE {
        let p = predictor_instance(family);
        let run = || {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .predictor(p)
                .trace(reqs.clone())
                .plan_sample(300)
                .build()
                .expect("predictor experiment builds")
                .run()
        };
        let (r1, s1) = run();
        let (r2, s2) = run();
        assert_eq!(checksum(&r1), checksum(&r2), "{p} report not bit-identical");
        assert_eq!(stats_fingerprint(&s1), stats_fingerprint(&s2), "{p} stats diverged");
        assert_eq!(
            (s1.mispredictions, s1.predict_reroutes, s1.predict_escalations),
            (s2.mispredictions, s2.predict_reroutes, s2.predict_escalations),
            "{p} recovery counters diverged"
        );
    }
}

#[test]
fn streaming_runs_match_materialized_for_every_registry_scheduler() {
    // The streaming driver (`Cluster::run_stream` via
    // `build_streaming`) must be a pure representation change: the
    // same (spec, seed) produces a byte-identical report whether the
    // trace is materialized up front or pulled lazily one arrival at
    // a time.  Covers every registry entry so a scheduler whose event
    // pattern breaks the lazy-arrival equivalence argument (e.g. by
    // racing a timer against an unscheduled arrival) fails by name.
    for name in REGISTRY_COVERAGE {
        let build = || {
            Experiment::builder()
                .instances(4)
                .scheduler(name)
                .workload_name("sharegpt")
                .rate(20.0)
                .requests(150)
                .seed(7)
                .plan_sample(300)
        };
        let (rm, sm) = build().build().expect("materialized experiment builds").run();
        let (rs, ss) = build()
            .build_streaming()
            .expect("streaming experiment builds")
            .run()
            .expect("streaming run succeeds");
        assert_eq!(rm.records.len(), rs.records.len(), "{name} record counts diverged");
        assert_eq!(checksum(&rm), checksum(&rs), "{name} streaming report diverged");
        assert_eq!(
            stats_fingerprint(&sm),
            stats_fingerprint(&ss),
            "{name} streaming stats diverged"
        );
        assert_eq!(
            sm.engine_iterations, ss.engine_iterations,
            "{name} streaming iteration counts diverged"
        );
    }
}

#[test]
fn streaming_matches_materialized_under_prediction_noise() {
    // The arena caches predictor outputs at admission; the streaming
    // and materialized paths must agree for every predictor family
    // (the cached column, the recompute fallback, and the
    // misprediction recovery machinery all run under noise).
    for family in PREDICTOR_COVERAGE {
        let p = predictor_instance(family);
        let build = || {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .predictor(p)
                .workload_name("heavytail")
                .rate(20.0)
                .requests(150)
                .seed(7)
                .plan_sample(300)
        };
        let (rm, sm) = build().build().expect("materialized builds").run();
        let (rs, ss) =
            build().build_streaming().expect("streaming builds").run().expect("stream runs");
        assert_eq!(checksum(&rm), checksum(&rs), "{p} streaming report diverged");
        assert_eq!(
            (sm.mispredictions, sm.predict_reroutes, sm.predict_escalations, sm.rejected),
            (ss.mispredictions, ss.predict_reroutes, ss.predict_escalations, ss.rejected),
            "{p} streaming recovery counters diverged"
        );
    }
}

#[test]
fn different_workload_seeds_diverge() {
    let a = generate(&ShareGptLike::default(), 24.0, 200, 1);
    let b = generate(&ShareGptLike::default(), 24.0, 200, 2);
    let (ra, _) = run_experiment(cfg8(SchedulerKind::Cascade), &a);
    let (rb, _) = run_experiment(cfg8(SchedulerKind::Cascade), &b);
    assert_ne!(checksum(&ra), checksum(&rb));
}
