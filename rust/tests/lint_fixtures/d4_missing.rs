// detlint fixture: D4 coverage list missing `newpolicy`.

const REGISTRY_COVERAGE: [&str; 2] = ["cascade", "vllm"];
