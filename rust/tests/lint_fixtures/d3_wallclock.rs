// detlint fixture: D3 — wall-clock access on the simulation path.
// Not compiled; lexed by tests/detlint.rs with a non-exempt virtual path.

// VIOLATION: reads the host clock inside simulator code.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

// Merely naming the type (storing a caller-provided instant) is fine.
pub fn hold(t: std::time::Instant) -> std::time::Instant {
    t
}
