// detlint fixture: the planet-scale simulation-core modules (arena
// storage, streaming workloads) must sit inside sim scope, so D1-D3
// all fire when lexed under `sim/arena.rs`-style virtual paths.
// Not compiled; lexed by tests/detlint.rs.

use std::collections::HashMap;

pub struct Arena {
    by_id: HashMap<u64, usize>,
}

impl Arena {
    // Keyed lookup is deterministic — must not fire.
    pub fn slot(&self, id: u64) -> Option<usize> {
        self.by_id.get(&id).copied()
    }

    // VIOLATION (D1): hash-order iteration over live slots.
    pub fn live_ids(&self) -> Vec<u64> {
        self.by_id.keys().copied().collect()
    }

    // VIOLATION (D2): NaN-unsafe comparison on arrival timestamps.
    pub fn earlier(a: f64, b: f64) -> bool {
        a.partial_cmp(&b) == Some(std::cmp::Ordering::Less)
    }

    // VIOLATION (D3): wall-clock read while draining a stream.
    pub fn drain_deadline() -> std::time::Instant {
        std::time::Instant::now()
    }
}
