// detlint fixture: an allow annotation without a justification is
// itself a finding AND fails to suppress the underlying violation.

use std::collections::HashMap;

pub struct Counters {
    per_instance: HashMap<usize, u64>,
}

impl Counters {
    pub fn total(&self) -> u64 {
        self.per_instance.values().sum() // detlint: allow(D1)
    }
}
