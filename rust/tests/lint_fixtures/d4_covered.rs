// detlint fixture: D4 coverage list naming every registry scheduler.

const REGISTRY_COVERAGE: [&str; 3] = ["cascade", "vllm", "newpolicy"];
