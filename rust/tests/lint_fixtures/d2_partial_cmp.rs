// detlint fixture: D2 — NaN-unsafe float ordering in sim scope.
// Not compiled; lexed by tests/detlint.rs with a sim-scoped virtual path.

// VIOLATION: `.partial_cmp(..)` call site; a NaN collapses to Equal.
pub fn earliest(times: &mut Vec<f64>) {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

// A delegating trait definition must NOT fire (no preceding `.`).
pub struct At(pub u64);
impl PartialOrd for At {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.0.cmp(&other.0))
    }
}
impl PartialEq for At {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}
