// detlint fixture: a D1 violation suppressed by a well-formed,
// justified allow annotation on the offending line — lints clean.

use std::collections::HashMap;

pub struct Counters {
    per_instance: HashMap<usize, u64>,
}

impl Counters {
    pub fn total(&self) -> u64 {
        self.per_instance.values().sum() // detlint: allow(D1) -- u64 sum over values; order-insensitive
    }
}
