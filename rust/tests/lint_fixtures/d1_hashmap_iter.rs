// detlint fixture: D1 — hash-order iteration in sim scope.
// Not compiled; lexed by tests/detlint.rs with a sim-scoped virtual path.

use std::collections::{HashMap, HashSet};

pub struct Tracker {
    loads: HashMap<u64, u64>,
}

impl Tracker {
    // Keyed lookups are fine — none of these may fire.
    pub fn get(&self, id: u64) -> Option<&u64> {
        self.loads.get(&id)
    }

    // VIOLATION: `.values()` visits entries in hash order.
    pub fn total(&self) -> u64 {
        self.loads.values().sum()
    }

    // VIOLATION: `for .. in` over a hash container.
    pub fn drop_all(&mut self) {
        let mut seen = HashSet::new();
        for (id, _) in &self.loads {
            seen.insert(*id);
        }
    }
}
