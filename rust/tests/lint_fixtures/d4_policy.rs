// detlint fixture: D4 — a miniature PolicySpec registry.
// Not compiled; cross-referenced by tests/detlint.rs against the
// d4_covered.rs / d4_missing.rs coverage fixtures.

pub struct PolicySpec;

impl PolicySpec {
    pub fn names() -> &'static [&'static str] {
        &["cascade", "vllm", "newpolicy"]
    }
}
