//! Real-path server integration: the threaded CascadeInfer server over
//! PJRT must complete every request, produce golden-exact tokens, and
//! migrate sequences across length stages.
//!
//! Requires the `pjrt` feature (real XLA bindings) and `make artifacts`.
#![cfg(feature = "pjrt")]

use cascade_infer::server::{ServeRequest, Server, ServerConfig};

fn goldens() -> Vec<(Vec<i32>, Vec<i32>)> {
    let text = std::fs::read_to_string("artifacts/golden.txt")
        .expect("run `make artifacts` first");
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|line| {
            let parts: Vec<&str> = line.split('|').collect();
            let prompt = parts[0].split(',').map(|s| s.parse().unwrap()).collect();
            let expected = parts[3].split(',').map(|s| s.parse().unwrap()).collect();
            (prompt, expected)
        })
        .collect()
}

#[test]
fn server_serves_batched_requests_with_exact_tokens() {
    let cases = goldens();
    let mut cfg = ServerConfig::new("artifacts");
    // Single stage: no migration, pure batched serving.
    cfg.stage_boundaries = vec![];
    cfg.max_batch = 8;
    let mut server = Server::start(cfg).expect("server starts");
    for (id, (prompt, expected)) in cases.iter().enumerate() {
        server.submit(ServeRequest {
            id: id as u64,
            prompt: prompt.clone(),
            max_new_tokens: expected.len(),
        });
    }
    let mut responses = server.collect(cases.len());
    responses.sort_by_key(|r| r.id);
    for (id, (_, expected)) in cases.iter().enumerate() {
        let r = &responses[id];
        assert_eq!(&r.tokens, expected, "request {id} tokens diverged (greedy must be batch-invariant)");
        assert!(r.ttft() <= r.e2e());
        assert_eq!(r.served_by, vec![r.served_by[0]], "single stage never migrates");
    }
    server.shutdown();
}

#[test]
fn server_migrates_across_stages_and_stays_exact() {
    let cases = goldens();
    let mut cfg = ServerConfig::new("artifacts");
    // Tight stage boundary right above the prompt lengths so decoding
    // pushes sequences into stage 1 mid-generation.
    cfg.stage_boundaries = vec![26];
    cfg.max_batch = 8;
    let mut server = Server::start(cfg).expect("server starts");
    // Only use short prompts (they start in stage 0 and outgrow it).
    let short: Vec<(usize, &(Vec<i32>, Vec<i32>))> = cases
        .iter()
        .enumerate()
        .filter(|(_, (p, _))| p.len() < 24)
        .collect();
    assert!(!short.is_empty());
    for (id, (prompt, expected)) in short.iter().map(|(i, c)| (*i, *c)) {
        server.submit(ServeRequest {
            id: id as u64,
            prompt: prompt.clone(),
            max_new_tokens: expected.len(),
        });
    }
    let mut responses = server.collect(short.len());
    responses.sort_by_key(|r| r.id);
    let mut any_migrated = false;
    for r in &responses {
        let (_, (_, expected)) = short.iter().find(|(i, _)| *i as u64 == r.id).unwrap();
        assert_eq!(
            &r.tokens, expected,
            "request {} tokens diverged across migration (KV transfer must be exact)",
            r.id
        );
        any_migrated |= r.served_by.len() > 1;
    }
    assert!(any_migrated, "expected at least one inter-stage migration");
    server.shutdown();
}
