//! Cluster-scale integration: paper-configuration simulations (16
//! instances, H20) exercising every scheduler, checking the *shape* of
//! the paper's headline results at reduced request counts.

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::metrics::Slo;
use cascade_infer::models::{llama_70b, LLAMA_3B, LLAMA_8B};
use cascade_infer::workload::{generate, ShareGptLike};

fn cfg16(k: SchedulerKind) -> ClusterConfig {
    let mut c = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 16, k);
    if k == SchedulerKind::LlumnixLike {
        c.engine_speed = 1.25;
    }
    c
}

#[test]
fn paper_scale_all_schedulers_complete() {
    let reqs = generate(&ShareGptLike::default(), 24.0, 600, 11);
    for k in [
        SchedulerKind::Cascade,
        SchedulerKind::RoundRobin,
        SchedulerKind::SgLangLike,
        SchedulerKind::LlumnixLike,
    ] {
        let (report, _) = run_experiment(cfg16(k), &reqs);
        assert_eq!(report.records.len(), 600, "{k:?}");
        assert!(report.mean_ttft().is_finite());
    }
}

#[test]
fn heavy_load_cascade_beats_round_robin_tpot() {
    // Figs. 6-7: under heavy load CascadeInfer reduces latency vs the
    // round-robin baselines. Exact factors are testbed-specific; the
    // *direction* must hold.
    let reqs = generate(&ShareGptLike::default(), 200.0, 1500, 12);
    let (cascade, stats) = run_experiment(cfg16(SchedulerKind::Cascade), &reqs);
    let (rr, _) = run_experiment(cfg16(SchedulerKind::RoundRobin), &reqs);
    assert!(
        cascade.mean_tpot() < rr.mean_tpot(),
        "cascade {} !< rr {}",
        cascade.mean_tpot(),
        rr.mean_tpot()
    );
    assert!(stats.migrations > 0, "pipeline should be migrating under load");
}

#[test]
fn heavy_load_cascade_beats_round_robin_throughput() {
    // Fig. 10 direction check: throughput measured over the offered-
    // load window (the paper runs fixed-duration tests), so the finite
    // trace's drain phase does not dominate.
    let reqs = generate(&ShareGptLike::default(), 250.0, 1500, 13);
    let window = reqs.last().unwrap().arrival;
    let (cascade, _) = run_experiment(cfg16(SchedulerKind::Cascade), &reqs);
    let (rr, _) = run_experiment(cfg16(SchedulerKind::RoundRobin), &reqs);
    assert!(
        cascade.throughput_until(window) >= rr.throughput_until(window) * 0.98,
        "cascade {} < rr {}",
        cascade.throughput_until(window),
        rr.throughput_until(window)
    );
}

#[test]
fn slo_attainment_cascade_dominates_under_load() {
    // Fig. 12 direction: at 5x base SLO under heavy load, CascadeInfer
    // attains at least as much as round-robin.
    let reqs = generate(&ShareGptLike::default(), 48.0, 700, 14);
    // Base SLO from a single-request run.
    let solo = generate(&ShareGptLike::default(), 0.01, 1, 15);
    let (base, _) = run_experiment(cfg16(SchedulerKind::Cascade), &solo);
    let slo5 = Slo::scaled(base.mean_ttft().max(1e-4), base.mean_tpot().max(1e-5), 5.0);
    let (cascade, _) = run_experiment(cfg16(SchedulerKind::Cascade), &reqs);
    let (rr, _) = run_experiment(cfg16(SchedulerKind::RoundRobin), &reqs);
    assert!(
        cascade.slo_attainment(slo5) >= rr.slo_attainment(slo5) * 0.95,
        "cascade {} vs rr {}",
        cascade.slo_attainment(slo5),
        rr.slo_attainment(slo5)
    );
}

#[test]
fn layout_ablation_ordering() {
    // Fig. 14: under saturation the planned pipeline beats the
    // no-pipeline layout (the paper's heavy-load target scenario; at
    // light load the layouts are equivalent by design).
    let reqs = generate(&ShareGptLike::default(), 220.0, 1500, 16);
    let (planned, _) = run_experiment(cfg16(SchedulerKind::Cascade), &reqs);
    let (flat, _) = run_experiment(cfg16(SchedulerKind::NoPipeline), &reqs);
    assert!(
        planned.mean_normalized_latency() < flat.mean_normalized_latency(),
        "planned {} vs flat {}",
        planned.mean_normalized_latency(),
        flat.mean_normalized_latency()
    );
    let window = reqs.last().unwrap().arrival;
    assert!(
        planned.throughput_until(window) > flat.throughput_until(window),
        "planned thr {} vs flat {}",
        planned.throughput_until(window),
        flat.throughput_until(window)
    );
}

#[test]
fn bidask_balances_better_than_rr_intra() {
    // Fig. 16 direction: on the paper's forced 4-stage x 4-instance
    // pipeline under saturation, full bid-ask yields lower per-stage
    // output CV than load-blind round-robin dispatch.
    use cascade_infer::coordinator::plan::{Pipeline, StageSpec};
    let four_by_four = Pipeline {
        stages: vec![
            StageSpec { lo: 0, hi: 512, n_instances: 4 },
            StageSpec { lo: 512, hi: 1536, n_instances: 4 },
            StageSpec { lo: 1536, hi: 4096, n_instances: 4 },
            StageSpec { lo: 4096, hi: 131_072, n_instances: 4 },
        ],
        predicted_quality: 0.0,
    };
    // CV over the three dense stages; the tail stage holds too few
    // (gigantic) requests for its CV to be statistically meaningful at
    // this scale — its seed-to-seed variance swamps the policy effect
    // (see EXPERIMENTS.md Fig. 16 notes).
    let cv = |stats: &cascade_infer::cluster::RunStats| -> f64 {
        let mut cvs = Vec::new();
        for stage in stats.stages.iter().take(3) {
            if stage.len() >= 2 {
                cvs.push(stats.counters.cv(stage));
            }
        }
        cvs.iter().sum::<f64>() / cvs.len().max(1) as f64
    };
    // Averaged across workload seeds.
    let mut sum_full = 0.0;
    let mut sum_rr = 0.0;
    for seed in [17, 18, 19, 20, 21] {
        let reqs = generate(&ShareGptLike::default(), 200.0, 3000, seed);
        let run = |k: SchedulerKind| {
            let mut cfg = cfg16(k);
            cfg.forced_pipeline = Some(four_by_four.clone());
            run_experiment(cfg, &reqs).1
        };
        sum_full += cv(&run(SchedulerKind::Cascade));
        sum_rr += cv(&run(SchedulerKind::CascadeRoundRobinIntra));
    }
    assert!(
        sum_full < sum_rr * 1.1,
        "mean bid-ask CV {} should not exceed RR dispatch CV {}",
        sum_full / 5.0,
        sum_rr / 5.0
    );
}

#[test]
fn tensor_parallel_70b_runs() {
    // Figs. 9b/11b substrate: 70B at TP2/TP4 on the H20 testbed.
    let reqs = generate(&ShareGptLike::default(), 6.0, 200, 18);
    for tp in [2, 4] {
        let n = 16 / tp as usize;
        let cfg = ClusterConfig::new(GpuProfile::H20, llama_70b(tp), n, SchedulerKind::Cascade);
        let (report, _) = run_experiment(cfg, &reqs);
        assert_eq!(report.records.len(), 200, "tp={tp}");
    }
}

#[test]
fn l40_testbed_runs_small_models() {
    // Fig. 9a/11a substrate: L40 with small models only.
    let reqs = generate(&ShareGptLike::default(), 12.0, 300, 19);
    let cfg = ClusterConfig::new(GpuProfile::L40, LLAMA_8B, 16, SchedulerKind::Cascade);
    let (report, _) = run_experiment(cfg, &reqs);
    assert_eq!(report.records.len(), 300);
}

#[test]
fn light_load_no_regression() {
    // §6.1: "Light load verifies that CascadeInfer does not introduce a
    // negative impact" — within 10% of round-robin.
    let reqs = generate(&ShareGptLike::default(), 2.0, 200, 20);
    let (cascade, _) = run_experiment(cfg16(SchedulerKind::Cascade), &reqs);
    let (rr, _) = run_experiment(cfg16(SchedulerKind::RoundRobin), &reqs);
    assert!(
        cascade.mean_normalized_latency() <= rr.mean_normalized_latency() * 1.10,
        "cascade light-load {} vs rr {}",
        cascade.mean_normalized_latency(),
        rr.mean_normalized_latency()
    );
}
