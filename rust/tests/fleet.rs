//! Heterogeneous-fleet integration tests.
//!
//! Covers the three fleet-facing promises:
//! 1. a mixed `h20:6,h100:2` cascade run completes end to end and shows
//!    capacity-aware behavior (the H100s carry a higher steady-state
//!    token load share than the H20s),
//! 2. capacity-normalized flat dispatch (`sjf`) shifts the served
//!    token share toward the fast instances,
//! 3. the node topology is configurable (satellite: the hardcoded
//!    `Topology::sequential(e, 8, NvLink)` is now a `ClusterConfig`
//!    field) and feeds the migration pricing.
//!
//! The homogeneous-fleet == legacy-path bit-identity property lives in
//! `tests/experiment_api.rs` next to the other compat regressions.

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::experiment::Experiment;
use cascade_infer::gpu::{GpuProfile, LinkKind, Topology};
use cascade_infer::models::LLAMA_3B;
use cascade_infer::workload::{generate, Request, ShareGptLike};

fn heavytail(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    generate(&ShareGptLike::heavy_tail(), rate, n, seed)
}

/// Mean of a per-instance statistic over the instances tagged `gpu`.
fn mean_for_gpu(values: &[f64], gpus: &[&'static str], gpu: &str) -> f64 {
    let mut sum = 0.0;
    let mut n = 0.0;
    for (v, g) in values.iter().zip(gpus.iter()) {
        if *g == gpu {
            sum += *v;
            n += 1.0;
        }
    }
    assert!(n > 0.0, "no {gpu} instances in {gpus:?}");
    sum / n
}

#[test]
fn mixed_fleet_cascade_completes_and_h100_carries_higher_load_share() {
    let reqs = heavytail(400, 24.0, 11);
    let (report, stats) = Experiment::builder()
        .model_profile(LLAMA_3B)
        .scheduler("cascade")
        .fleet("h20:6,h100:2")
        .trace(reqs.clone())
        .plan_sample(400)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.records.len(), reqs.len(), "mixed fleet dropped requests");
    assert_eq!(stats.instance_gpus.len(), 8);
    assert_eq!(stats.instance_capacity.len(), 8);
    // The weighted planner still produces a pipeline on a heavy tail.
    assert!(stats.stages.len() > 1, "expected a pipeline: {:?}", stats.stages);
    // Capacity-aware behavior: the capacity-rich H100s sit on the
    // long-sequence end of the pipeline and hold a higher steady-state
    // token load than the average H20.
    assert_eq!(stats.mean_token_load.len(), 8, "cascade gossips, so load is sampled");
    let h100 = mean_for_gpu(&stats.mean_token_load, &stats.instance_gpus, "H100");
    let h20 = mean_for_gpu(&stats.mean_token_load, &stats.instance_gpus, "H20");
    assert!(
        h100 > h20,
        "H100 mean steady-state token load ({h100:.0}) should exceed H20's ({h20:.0}); \
         loads {:?} gpus {:?}",
        stats.mean_token_load,
        stats.instance_gpus
    );
}

#[test]
fn mixed_fleet_run_is_deterministic() {
    let reqs = heavytail(200, 16.0, 21);
    let run = || {
        Experiment::builder()
            .model_profile(LLAMA_3B)
            .scheduler("cascade")
            .fleet("h20:3,h100:1")
            .trace(reqs.clone())
            .plan_sample(200)
            .build()
            .unwrap()
            .run()
            .0
            .fingerprint()
    };
    assert_eq!(run(), run());
}

#[test]
fn capacity_normalized_dispatch_shifts_share_to_h100() {
    // Flat SJF dispatch compares capacity-normalized outstanding work:
    // under sustained load the H100 pair must end up serving more
    // output tokens than the H20 pair.
    let reqs = generate(&ShareGptLike::default(), 40.0, 400, 12);
    let (report, stats) = Experiment::builder()
        .model_profile(LLAMA_3B)
        .scheduler("sjf")
        .fleet("h20:2,h100:2")
        .trace(reqs)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.records.len(), 400);
    let tok = |i: usize| *stats.counters.output_tokens.get(&i).unwrap_or(&0) as f64;
    let h20 = tok(0) + tok(1);
    let h100 = tok(2) + tok(3);
    assert!(
        h100 > h20,
        "H100 pair ({h100}) should out-serve the H20 pair ({h20}) under \
         capacity-normalized dispatch"
    );
}

#[test]
fn custom_topology_feeds_migration_pricing() {
    // Same config and workload, but PCIe intra-node links instead of
    // the default NVLink: 18x less transfer bandwidth and 2x control
    // latency.  A migration-heavy run must diverge in timing.
    let mut reqs = generate(&ShareGptLike::default(), 12.0, 150, 13);
    for r in reqs.iter_mut() {
        r.output_len = r.output_len.max(1500);
    }
    let mut base = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, SchedulerKind::Cascade);
    base.plan_sample = 400;
    let (r_nvlink, s_nvlink) = run_experiment(base.clone(), &reqs);
    let mut pcie = base;
    pcie.topology = Some(Topology::sequential(4, 8, LinkKind::Pcie));
    let (r_pcie, s_pcie) = run_experiment(pcie, &reqs);
    assert_eq!(r_nvlink.records.len(), r_pcie.records.len());
    assert!(s_nvlink.migrations > 0, "forcing workload should migrate: {s_nvlink:?}");
    assert_ne!(
        r_nvlink.fingerprint(),
        r_pcie.fingerprint(),
        "link technology must affect migration timing (pcie stats: {s_pcie:?})"
    );
}

#[test]
fn default_topology_matches_the_historical_hardcoded_one() {
    // `topology: None` and an explicit `sequential(e, 8, NvLink)` are
    // the same configuration and must be bit-identical.
    let reqs = heavytail(150, 12.0, 14);
    let mut a = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, SchedulerKind::Cascade);
    a.plan_sample = 300;
    let mut b = a.clone();
    b.topology = Some(Topology::sequential(4, 8, LinkKind::NvLink));
    let (ra, _) = run_experiment(a, &reqs);
    let (rb, _) = run_experiment(b, &reqs);
    assert_eq!(ra.fingerprint(), rb.fingerprint());
}

#[test]
fn per_instance_kv_capacity_follows_each_gpu() {
    // An H100 (80 GB) derives a smaller KV pool than an H20 (141 GB);
    // the mixed cluster must give each instance its own budget instead
    // of replicating the reference GPU's.
    let exp = Experiment::builder()
        .model_profile(LLAMA_3B)
        .fleet("h20:1,h100:1")
        .requests(5)
        .build()
        .unwrap();
    let fleet = exp.cfg.resolved_fleet();
    let caps: Vec<u64> = fleet
        .instances
        .iter()
        .map(|s| {
            let budget = exp.cfg.model.kv_budget_bytes(s.gpu.mem_bytes, 0.9);
            exp.cfg.model.kv_capacity_tokens(budget).max(1024)
        })
        .collect();
    assert!(
        caps[0] > caps[1],
        "H20 (141 GB) must derive a larger KV pool than H100 (80 GB): {caps:?}"
    );
}
