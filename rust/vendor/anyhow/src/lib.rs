//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container image has no network and no vendored crates.io set,
//! so this crate re-implements exactly the API subset the repository
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros,
//! and the [`Context`] extension trait.  Semantics match upstream for
//! that subset (error chaining is flattened into the message).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional source, like `anyhow::Error`.
///
/// Deliberately does NOT implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` conversion below does not conflict with
/// the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// Helper behind the single-expression `anyhow!(expr)` arm.
    pub fn from_any<E: Into<Error>>(err: E) -> Self {
        err.into()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_any($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn message_and_context() {
        let e = Error::msg("base").context("outer");
        assert_eq!(e.to_string(), "outer: base");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn macros() {
        let name = "x";
        let e: Error = anyhow!("bad {name}");
        assert_eq!(e.to_string(), "bad x");
        let e: Error = anyhow!("v={}", 3);
        assert_eq!(e.to_string(), "v=3");
        let e: Error = anyhow!(io_err());
        assert_eq!(e.to_string(), "gone");
        fn bails() -> Result<()> {
            bail!("stop {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop 1");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "f")).unwrap_err();
        assert_eq!(e.to_string(), "reading f: gone");
    }
}
