//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real serving path compiles AOT-lowered HLO through PJRT; that
//! native stack is not available in this container, so this stub
//! provides the exact API surface `cascade_infer::runtime` and
//! `cascade_infer::server` consume.  Host-side [`Literal`] buffers are
//! fully functional (shape/reshape/to_vec); anything that would need a
//! real PJRT client ([`PjRtClient::cpu`], compilation, execution)
//! returns a descriptive [`Error`] instead, so the `pjrt` feature
//! builds and degrades cleanly on machines without the toolchain.

use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error` so `?` converts it
/// into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real PJRT bindings; this build uses the offline stub \
         (vendor/xla). Install the native xla_extension and swap the dependency to run."
    ))
}

/// Element types the stub stores. Public only because [`NativeType`]
/// mentions it; not part of the emulated xla-rs API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: a typed buffer plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Array shape accessor, mirroring xla-rs' `ArrayShape`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed-ish conversion trait for the element types the stub supports.
pub trait NativeType: Sized + Copy {
    fn wrap(v: Vec<Self>) -> Storage;
    fn unwrap_ref(s: &Storage) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::F32(v)
    }
    fn unwrap_ref(s: &Storage) -> Option<&[Self]> {
        match s {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::I32(v)
    }
    fn unwrap_ref(s: &Storage) -> Option<&[Self]> {
        match s {
            Storage::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::I64(v)
    }
    fn unwrap_ref(s: &Storage) -> Option<&[Self]> {
        match s {
            Storage::I64(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { storage: T::wrap(values.to_vec()), dims: vec![values.len() as i64] }
    }

    fn numel(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret the buffer under new dimensions (element count must
    /// be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.numel() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_ref(&self.storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.storage {
            Storage::Tuple(_) => Err(Error("array_shape on a tuple literal".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }
}

/// Parsed HLO module (stub: retains nothing).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        // Validate the file exists so error messages stay actionable,
        // then fail at compile time like the rest of the stub.
        if !path.as_ref().exists() {
            return Err(Error(format!("HLO file not found: {}", path.as_ref().display())));
        }
        Ok(HloModuleProto)
    }
}

/// Computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer handle (stub: never instantiated).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: never instantiated).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction fails).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
