//! §6.5 complexity claim: optimized stage partitioning at (16
//! instances, 128K context) runs in ~0.06s, vs an estimated 51 hours
//! for the naive O(E^3 L^2) DP — a ~3e6x speedup.
//!
//! We time the optimized planners directly and *extrapolate* the naive
//! DP from small cut-point counts (its per-cut cost is measured, then
//! scaled to L = 128K cut points), exactly as the paper estimated it.

mod common;

use cascade_infer::coordinator::plan::{MigrationCost, Planner};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::qoe::profile_and_fit;
use cascade_infer::workload::{generate, LengthHistogram, ShareGptLike};
use std::time::Instant;

fn main() {
    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let (qoe, _) = profile_and_fit(&am, 64, 131_072, 512);
    let planner = Planner::new(
        qoe,
        MigrationCost::new(LLAMA_3B.kv_bytes_per_token() as f64, 450e9),
    );
    let reqs = generate(&ShareGptLike::default(), 10.0, 8000, 42);
    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    let pairs: Vec<(u64, u64)> = reqs.iter().map(|r| (r.input_len, r.final_len())).collect();

    println!("=== §6.5: stage-partition complexity (16 instances, 128K context) ===");
    let t0 = Instant::now();
    let dp = planner.plan_dp(&hist, 16);
    let t_dp = t0.elapsed().as_secs_f64();
    println!("bucketed exact DP      : {:>10.4}s  ({} stages)", t_dp, dp.stages.len());

    let t0 = Instant::now();
    let heur = planner.plan_heuristic(&hist, 16);
    let t_heur = t0.elapsed().as_secs_f64();
    println!("two-phase heuristic    : {:>10.4}s  ({} stages)", t_heur, heur.stages.len());

    // Naive DP: measure at increasing cut counts, fit t = c * K^2 * E^3
    // (per-state cost), extrapolate to K = 131072 cuts.
    println!("\nnaive fine-grained DP (measured then extrapolated):");
    let mut per_state = 0.0;
    for granularity in [4096u64, 2048, 1024] {
        let cuts = 131_072 / granularity;
        let t0 = Instant::now();
        let _ = planner.plan_exact_fine(&pairs, 16, 131_072, granularity);
        let t = t0.elapsed().as_secs_f64();
        println!("  {cuts:>6} cut points     : {t:>10.4}s");
        per_state = t / (cuts as f64 * cuts as f64);
    }
    let full = per_state * 131_072.0f64 * 131_072.0;
    println!("  131072 cut points     : {:>10.1}s extrapolated ({:.1} hours)", full, full / 3600.0);
    println!("\nspeedup (extrapolated naive / optimized): {:.2e}x  (paper: ~3e6x)", full / t_dp);
}
