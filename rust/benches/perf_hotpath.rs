//! Perf harness for the L3 hot paths (EXPERIMENTS.md §Perf): cost-model
//! pricing, engine stepping, planning, and whole-cluster simulation
//! throughput (simulated decode-iterations per wall-second).

mod common;

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::engine::{CostModelBackend, Engine, EngineConfig};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::sim::Rng;
use cascade_infer::workload::{generate, Request, ShareGptLike};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    let mut sink = 0u64;
    for _ in 0..(iters / 10).max(1) {
        sink = sink.wrapping_add(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{name:<44} {:>12.2} ops/s   ({:.3} us/op, sink {})",
             iters as f64 / dt, dt / iters as f64 * 1e6, sink % 10);
}

fn main() {
    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let mut rng = Rng::new(99);
    let lens_small: Vec<u64> = (0..32).map(|_| 100 + rng.next_range(4000)).collect();
    let lens_big: Vec<u64> = (0..512).map(|_| 100 + rng.next_range(50_000)).collect();

    println!("=== L3 hot-path microbenchmarks ===");
    bench("decode_iteration_latency (batch 32)", 200_000, || {
        am.decode_iteration_latency(&lens_small).to_bits()
    });
    bench("decode_iteration_latency (batch 512)", 20_000, || {
        am.decode_iteration_latency(&lens_big).to_bits()
    });

    // Engine stepping throughput.
    bench("engine.step (64 live seqs)", 2_000, || {
        let mut e = Engine::new(EngineConfig::default(), CostModelBackend::new(am));
        for i in 0..64 {
            e.submit(Request { id: i, arrival: 0.0, input_len: 200 + i * 10, output_len: 4 });
        }
        let mut now = 0.0;
        let mut n = 0u64;
        while e.has_work() {
            let o = e.step(now);
            now += o.duration.max(1e-9);
            n += 1;
        }
        n
    });

    // Whole-cluster simulation rate.
    let reqs = generate(&ShareGptLike::default(), 32.0, 2000, 7);
    let total_tokens: u64 = reqs.iter().map(|r| r.output_len).sum();
    let t0 = Instant::now();
    let cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 16, SchedulerKind::Cascade);
    let (rep, _) = run_experiment(cfg, &reqs);
    let dt = t0.elapsed().as_secs_f64();
    println!("\n=== cluster simulation throughput ===");
    println!("2000 requests / {total_tokens} decode tokens in {dt:.2}s wall");
    println!("{:.0} simulated output tokens per wall-second", total_tokens as f64 / dt);
    println!("(completed: {})", rep.records.len());
}
