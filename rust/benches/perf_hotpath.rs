//! Perf harness for the L3 hot paths (EXPERIMENTS.md §Perf): cost-model
//! pricing, engine stepping, planning, and whole-cluster simulation
//! throughput (simulated engine iterations per wall-second).
//!
//! Alongside the human table it writes `BENCH_hotpath.json` (override
//! with `--json PATH`) so the perf trajectory is tracked in a
//! machine-readable form.  `--quick` shrinks every run to CI-smoke
//! size.  `--check BASELINE.json [--tolerance F]` compares the
//! headline cluster-sim throughput against a committed baseline and
//! exits non-zero on a regression beyond the tolerance (default 30%) —
//! the CI perf-smoke gate.  A baseline containing `"placeholder": 1`
//! (the state before the first toolchain-bearing run) skips the gate
//! and prints blessing instructions instead.  `--bless` runs at quick
//! size and writes the fresh report straight over the committed
//! baseline (`benches/baseline/BENCH_hotpath.json`) — the one-command
//! blessing path; commit the result, never hand-edit it.

mod common;

use cascade_infer::engine::{CostModelBackend, Engine, EngineConfig};
use cascade_infer::experiment::Experiment;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::metrics::BenchReport;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::sim::Rng;
use cascade_infer::workload::{Request, WorkloadSpec};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(
    report: &mut BenchReport,
    name: &str,
    key: &str,
    iters: usize,
    mut f: F,
) {
    // Warmup.
    let mut sink = 0u64;
    for _ in 0..(iters / 10).max(1) {
        sink = sink.wrapping_add(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let dt = t0.elapsed().as_secs_f64();
    let ops = iters as f64 / dt;
    println!(
        "{name:<44} {ops:>12.2} ops/s   ({:.3} us/op, sink {})",
        dt / iters as f64 * 1e6,
        sink % 10
    );
    report.push(key, ops);
}

/// One cluster simulation; returns (wall seconds, engine iterations,
/// simulated output tokens).
fn cluster_run(
    scheduler: &str,
    workload: WorkloadSpec,
    instances: usize,
    rate: f64,
    requests: usize,
    seed: u64,
    micro_step: bool,
) -> (f64, u64, u64) {
    let exp = Experiment::builder()
        .gpu("H20")
        .instances(instances)
        .scheduler(scheduler)
        .workload(workload)
        .rate(rate)
        .requests(requests)
        .seed(seed)
        .micro_step(micro_step)
        .build()
        .expect("bench experiment builds");
    let tokens: u64 = exp.requests.iter().map(|r| r.output_len).sum();
    let n = exp.requests.len();
    let t0 = Instant::now();
    let (rep, stats) = exp.run();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(rep.records.len(), n, "bench run dropped requests");
    (dt, stats.engine_iterations, tokens)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let bless = flag("--bless");
    // The committed baseline is always a --quick measurement (the CI
    // gate compares like against like), so --bless forces quick size.
    let quick = flag("--quick") || bless;
    let json_path = opt("--json").unwrap_or_else(|| "BENCH_hotpath.json".into());
    let tolerance: f64 =
        opt("--tolerance").and_then(|s| s.parse().ok()).unwrap_or(0.30);

    let mut report = BenchReport::default();
    report.push("quick", f64::from(u8::from(quick)));

    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let mut rng = Rng::new(99);
    let lens_small: Vec<u64> = (0..32).map(|_| 100 + rng.next_range(4000)).collect();
    let lens_big: Vec<u64> = (0..512).map(|_| 100 + rng.next_range(50_000)).collect();
    let scale = if quick { 10 } else { 1 };

    println!("=== L3 hot-path microbenchmarks ===");
    bench(
        &mut report,
        "decode_iteration_latency (batch 32)",
        "decode_iteration_latency_b32_ops_per_s",
        200_000 / scale,
        || am.decode_iteration_latency(&lens_small).to_bits(),
    );
    bench(
        &mut report,
        "decode_iteration_latency (batch 512)",
        "decode_iteration_latency_b512_ops_per_s",
        20_000 / scale,
        || am.decode_iteration_latency(&lens_big).to_bits(),
    );

    // Engine stepping throughput.
    bench(
        &mut report,
        "engine.step (64 live seqs)",
        "engine_step_64seqs_ops_per_s",
        2_000 / scale,
        || {
            let mut e = Engine::new(EngineConfig::default(), CostModelBackend::new(am));
            for i in 0..64 {
                e.submit(Request {
                    id: i,
                    arrival: 0.0,
                    input_len: 200 + i * 10,
                    output_len: 4,
                });
            }
            let mut now = 0.0;
            let mut n = 0u64;
            while e.has_work() {
                let o = e.step(now);
                now += o.duration.max(1e-9);
                n += 1;
            }
            n
        },
    );

    // Whole-cluster simulation rates.
    println!("\n=== cluster simulation throughput ===");
    let n16 = if quick { 400 } else { 2000 };
    let (dt, iters, tokens) =
        cluster_run("cascade", WorkloadSpec::default(), 16, 32.0, n16, 7, false);
    println!(
        "16x sharegpt cascade: {n16} requests / {tokens} decode tokens in {dt:.2}s \
         ({:.0} tok/s, {:.0} iters/s)",
        tokens as f64 / dt,
        iters as f64 / dt
    );
    report.push("cluster_sim_16x_sharegpt_tokens_per_s", tokens as f64 / dt);

    // The acceptance workload: 8-instance heavytail, macro-stepped.
    let n8 = if quick { 400 } else { 1500 };
    let (dt, iters, _) =
        cluster_run("cascade", WorkloadSpec::HeavyTail, 8, 24.0, n8, 7, false);
    let macro_ips = iters as f64 / dt;
    println!(
        "8x heavytail cascade (macro): {n8} requests, {iters} engine iterations \
         in {dt:.2}s = {macro_ips:.0} simulated iters per wall-second"
    );
    report.push("cluster_sim_8x_heavytail_iters_per_s", macro_ips);
    report.push("cluster_sim_8x_heavytail_wall_s", dt);
    report.push("cluster_sim_8x_heavytail_iterations", iters as f64);

    // The same workload on the --micro-step debug path: the committed
    // speedup factor of the macro-stepped core (reports bit-identical;
    // see tests/macro_equivalence.rs).
    let (dt_micro, iters_micro, _) =
        cluster_run("cascade", WorkloadSpec::HeavyTail, 8, 24.0, n8, 7, true);
    assert_eq!(iters, iters_micro, "macro/micro iteration counts must agree");
    let micro_ips = iters_micro as f64 / dt_micro;
    println!(
        "8x heavytail cascade (micro): {dt_micro:.2}s = {micro_ips:.0} iters/s \
         -> macro speedup {:.2}x",
        macro_ips / micro_ips
    );
    report.push("cluster_sim_8x_heavytail_micro_iters_per_s", micro_ips);
    report.push("cluster_sim_8x_heavytail_macro_speedup", macro_ips / micro_ips);

    // Planet-scale fleet cell: 1000 instances through the full planned
    // stack (offline DP, staged routing, gossip/refine timers).  The
    // calendar event queue and arena storage are what keep this cell's
    // per-event cost flat as the fleet grows.
    println!("\n=== planet-scale cells ===");
    let (n_fleet, rate_fleet) = if quick { (3_000, 400.0) } else { (20_000, 600.0) };
    let (dt, iters, _) =
        cluster_run("cascade", WorkloadSpec::HeavyTail, 1000, rate_fleet, n_fleet, 7, false);
    println!(
        "1000x heavytail cascade: {n_fleet} requests, {iters} engine iterations \
         in {dt:.2}s = {:.0} iters/s",
        iters as f64 / dt
    );
    report.push("cluster_sim_1000x_heavytail_iters_per_s", iters as f64 / dt);
    report.push("cluster_sim_1000x_heavytail_wall_s", dt);

    // Streaming-workload cell: arrivals pulled lazily, trace never
    // materialized (full size: 1M requests).  Short contexts keep the
    // simulated token volume bounded so the cell measures driver
    // overhead per request, not decode pricing.
    let n_stream = if quick { 50_000 } else { 1_000_000 };
    let exp = Experiment::builder()
        .gpu("H20")
        .instances(16)
        .scheduler("cascade")
        .workload_name("uniformshort")
        .rate(600.0)
        .requests(n_stream)
        .seed(7)
        .build_streaming()
        .expect("streaming bench builds");
    let t0 = Instant::now();
    let (rep, stats) = exp.run().expect("streaming bench runs");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(rep.records.len(), n_stream, "streaming bench dropped requests");
    println!(
        "16x uniformshort streaming: {n_stream} requests in {dt:.2}s = {:.0} reqs/s \
         (peak in-flight {} of {} total)",
        n_stream as f64 / dt,
        stats.arena_high_water,
        n_stream
    );
    report.push("cluster_sim_stream_reqs_per_s", n_stream as f64 / dt);
    report.push("cluster_sim_stream_peak_in_flight", stats.arena_high_water as f64);

    std::fs::write(&json_path, report.to_json()).expect("write bench json");
    println!("\nwrote {json_path}");

    if bless {
        // Anchored on the manifest dir so blessing works from any cwd
        // (`cargo bench` runs benches from the package root, but a
        // direct target/ invocation may not).
        let baseline =
            concat!(env!("CARGO_MANIFEST_DIR"), "/benches/baseline/BENCH_hotpath.json");
        std::fs::write(baseline, report.to_json()).expect("write blessed baseline");
        println!("blessed baseline {baseline} — review the diff and commit it");
    }

    // --check: the CI regression gate.
    if let Some(baseline_path) = opt("--check") {
        let baseline = std::fs::read_to_string(&baseline_path)
            .unwrap_or_else(|e| panic!("cannot read baseline {baseline_path}: {e}"));
        if BenchReport::parse_value(&baseline, "placeholder") == Some(1.0) {
            // GitHub Actions surfaces `::warning::` lines as loud
            // annotations on the run — an unblessed baseline must not
            // pass silently forever.
            println!(
                "::warning title=perf baseline is a placeholder::{baseline_path} still \
                 carries `placeholder: 1`, so the perf regression gate is NOT running. \
                 Bless it by committing the fresh --quick BENCH_hotpath.json over it."
            );
            println!(
                "baseline {baseline_path} is a placeholder — skipping the regression \
                 gate.  Bless it by committing the fresh {json_path} over it."
            );
            return;
        }
        // Quick and full-size runs have systematically different
        // throughput (startup/planning weight, batch mix) — only gate
        // like against like.
        let this_quick = f64::from(u8::from(quick));
        if BenchReport::parse_value(&baseline, "quick") != Some(this_quick) {
            println!(
                "baseline {baseline_path} was measured at a different run size \
                 (its `quick` field does not match this run's {this_quick}) — \
                 skipping the regression gate; re-bless with a same-size run."
            );
            return;
        }
        // Per-metric drift report: one `::notice::` annotation per key
        // shared with the baseline, so trends (not just the gated
        // headline) are visible on every CI run without downloading
        // artifacts.  The `quick` field is a run-size tag, not a
        // metric, and keys new in this run have no baseline to diff.
        for (k, v) in &report.entries {
            if k == "quick" {
                continue;
            }
            if let Some(b) = BenchReport::parse_value(&baseline, k) {
                let delta = if b.abs() > f64::EPSILON { (v - b) / b * 100.0 } else { 0.0 };
                println!("::notice title=perf delta::{k}: {v:.2} vs baseline {b:.2} ({delta:+.1}%)");
            } else {
                println!("::notice title=perf delta::{k}: {v:.2} (no baseline entry yet)");
            }
        }
        let key = "cluster_sim_8x_heavytail_iters_per_s";
        let base = BenchReport::parse_value(&baseline, key)
            .unwrap_or_else(|| panic!("baseline {baseline_path} lacks {key}"));
        let floor = base * (1.0 - tolerance);
        if macro_ips < floor {
            eprintln!(
                "PERF REGRESSION: {key} = {macro_ips:.0} is below {floor:.0} \
                 (baseline {base:.0} - {:.0}% tolerance)",
                tolerance * 100.0
            );
            std::process::exit(1);
        }
        println!(
            "perf gate OK: {key} = {macro_ips:.0} vs baseline {base:.0} \
             (floor {floor:.0})"
        );
    }
}
