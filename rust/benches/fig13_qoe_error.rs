//! Fig. 13: prediction-error distribution of the QoE cost model vs a
//! static mean predictor.
//!
//! Paper: QoE-model error density peaks sharply at zero with mean
//! absolute error 8.9%, vs 64% for the static baseline.

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::models::LLAMA_3B;
use cascade_infer::qoe::{
    fit, mean_abs_rel_error, profile_and_fit, relative_errors, static_baseline_errors,
};
use cascade_infer::sim::Rng;

fn main() {
    let am = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    let (_, all) = profile_and_fit(&am, 64, 131_072, 512);

    // Fit/validation split (§4.1), shuffled deterministically.
    let mut idx: Vec<usize> = (0..all.len()).collect();
    Rng::new(1313).shuffle(&mut idx);
    let cut = all.len() * 7 / 10;
    let fit_set: Vec<_> = idx[..cut].iter().map(|&i| all[i]).collect();
    let val_set: Vec<_> = idx[cut..].iter().map(|&i| all[i]).collect();
    let model = fit(&fit_set).expect("fit");

    let model_errs = relative_errors(&model, &val_set);
    let static_errs = static_baseline_errors(&fit_set, &val_set);
    println!("=== Fig. 13: relative prediction error ===");
    println!(
        "QoE model  : MAE {:>6.1}%  (paper: 8.9%)",
        100.0 * mean_abs_rel_error(&model_errs)
    );
    println!(
        "static mean: MAE {:>6.1}%  (paper: 64%)",
        100.0 * mean_abs_rel_error(&static_errs)
    );

    // Error density histogram (text form of the figure).
    println!("\nerror density (bucketed relative error):");
    let buckets = [-1.0, -0.5, -0.25, -0.1, -0.05, 0.05, 0.1, 0.25, 0.5, 1.0];
    for (name, errs) in [("model", &model_errs), ("static", &static_errs)] {
        print!("{name:<7}");
        for w in buckets.windows(2) {
            let c = errs.iter().filter(|&&e| e >= w[0] && e < w[1]).count();
            let frac = c as f64 / errs.len().max(1) as f64;
            print!(" [{:>+5.2},{:>+5.2}):{:>4.0}%", w[0], w[1], 100.0 * frac);
        }
        println!();
    }
}
