//! Fig. 14: layout ablation — CascadeInfer's planned pipeline vs the
//! chain layout (one instance per stage) vs no-pipeline.
//!
//! Paper: no-pipeline worst; chain loses ~30% latency / 7.1%
//! throughput vs CascadeInfer (migration overhead + balancing).

mod common;

use cascade_infer::cluster::SchedulerKind;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;

fn main() {
    let n = common::n_requests(2000);
    println!("=== Fig. 14: layout ablation (Llama-3.2-3B, 16 instances, H20) ===");
    println!(
        "{:<12} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "layout", "rate", "norm lat ms", "mean TPOT ms", "tok/s", "migrations"
    );
    for rate in [100.0, 200.0, 300.0] {
        let reqs = common::workload(rate, n, 1414);
        let window = reqs.last().unwrap().arrival;
        for k in [SchedulerKind::Cascade, SchedulerKind::Chain, SchedulerKind::NoPipeline] {
            let (rep, stats) = common::run(GpuProfile::H20, LLAMA_3B, 16, k, 1.0, &reqs);
            println!(
                "{:<12} {:>8.0} {:>12.3} {:>12.3} {:>12.0} {:>10}",
                k.name(),
                rate,
                rep.mean_normalized_latency() * 1e3,
                rep.mean_tpot() * 1e3,
                rep.throughput_until(window),
                stats.migrations
            );
        }
        common::hr();
    }
}
