//! Fig. 10: system throughput across the model zoo under varying
//! arrival rates (H20 testbed, 16 instances).
//!
//! Paper headline: heavy-load average throughput 1.99x vLLM, 2.18x
//! SGLang, 1.71x Llumnix (up to 2.89x).

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::paper_zoo;

fn main() {
    let n = common::n_requests(1500);
    println!("=== Fig. 10: throughput (tokens/s over the offered-load window) ===");
    for model in paper_zoo() {
        // Light / medium / saturation rates per model size class.
        let rates: [f64; 3] = if model.params > 20_000_000_000 {
            [8.0, 20.0, 40.0]
        } else if model.params > 10_000_000_000 {
            [15.0, 40.0, 80.0]
        } else {
            [50.0, 150.0, 300.0]
        };
        println!("--- {} ---", model.name);
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in rates {
                let reqs = common::workload(rate, n, 1010);
                let window = reqs.last().unwrap().arrival;
                let (rep, _) = common::run(GpuProfile::H20, model, 16, k, speed, &reqs);
                print!(" {:>10.0}", rep.throughput_until(window));
            }
            println!();
        }
    }
}
