//! Fig. 9: normalized latency (end-to-end delay per output token) on
//! (a) the L40 testbed (small models) and (b) Llama-3.1-70B at TP2/TP4
//! on the H20 testbed.
//!
//! Paper: 45-67% reduction on L40; 27-65% at TP2, 49-64% at TP4.

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::{llama_70b, LLAMA_3B, LLAMA_8B};

fn main() {
    let n = common::n_requests(1200);
    println!("=== Fig. 9a: normalized latency (ms/token), L40 testbed ===");
    for model in [LLAMA_3B, LLAMA_8B] {
        println!("--- {} ---", model.name);
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in [15.0, 40.0, 80.0] {
                let reqs = common::workload(rate, n, 909);
                let (rep, _) = common::run(GpuProfile::L40, model, 16, k, speed, &reqs);
                print!(" {:>10.3}", rep.mean_normalized_latency() * 1e3);
            }
            println!();
        }
    }
    common::hr();
    println!("=== Fig. 9b: normalized latency (ms/token), Llama-3.1-70B TP on H20 ===");
    for tp in [2u32, 4] {
        let model = llama_70b(tp);
        let n_inst = 16 / tp as usize;
        println!("--- TP={tp} ({n_inst} instances) ---");
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in [3.0, 8.0, 16.0] {
                let reqs = common::workload(rate, n, 910);
                let (rep, _) = common::run(GpuProfile::H20, model, n_inst, k, speed, &reqs);
                print!(" {:>10.3}", rep.mean_normalized_latency() * 1e3);
            }
            println!();
        }
    }
}
