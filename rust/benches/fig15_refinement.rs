//! Fig. 15: boundary-refinement ablation — adaptive (QoE-optimal
//! split) vs quantity-based vs memory-based policies.
//!
//! Paper: quantity-based worst (severe imbalance); CascadeInfer beats
//! memory-based by 21% latency / 12% throughput.

mod common;

use cascade_infer::cluster::SchedulerKind;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;

fn main() {
    let n = common::n_requests(2000);
    println!("=== Fig. 15: refinement ablation (Llama-3.2-3B, 16 instances, H20) ===");
    println!(
        "{:<16} {:>8} {:>12} {:>12} {:>12}",
        "refinement", "rate", "norm lat ms", "mean TPOT ms", "tok/s"
    );
    for rate in [100.0, 200.0, 300.0] {
        let reqs = common::workload(rate, n, 1515);
        let window = reqs.last().unwrap().arrival;
        for k in [
            SchedulerKind::Cascade,
            SchedulerKind::CascadeMemoryRefine,
            SchedulerKind::CascadeQuantityRefine,
        ] {
            let (rep, _) = common::run(GpuProfile::H20, LLAMA_3B, 16, k, 1.0, &reqs);
            println!(
                "{:<16} {:>8.0} {:>12.3} {:>12.3} {:>12.0}",
                k.name(),
                rate,
                rep.mean_normalized_latency() * 1e3,
                rep.mean_tpot() * 1e3,
                rep.throughput_until(window)
            );
        }
        common::hr();
    }
}
