//! Fig. 8: TPOT of a *single* instance across request rates.
//!
//! Paper finding: CascadeInfer's single-instance performance matches
//! vLLM's (it does not touch instance internals) but trails Llumnix's
//! newer engine by 22-81% — so the multi-instance gains in Figs. 6-7
//! are scheduling gains, not engine gains.

mod common;

use cascade_infer::cluster::SchedulerKind;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;

fn main() {
    let n = common::n_requests(400);
    println!("=== Fig. 8: single-instance TPOT (ms/token) ===");
    println!("{:<14} {:>8} {:>8} {:>8} {:>8}", "system", "2/s", "5/s", "10/s", "20/s");
    for (k, speed) in [
        (SchedulerKind::Cascade, 1.0),
        (SchedulerKind::RoundRobin, 1.0),
        (SchedulerKind::LlumnixLike, 1.25),
    ] {
        print!("{:<14}", k.name());
        for rate in [2.0, 5.0, 10.0, 20.0] {
            let reqs = common::workload(rate, n, 808);
            let (rep, _) = common::run(GpuProfile::H20, LLAMA_3B, 1, k, speed, &reqs);
            print!(" {:>8.3}", rep.mean_tpot() * 1e3);
        }
        println!();
    }
    println!("\n(CascadeInfer == vLLM single-instance by construction; Llumnix's\n newer engine is faster — its multi-instance gains are smaller.)");
}
