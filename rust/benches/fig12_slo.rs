//! Fig. 12: SLO attainment across SLO scales and arrival rates
//! (Llama-3.2-3B, H20). The base SLO is TTFT/TPOT under minimum load;
//! the Nx SLO scales both bounds.
//!
//! Paper: 3.8-7.6x attainment under 5x SLO, 2.0-2.8x under 20x.

mod common;

use cascade_infer::cluster::SchedulerKind;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::metrics::Slo;
use cascade_infer::models::LLAMA_3B;

fn main() {
    let n = common::n_requests(2000);
    // Base SLO: a single request on an idle CascadeInfer cluster.
    let solo = common::workload(0.01, 1, 1212);
    let (base, _) =
        common::run(GpuProfile::H20, LLAMA_3B, 16, SchedulerKind::Cascade, 1.0, &solo);
    let (bt, bp) = (base.mean_ttft().max(1e-4), base.mean_tpot().max(1e-5));
    println!("base SLO: TTFT {bt:.4}s, TPOT {bp:.5}s");
    println!("=== Fig. 12: SLO attainment (%) ===");
    for rate in [100.0, 200.0, 300.0] {
        let reqs = common::workload(rate, n, 1213);
        println!("--- rate {rate} req/s ---");
        println!("{:<14} {:>8} {:>8} {:>8} {:>8}", "system", "5x", "10x", "20x", "40x");
        for (k, speed) in common::systems() {
            let (rep, _) = common::run(GpuProfile::H20, LLAMA_3B, 16, k, speed, &reqs);
            print!("{:<14}", k.name());
            for scale in [5.0, 10.0, 20.0, 40.0] {
                let slo = Slo::scaled(bt, bp, scale);
                print!(" {:>7.1}%", 100.0 * rep.slo_attainment(slo));
            }
            println!();
        }
    }
}
