//! Shared helpers for the figure-regeneration benches.
//!
//! Each bench is a standalone binary (`harness = false`) that prints
//! the rows/series of one paper table or figure. Absolute numbers come
//! from the simulated testbed (DESIGN.md §1), so the comparisons —
//! who wins, rough factors, crossovers — are the reproduction target,
//! not the raw values.

#![allow(dead_code)]

use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::metrics::Report;
use cascade_infer::models::ModelProfile;
use cascade_infer::workload::{generate, Request, ShareGptLike};

/// Scale knob: `CASCADE_BENCH_REQUESTS` overrides the per-point
/// request count (default keeps the full sweep under a few minutes).
pub fn n_requests(default: usize) -> usize {
    std::env::var("CASCADE_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn workload(rate: f64, n: usize, seed: u64) -> Vec<Request> {
    generate(&ShareGptLike::default(), rate, n, seed)
}

/// The four compared systems of §6 with their engine speeds.
pub fn systems() -> Vec<(SchedulerKind, f64)> {
    vec![
        (SchedulerKind::Cascade, 1.0),
        (SchedulerKind::RoundRobin, 1.0),  // vLLM 0.9.1 + RR
        (SchedulerKind::SgLangLike, 0.95), // SGLang 0.4.9 + RR
        (SchedulerKind::LlumnixLike, 1.25),
    ]
}

pub fn run(
    gpu: GpuProfile,
    model: ModelProfile,
    n_instances: usize,
    k: SchedulerKind,
    speed: f64,
    reqs: &[Request],
) -> (Report, cascade_infer::cluster::RunStats) {
    let mut cfg = ClusterConfig::new(gpu, model, n_instances, k);
    cfg.engine_speed = speed;
    run_experiment(cfg, reqs)
}

pub fn hr() {
    println!("{}", "-".repeat(100));
}
