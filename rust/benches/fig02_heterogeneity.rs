//! Fig. 2: effect of sequence-length heterogeneity on the decode
//! forward pass at constant total tokens (paper: 1.1-2.1x inflation,
//! Llama-3.2-3B, batch 512).
//!
//! (a) 1000 vs 50000 tokens; (b) 200 vs 10000 tokens.  The fixed-split
//! sweep exposes the block-size/block-count trade-off of §2.3.

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::{AttentionModel, BLOCK_CANDIDATES};
use cascade_infer::models::LLAMA_3B;

fn mix(n: usize, n_long: usize, long: u64, short: u64) -> Vec<u64> {
    let mut v = vec![long; n_long];
    v.extend(vec![short; n - n_long]);
    v
}

fn main() {
    let m = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
    for (name, long, short) in [("Fig 2a: 1000 vs 50000", 50_000u64, 1000u64),
                                ("Fig 2b:  200 vs 10000", 10_000, 200)] {
        println!("=== {name} (batch 512, constant total tokens) ===");
        println!("{:<10} {:>14} {:>14} {:>9}", "long rows", "hetero (ms)", "homo (ms)", "penalty");
        for n_long in [5, 10, 26, 51, 102, 128] {
            let lens = mix(512, n_long, long, short);
            let total: u64 = lens.iter().sum();
            let homo = vec![(total / 512).max(1); 512];
            let t_het = m.decode_attention_latency(&lens);
            let t_hom = m.decode_attention_latency(&homo);
            println!(
                "{n_long:<10} {:>14.3} {:>14.3} {:>8.2}x",
                t_het * 1e3,
                t_hom * 1e3,
                t_het / t_hom
            );
        }
        common::hr();
    }

    println!("=== split-size sweep (partitioning inefficiency, 26 long rows of 50K) ===");
    let lens = mix(512, 26, 50_000, 1000);
    println!("{:<12} {:>14}", "split", "latency (ms)");
    for b in BLOCK_CANDIDATES {
        let t = m.decode_attention_latency_fixed_block(&lens, b);
        println!("{b:<12} {:>14.3}", t * 1e3);
    }
    let t = m.decode_attention_latency_fixed_block(&lens, u32::MAX);
    println!("{:<12} {:>14.3}", "no-split", t * 1e3);
    let t = m.decode_attention_latency(&lens);
    println!("{:<12} {:>14.3}", "heuristic", t * 1e3);
}
