//! Fig. 11: throughput on (a) the L40 testbed and (b) Llama-3.1-70B at
//! TP2/TP4 on H20.
//!
//! Paper: 1.21-1.37x on L40 (smaller gains: less memory, smaller
//! batches); 1.31-2.53x at TP2, 2.89-4.16x at TP4.

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::{llama_70b, LLAMA_3B, LLAMA_8B};

fn main() {
    let n = common::n_requests(1200);
    println!("=== Fig. 11a: throughput (tok/s), L40 testbed ===");
    for model in [LLAMA_3B, LLAMA_8B] {
        println!("--- {} ---", model.name);
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in [15.0, 40.0, 80.0] {
                let reqs = common::workload(rate, n, 1111);
                let window = reqs.last().unwrap().arrival;
                let (rep, _) = common::run(GpuProfile::L40, model, 16, k, speed, &reqs);
                print!(" {:>10.0}", rep.throughput_until(window));
            }
            println!();
        }
    }
    common::hr();
    println!("=== Fig. 11b: throughput (tok/s), Llama-3.1-70B TP on H20 ===");
    for tp in [2u32, 4] {
        let model = llama_70b(tp);
        let n_inst = 16 / tp as usize;
        println!("--- TP={tp} ({n_inst} instances) ---");
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in [3.0, 8.0, 16.0] {
                let reqs = common::workload(rate, n, 1112);
                let window = reqs.last().unwrap().arrival;
                let (rep, _) = common::run(GpuProfile::H20, model, n_inst, k, speed, &reqs);
                print!(" {:>10.0}", rep.throughput_until(window));
            }
            println!();
        }
    }
}
