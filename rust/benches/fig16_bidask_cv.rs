//! Fig. 16: load-balance quality of the bid-ask protocol — coefficient
//! of variation of per-instance output tokens within each stage, for
//! the paper's forced four-stage x four-instance pipeline.
//!
//! Paper: full bid-ask cuts CV ~40% vs inter-stage-only and ~47% vs
//! round-robin receiver selection.

mod common;

use cascade_infer::cluster::{ClusterConfig, SchedulerKind};
use cascade_infer::coordinator::plan::{Pipeline, StageSpec};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;

fn four_by_four() -> Pipeline {
    Pipeline {
        stages: vec![
            StageSpec { lo: 0, hi: 512, n_instances: 4 },
            StageSpec { lo: 512, hi: 1536, n_instances: 4 },
            StageSpec { lo: 1536, hi: 4096, n_instances: 4 },
            StageSpec { lo: 4096, hi: 131_072, n_instances: 4 },
        ],
        predicted_quality: 0.0,
    }
}

fn main() {
    let n = common::n_requests(3000);
    let seeds = [1616u64, 1717, 1818, 1919, 2020];
    println!("=== Fig. 16: per-stage output-token CV, 4 stages x 4 instances ===");
    println!("(averaged over {} workload seeds at rate 200)\n", seeds.len());
    println!("{:<16} {:>32} {:>10}", "policy", "mean per-stage CVs (s0..s3)", "mean CV");
    for k in [
        SchedulerKind::Cascade,
        SchedulerKind::CascadeInterStageOnly,
        SchedulerKind::CascadeRoundRobinIntra,
    ] {
        let mut stage_cvs = vec![0.0f64; 4];
        let mut total = 0.0;
        for &seed in &seeds {
            let reqs = common::workload(200.0, n, seed);
            let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 16, k);
            cfg.forced_pipeline = Some(four_by_four());
            let (_, stats) = cascade_infer::cluster::run_experiment(cfg, &reqs);
            for (si, stage) in stats.stages.iter().enumerate() {
                if stage.len() >= 2 {
                    stage_cvs[si] += stats.counters.cv(stage);
                }
            }
        }
        for c in stage_cvs.iter_mut() {
            *c /= seeds.len() as f64;
            total += *c;
        }
        let mean = total / 4.0;
        let cv_str: Vec<String> = stage_cvs.iter().map(|c| format!("{c:.3}")).collect();
        println!("{:<16} {:>32} {:>10.3}", k.name(), cv_str.join(" "), mean);
    }
}
