//! Fig. 6: mean and p95 TTFT across the model zoo under varying
//! arrival rates (H20 testbed, 16 instances).
//!
//! Paper headline: under heavy load CascadeInfer cuts mean TTFT
//! 67-78% vs vLLM, 70-84% vs SGLang, 36-66% vs Llumnix.

mod common;

use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::paper_zoo;

fn main() {
    let n = common::n_requests(1500);
    // Per-model rates: larger models saturate earlier.
    println!("=== Fig. 6: TTFT (s) — mean / p95 ===");
    for model in paper_zoo() {
        // Light / medium / saturation rates per model size class.
        let rates: [f64; 3] = if model.params > 20_000_000_000 {
            [8.0, 20.0, 40.0]
        } else if model.params > 10_000_000_000 {
            [15.0, 40.0, 80.0]
        } else {
            [50.0, 150.0, 300.0]
        };
        println!("--- {} ---", model.name);
        print!("{:<14}", "rate:");
        for r in rates {
            print!(" {r:>21.0} req/s");
        }
        println!();
        for (k, speed) in common::systems() {
            print!("{:<14}", k.name());
            for rate in rates {
                let reqs = common::workload(rate, n, 606);
                let (rep, _) = common::run(GpuProfile::H20, model, 16, k, speed, &reqs);
                print!("  {:>10.4}/{:>10.4}", rep.mean_ttft(), rep.p95_ttft());
            }
            println!();
        }
    }
}
