//! Fig. 1: request-length distribution inside decode batches, sampled
//! at 20/40/60/80% of the run, per scheduling policy and request rate.
//!
//! The paper's point: under length-agnostic policies, every sampled
//! batch mixes short and very long sequences; CascadeInfer's batches
//! are length-homogeneous per stage.

mod common;

use cascade_infer::cluster::SchedulerKind;
use cascade_infer::gpu::GpuProfile;
use cascade_infer::models::LLAMA_3B;

fn percentile(xs: &mut Vec<u64>, p: f64) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    xs.sort_unstable();
    xs[((xs.len() - 1) as f64 * p / 100.0).round() as usize]
}

fn main() {
    println!("=== Fig. 1: batch length composition (p10/p50/p90 within sampled batches) ===");
    let n = common::n_requests(2000);
    for rate in [50.0, 250.0] {
        let reqs = common::workload(rate, n, 101);
        for (k, speed) in common::systems() {
            let (_, stats) = common::run(GpuProfile::H20, LLAMA_3B, 16, k, speed, &reqs);
            print!("rate {rate:>4.0}  {:<14}", k.name());
            for mark in [0.2, 0.4, 0.6, 0.8] {
                let mut lens: Vec<u64> = stats
                    .batch_snapshots
                    .iter()
                    .filter(|(m, _)| (*m - mark).abs() < 1e-9)
                    .flat_map(|(_, l)| l.iter().copied())
                    .collect();
                if lens.is_empty() {
                    print!("  [{:>3.0}%] (no sample)        ", mark * 100.0);
                    continue;
                }
                let p10 = percentile(&mut lens, 10.0);
                let p50 = percentile(&mut lens, 50.0);
                let p90 = percentile(&mut lens, 90.0);
                print!("  [{:>2.0}%] {p10:>5}/{p50:>6}/{p90:>7}", mark * 100.0);
            }
            // Spread statistic: mean p90/p10 ratio across marks (the
            // heterogeneity CascadeInfer removes).
            let mut ratios = Vec::new();
            for mark in [0.2, 0.4, 0.6, 0.8] {
                for (m, lens) in &stats.batch_snapshots {
                    if (*m - mark).abs() < 1e-9 && lens.len() >= 4 {
                        let mut v = lens.clone();
                        v.sort_unstable();
                        let p10 = v[(v.len() - 1) / 10].max(1);
                        let p90 = v[(v.len() - 1) * 9 / 10];
                        ratios.push(p90 as f64 / p10 as f64);
                    }
                }
            }
            let spread = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
            println!("  | spread p90/p10 = {spread:>7.1}x");
        }
        common::hr();
    }
}
