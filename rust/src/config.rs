//! Minimal TOML-subset configuration parser + typed experiment configs.
//!
//! The offline vendor set has no `serde`/`toml`, so this module parses
//! the subset the repo's config files use: `[section]` headers,
//! `key = value` with string / integer / float / bool / flat arrays,
//! and `#` comments.  Typed accessors convert into the experiment
//! structs used by the CLI and examples.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parsed config: section -> key -> value. Keys outside any section
/// land in the "" section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let lineno = idx + 1;
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            cfg.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn get_int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn get_float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn get_float_array(&self, section: &str, key: &str) -> Option<Vec<f64>> {
        self.get(section, key)?
            .as_array()?
            .iter()
            .map(|v| v.as_float())
            .collect()
    }
}

fn err(line: usize, msg: &str) -> ParseError {
    ParseError { line, message: msg.to_string() }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value `{s}`")))
}

/// Split on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// Typed experiment configuration assembled from a [`Config`] —
/// the `[experiment]` section of a config file.  Routed into the
/// builder via [`crate::experiment::Experiment::from_config`]; the
/// `sim` subcommand's flags override individual fields.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub model: String,
    pub gpu: String,
    pub n_instances: usize,
    pub rate: f64,
    pub n_requests: usize,
    pub seed: u64,
    /// Registry name or `custom:` axis string
    /// (see [`crate::cluster::PolicySpec::resolve`]).
    pub scheduler: String,
    /// Workload name (see [`crate::workload::WorkloadSpec::parse`]).
    pub workload: String,
    /// Optional heterogeneous fleet string
    /// (see [`crate::fleet::FleetSpec::parse`], e.g. `"h20:6,h100:2"`).
    /// When set it overrides `instances`/`gpu`.
    pub fleet: Option<String>,
    /// Optional length predictor (see
    /// [`crate::predict::PredictorSpec::parse`], e.g. `"noisy:0.5"`).
    /// When set it overrides the predictor carried by the scheduler
    /// spec.
    pub predictor: Option<String>,
    /// Optional stage layout override (see
    /// [`crate::cluster::parse_layout`], e.g. `"pd:2/2"` for
    /// prefill/decode disaggregation).  When set it overrides the
    /// layout carried by the scheduler spec.
    pub layout: Option<String>,
    /// Optional fault-injection / elasticity spec (see
    /// [`crate::cluster::ChurnSpec::parse`], e.g.
    /// `"spot:2.0@1,join:6.0"` or `"auto:1.0:2..8"`).
    pub churn: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "Llama-3.2-3B".into(),
            gpu: "H20".into(),
            n_instances: 16,
            rate: 8.0,
            n_requests: 2000,
            seed: 42,
            scheduler: "cascade".into(),
            workload: "sharegpt".into(),
            fleet: None,
            predictor: None,
            layout: None,
            churn: None,
        }
    }
}

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> Self {
        let d = Self::default();
        Self {
            model: cfg.get_str("experiment", "model", &d.model),
            gpu: cfg.get_str("experiment", "gpu", &d.gpu),
            n_instances: cfg.get_int("experiment", "instances", d.n_instances as i64) as usize,
            rate: cfg.get_float("experiment", "rate", d.rate),
            n_requests: cfg.get_int("experiment", "requests", d.n_requests as i64) as usize,
            seed: cfg.get_int("experiment", "seed", d.seed as i64) as u64,
            scheduler: cfg.get_str("experiment", "scheduler", &d.scheduler),
            workload: cfg.get_str("experiment", "workload", &d.workload),
            fleet: cfg
                .get("experiment", "fleet")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            predictor: cfg
                .get("experiment", "predictor")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            layout: cfg
                .get("experiment", "layout")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            churn: cfg
                .get("experiment", "churn")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level comment
title = "cascade"   # trailing comment

[experiment]
model = "Llama-3.2-3B"
instances = 16
rate = 8.5
requests = 2000
seed = 42
warm = true
rates = [2.0, 4.0, 8.0]
names = ["a", "b"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get_str("", "title", ""), "cascade");
        assert_eq!(cfg.get_int("experiment", "instances", 0), 16);
        assert!((cfg.get_float("experiment", "rate", 0.0) - 8.5).abs() < 1e-12);
        assert!(cfg.get_bool("experiment", "warm", false));
        assert_eq!(cfg.get_float_array("experiment", "rates").unwrap(), vec![2.0, 4.0, 8.0]);
    }

    #[test]
    fn int_promotes_to_float() {
        let cfg = Config::parse("x = 3").unwrap();
        assert_eq!(cfg.get_float("", "x", 0.0), 3.0);
    }

    #[test]
    fn string_arrays() {
        let cfg = Config::parse(r#"xs = ["a,b", "c"]"#).unwrap();
        let arr = cfg.get("", "xs").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_str(), Some("a,b"));
        assert_eq!(arr[1].as_str(), Some("c"));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let cfg = Config::parse(r##"x = "a#b""##).unwrap();
        assert_eq!(cfg.get_str("", "x", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Config::parse("\n\nbad line").unwrap_err();
        assert_eq!(e.line, 3);
        let e = Config::parse("[unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Config::parse("x = \"open").unwrap_err();
        assert!(e.message.contains("unterminated"));
    }

    #[test]
    fn experiment_config_defaults_fill_gaps() {
        let cfg = Config::parse("[experiment]\nrate = 2.0").unwrap();
        let e = ExperimentConfig::from_config(&cfg);
        assert_eq!(e.rate, 2.0);
        assert_eq!(e.n_instances, 16);
        assert_eq!(e.scheduler, "cascade");
    }

    #[test]
    fn missing_keys_fall_back() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_int("nope", "x", 7), 7);
    }
}
