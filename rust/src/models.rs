//! Model zoo — analytic profiles of the eight LLMs the paper serves.
//!
//! The evaluation (§6.1) sweeps Llama-3.2-3B … Qwen-2.5-32B plus
//! Llama-3.1-70B under TP2/TP4.  For scheduling purposes a model is
//! fully characterised by: weight bytes (per-iteration HBM read),
//! per-token KV-cache bytes (attention read volume), and the dense
//! FLOPs per token (prefill compute).  The numbers below come from the
//! models' published architectures at FP16.

use crate::gpu::GIB;

/// Architecture-derived cost profile of one served LLM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Total parameters.
    pub params: u64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    /// Distinct KV heads (GQA: n_kv_heads <= n_heads).
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// Supported context window.
    pub max_context: u32,
    /// Tensor-parallel degree this profile is sliced at.
    pub tp: u32,
}

impl ModelProfile {
    /// The same architecture re-sliced at tensor-parallel degree `tp`
    /// — the per-instance resolution step of a TP-aware fleet: an
    /// `InstanceSpec` carrying `tp=4` serves
    /// `base.with_tp(4)` regardless of the degree baked into the base
    /// profile's name.  Weights, KV bytes, and dense FLOPs all divide
    /// by the new degree; the architecture numbers are untouched.
    pub const fn with_tp(self, tp: u32) -> ModelProfile {
        ModelProfile {
            name: self.name,
            params: self.params,
            n_layers: self.n_layers,
            d_model: self.d_model,
            n_heads: self.n_heads,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
            max_context: self.max_context,
            tp,
        }
    }

    /// FP16 weight bytes *per GPU* (TP slices weights evenly).
    pub fn weight_bytes(&self) -> u64 {
        2 * self.params / self.tp as u64
    }

    /// KV-cache bytes per token *per GPU* at FP16 (K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * 2 * self.n_layers as u64 * self.n_kv_heads as u64 * self.head_dim as u64)
            / self.tp as u64
    }

    /// Dense FLOPs to process one token through the stack (2*params,
    /// attention excluded — the kernel model prices that separately).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params as f64 / self.tp as f64
    }

    /// How many cached tokens fit in `budget` bytes of KV memory.
    pub fn kv_capacity_tokens(&self, budget_bytes: u64) -> u64 {
        budget_bytes / self.kv_bytes_per_token().max(1)
    }

    /// KV memory budget on a device: what's left after weights and a
    /// fixed activation/fragmentation reserve (vLLM's
    /// `gpu_memory_utilization`-style accounting).
    pub fn kv_budget_bytes(&self, device_mem: u64, util: f64) -> u64 {
        let usable = (device_mem as f64 * util) as u64;
        usable.saturating_sub(self.weight_bytes()).saturating_sub(2 * GIB)
    }
}

/// Llama-3.2-3B: 28 layers, d=3072, 24 Q heads, 8 KV heads, hd=128.
pub const LLAMA_3B: ModelProfile = ModelProfile {
    name: "Llama-3.2-3B",
    params: 3_210_000_000,
    n_layers: 28,
    d_model: 3072,
    n_heads: 24,
    n_kv_heads: 8,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// Phi-3-mini (3.8B): 32 layers, d=3072, 32 heads (MHA), hd=96.
pub const PHI_3B: ModelProfile = ModelProfile {
    name: "Phi-3-3B",
    params: 3_820_000_000,
    n_layers: 32,
    d_model: 3072,
    n_heads: 32,
    n_kv_heads: 32,
    head_dim: 96,
    max_context: 131_072,
    tp: 1,
};

/// Llama-3.1-8B: 32 layers, d=4096, 32 Q / 8 KV heads, hd=128.
pub const LLAMA_8B: ModelProfile = ModelProfile {
    name: "Llama-3.1-8B",
    params: 8_030_000_000,
    n_layers: 32,
    d_model: 4096,
    n_heads: 32,
    n_kv_heads: 8,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// GLM-4-9B: 40 layers, d=4096, 32 Q / 2 KV heads, hd=128.
pub const GLM_9B: ModelProfile = ModelProfile {
    name: "GLM-4-9B",
    params: 9_400_000_000,
    n_layers: 40,
    d_model: 4096,
    n_heads: 32,
    n_kv_heads: 2,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// Phi-3-medium (14B): 40 layers, d=5120, 40 Q / 10 KV heads, hd=128.
pub const PHI_14B: ModelProfile = ModelProfile {
    name: "Phi-3-14B",
    params: 14_000_000_000,
    n_layers: 40,
    d_model: 5120,
    n_heads: 40,
    n_kv_heads: 10,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// Qwen-2.5-14B: 48 layers, d=5120, 40 Q / 8 KV heads, hd=128.
pub const QWEN_14B: ModelProfile = ModelProfile {
    name: "Qwen-2.5-14B",
    params: 14_770_000_000,
    n_layers: 48,
    d_model: 5120,
    n_heads: 40,
    n_kv_heads: 8,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// QwQ-32B: 64 layers, d=5120, 40 Q / 8 KV heads, hd=128.
pub const QWQ_32B: ModelProfile = ModelProfile {
    name: "QwQ-32B",
    params: 32_500_000_000,
    n_layers: 64,
    d_model: 5120,
    n_heads: 40,
    n_kv_heads: 8,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// Qwen-2.5-32B: 64 layers, d=5120, 40 Q / 8 KV heads, hd=128.
pub const QWEN_32B: ModelProfile = ModelProfile {
    name: "Qwen-2.5-32B",
    params: 32_760_000_000,
    n_layers: 64,
    d_model: 5120,
    n_heads: 40,
    n_kv_heads: 8,
    head_dim: 128,
    max_context: 131_072,
    tp: 1,
};

/// Llama-3.1-70B at a given TP degree (§6.2 "tensor parallelism").
pub const fn llama_70b(tp: u32) -> ModelProfile {
    ModelProfile {
        name: "Llama-3.1-70B",
        params: 70_600_000_000,
        n_layers: 80,
        d_model: 8192,
        n_heads: 64,
        n_kv_heads: 8,
        head_dim: 128,
        max_context: 131_072,
        tp,
    }
}

/// The paper's four size categories (§6.1), in evaluation order.
pub fn paper_zoo() -> Vec<ModelProfile> {
    vec![
        LLAMA_3B, PHI_3B,        // Tiny
        LLAMA_8B, GLM_9B,        // Small
        PHI_14B, QWEN_14B,       // Moderate
        QWQ_32B, QWEN_32B,       // Large
    ]
}

pub fn by_name(name: &str) -> Option<ModelProfile> {
    let lower = name.to_ascii_lowercase();
    paper_zoo()
        .into_iter()
        .chain([llama_70b(2), llama_70b(4)])
        .find(|m| m.name.to_ascii_lowercase().contains(&lower) || lower.contains("70b") && m.name.contains("70B"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuProfile;

    #[test]
    fn weight_bytes_are_2x_params_fp16() {
        assert_eq!(LLAMA_3B.weight_bytes(), 2 * LLAMA_3B.params);
    }

    #[test]
    fn llama3b_kv_bytes_match_hand_calc() {
        // 2 (K,V) * 2 bytes * 28 layers * 8 kv heads * 128 head dim.
        assert_eq!(LLAMA_3B.kv_bytes_per_token(), 2 * 2 * 28 * 8 * 128);
        assert_eq!(LLAMA_3B.kv_bytes_per_token(), 114_688);
    }

    #[test]
    fn tp_slices_weights_and_kv() {
        let m2 = llama_70b(2);
        let m4 = llama_70b(4);
        assert_eq!(m2.weight_bytes(), 2 * m4.weight_bytes());
        assert_eq!(m2.kv_bytes_per_token(), 2 * m4.kv_bytes_per_token());
    }

    #[test]
    fn with_tp_reslices_any_base_profile() {
        assert_eq!(llama_70b(1).with_tp(4), llama_70b(4));
        assert_eq!(llama_70b(2).with_tp(2), llama_70b(2));
        let m = LLAMA_3B.with_tp(2);
        assert_eq!(m.tp, 2);
        assert_eq!(m.weight_bytes(), LLAMA_3B.weight_bytes() / 2);
        assert_eq!(m.n_layers, LLAMA_3B.n_layers);
    }

    #[test]
    fn zoo_is_ordered_small_to_large() {
        let zoo = paper_zoo();
        assert_eq!(zoo.len(), 8);
        for pair in zoo.windows(2) {
            // Categories are non-decreasing in parameter count (within
            // a category order can vary slightly, so allow 35% slack).
            assert!(pair[1].params as f64 > 0.65 * pair[0].params as f64);
        }
    }

    #[test]
    fn tp2_70b_fills_half_an_h20() {
        // §6.2: at TP=2 the 70B weights occupy nearly half of each
        // GPU's memory.
        let m = llama_70b(2);
        let frac = m.weight_bytes() as f64 / GpuProfile::H20.mem_bytes as f64;
        assert!(frac > 0.40 && frac < 0.55, "frac {frac}");
    }

    #[test]
    fn kv_budget_positive_for_all_paper_models_on_h20() {
        for m in paper_zoo() {
            let b = m.kv_budget_bytes(GpuProfile::H20.mem_bytes, 0.9);
            assert!(b > 0, "{} has no KV budget", m.name);
            assert!(m.kv_capacity_tokens(b) > 10_000, "{}", m.name);
        }
    }

    #[test]
    fn large_models_do_not_fit_l40_at_fp16() {
        // The paper only runs small models on the L40 testbed.
        let b = QWEN_32B.kv_budget_bytes(GpuProfile::L40.mem_bytes, 0.9);
        assert_eq!(b, 0);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("llama-3.2-3b").unwrap().name, "Llama-3.2-3B");
        assert_eq!(by_name("qwq").unwrap().name, "QwQ-32B");
        assert!(by_name("nonexistent-model").is_none());
    }
}
