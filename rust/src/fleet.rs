//! Heterogeneous fleet description — per-instance GPU/engine/speed.
//!
//! The paper's testbeds are homogeneous (16 identical H20s or L40s),
//! but real multi-instance deployments mix GPU generations and engine
//! builds: the paper itself models a faster Llumnix engine with a
//! scalar `engine_speed` (§6.2 Fig. 8), and UELLM / slice-level
//! scheduling motivate serving across non-uniform resources.  This
//! module makes the fleet a first-class value:
//!
//! * [`InstanceSpec`] — one instance's hardware + runtime: a
//!   [`GpuProfile`], an [`EngineConfig`], and a relative engine speed.
//! * [`FleetSpec`] — the ordered instance list.  Order matters: the
//!   planner assigns instances to pipeline stages contiguously (the §5
//!   placement optimization), so `h20:6,h100:2` puts the H100s on the
//!   long-sequence end of the pipeline.
//!
//! The CLI grammar (`--fleet`) is a comma-separated list of
//! `GPU:COUNT` groups, each optionally followed by `speed=F` / `tp=N`
//! options applying to the group, e.g. `h20:12,h100:4,speed=1.37` (12
//! stock H20s plus 4 H100s running a 1.37x-faster engine build) or
//! `h20:4,tp=2,h20:2,tp=4` (four TP2 slices feeding two TP4 slices).
//!
//! Tensor parallelism: an instance with `tp=N` serves the configured
//! model re-sliced at degree `N` ([`InstanceSpec::model_for`]) — its
//! per-GPU weight and KV traffic shrink `N`x and its KV pool derives
//! `N`x the per-instance token headroom, at the cost of per-layer
//! all-reduce collectives priced by the attention model
//! ([`crate::kernelmodel::AttentionModel::tp_comm_latency`]).  `tp=1`
//! (the default) leaves the base model untouched, so TP-free fleets
//! stay bit-identical to the pre-TP behavior.
//!
//! Capacity: [`InstanceSpec::reference_throughput`] prices a reference
//! serving mix (prefill + steady-state decode) with the same analytic
//! cost model the engines execute under, so "capacity" is consistent
//! with what the simulator will actually measure.  The cluster
//! normalizes capacities to the fleet maximum; a homogeneous fleet
//! therefore gets exactly 1.0 everywhere and every capacity-normalized
//! code path reduces bit-identically to the legacy uniform one.

use crate::engine::EngineConfig;
use crate::gpu::{GpuProfile, LinkKind};
use crate::kernelmodel::AttentionModel;
use crate::models::ModelProfile;
use crate::Tokens;

use std::fmt;

/// One instance's hardware + runtime configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceSpec {
    pub gpu: GpuProfile,
    /// Engine knobs; a `None` KV capacity is derived from *this
    /// instance's* GPU memory budget.
    pub engine: EngineConfig,
    /// Relative engine speed (1.0 = vLLM-class).  Composes with
    /// `ClusterConfig::engine_speed`, which acts as a fleet-wide
    /// multiplier (so policy-level speeds like Llumnix's 1.25 apply on
    /// top of per-instance hardware speeds).
    pub speed: f64,
    /// Tensor-parallel degree of this instance (1 = whole model per
    /// GPU, the legacy configuration).  `tp > 1` re-slices the base
    /// model ([`InstanceSpec::model_for`]): per-GPU weights/KV shrink,
    /// the pooled KV headroom grows, and every forward pass pays the
    /// per-layer all-reduce collectives.
    pub tp: u32,
}

/// Reference serving mix used to price relative capacity: a 1024-token
/// prompt producing 256 output tokens, decoded in a 64-deep batch of
/// 1280-token rows.  Chosen to exercise both the compute-bound prefill
/// regime (where an H100 crushes an H20) and the bandwidth-bound decode
/// regime (where the H20's fat HBM nearly evens the score).
const REF_INPUT: Tokens = 1024;
const REF_OUTPUT: f64 = 256.0;
const REF_BATCH: usize = 64;
const REF_ROW_LEN: Tokens = 1280;

impl InstanceSpec {
    pub fn new(gpu: GpuProfile) -> Self {
        Self { gpu, engine: EngineConfig::default(), speed: 1.0, tp: 1 }
    }

    pub fn with_speed(mut self, speed: f64) -> Self {
        self.speed = speed;
        self
    }

    pub fn with_tp(mut self, tp: u32) -> Self {
        assert!(tp >= 1, "tp degree must be >= 1");
        self.tp = tp;
        self
    }

    /// The model profile this instance actually serves: the base model
    /// re-sliced at this instance's TP degree.  `tp == 1` returns the
    /// base untouched — including base profiles that already carry a
    /// degree in their name (e.g. `llama_70b(2)`), so the legacy
    /// "model-level TP" configurations keep their exact meaning.
    pub fn model_for(&self, base: &ModelProfile) -> ModelProfile {
        if self.tp <= 1 {
            *base
        } else {
            base.with_tp(self.tp)
        }
    }

    /// Modeled output tokens/s of this instance on the reference
    /// serving mix — the capacity weight the router and bid-ask
    /// balancer normalize load by.  Deterministic (pure cost model, no
    /// profiling runs).  TP-sharded instances are priced on their
    /// resolved slice — faster weight/KV streaming minus the
    /// all-reduce premium, with collectives at the NVLink default;
    /// the cluster uses [`InstanceSpec::reference_throughput_with_link`]
    /// to price them over its actual intra-node link.
    pub fn reference_throughput(&self, model: &ModelProfile) -> f64 {
        self.reference_mix_throughput(AttentionModel::new(self.gpu, self.model_for(model)))
    }

    /// [`InstanceSpec::reference_throughput`] with TP collectives
    /// priced over `link` — keeps capacity weights consistent with the
    /// per-instance cost backends, which ride the topology's
    /// intra-node link.  TP1 instances are link-independent
    /// (collectives are exactly 0.0), so TP-free fleets stay
    /// bit-identical regardless of the link passed.
    pub fn reference_throughput_with_link(&self, model: &ModelProfile, link: LinkKind) -> f64 {
        self.reference_mix_throughput(
            AttentionModel::new(self.gpu, self.model_for(model)).with_tp_link(link),
        )
    }

    /// Collective-free throughput on the reference mix — the TP-aware
    /// planner's capacity weight.  The DP charges collectives as a
    /// separate additive term ([`crate::coordinator::plan::PlanInstance`]
    /// `::comm_s_per_token`); baking them into the capacity as well
    /// would double-count the premium.
    pub fn plan_capacity(&self, model: &ModelProfile) -> f64 {
        self.reference_mix_throughput(
            AttentionModel::new(self.gpu, self.model_for(model)).without_tp_collectives(),
        )
    }

    /// Shared reference-mix pricing behind the capacity weights.
    fn reference_mix_throughput(&self, am: AttentionModel) -> f64 {
        let t_prefill = am.prefill_latency(REF_INPUT);
        let t_iter = am.decode_iteration_latency(&[REF_ROW_LEN; REF_BATCH]);
        // Steady state: the prefill's compute is serialized per request,
        // decode tokens are amortized over the batch.
        let per_request = t_prefill + REF_OUTPUT * t_iter / REF_BATCH as f64;
        self.speed * REF_OUTPUT / per_request
    }

    /// Amortized tensor-parallel collective seconds per generated
    /// token at the reference decode batch, priced over `link` — the
    /// planner's per-instance communication weight.  Exactly 0.0 for
    /// TP1 instances.
    pub fn tp_comm_s_per_token(&self, model: &ModelProfile, link: LinkKind) -> f64 {
        let m = self.model_for(model);
        if m.tp <= 1 {
            return 0.0;
        }
        let am = AttentionModel::new(self.gpu, m).with_tp_link(link);
        am.tp_comm_latency(REF_BATCH as u64) / REF_BATCH as f64
    }
}

/// The ordered fleet: one [`InstanceSpec`] per instance id.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub instances: Vec<InstanceSpec>,
}

impl FleetSpec {
    /// A fleet of `n` identical instances (the legacy configuration).
    pub fn homogeneous(gpu: GpuProfile, engine: EngineConfig, speed: f64, n: usize) -> Self {
        Self { instances: vec![InstanceSpec { gpu, engine, speed, tp: 1 }; n] }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// True when every instance shares one (GPU, engine, speed) — the
    /// capacity-normalized paths then reduce exactly to the legacy
    /// uniform behavior.
    pub fn is_homogeneous(&self) -> bool {
        self.instances.windows(2).all(|w| w[0] == w[1])
    }

    /// Per-instance GPU names, in instance-id order (report tags).
    pub fn gpu_names(&self) -> Vec<&'static str> {
        self.instances.iter().map(|s| s.gpu.name).collect()
    }

    /// Per-instance tensor-parallel degrees, in instance-id order.
    pub fn tp_degrees(&self) -> Vec<u32> {
        self.instances.iter().map(|s| s.tp).collect()
    }

    /// True when any instance is tensor-parallel sharded (`tp > 1`) —
    /// the gate that routes planning through the TP-aware DP.  TP-free
    /// fleets take the exact legacy code paths.
    pub fn has_tensor_parallel(&self) -> bool {
        self.instances.iter().any(|s| s.tp > 1)
    }

    /// The fleet's reference instance for shared calibration (QoE
    /// profiling fits one model): the majority GPU, ties broken by
    /// earliest appearance.  A homogeneous fleet returns its only kind.
    pub fn reference(&self) -> &InstanceSpec {
        assert!(!self.instances.is_empty(), "fleet must have instances");
        let mut best = &self.instances[0];
        let mut best_count = 0usize;
        for s in &self.instances {
            let count = self.instances.iter().filter(|o| o.gpu.name == s.gpu.name).count();
            if count > best_count {
                best = s;
                best_count = count;
            }
        }
        best
    }

    /// Raw per-instance capacities (modeled reference throughput, TP
    /// collectives at the NVLink default).
    pub fn capacities(&self, model: &ModelProfile) -> Vec<f64> {
        self.instances.iter().map(|s| s.reference_throughput(model)).collect()
    }

    /// Capacities normalized to the fleet maximum, in (0, 1].  A
    /// homogeneous fleet yields exactly 1.0 per instance (x/x == 1.0
    /// in IEEE 754), so `load / cap` is bit-identical to the raw load
    /// and the legacy uniform behavior is preserved bit-for-bit.
    pub fn normalized_capacities(&self, model: &ModelProfile) -> Vec<f64> {
        Self::normalize(self.capacities(model))
    }

    /// [`FleetSpec::normalized_capacities`] with TP collectives priced
    /// over `link` — what the cluster uses, so capacity weights agree
    /// with the per-instance cost backends on the same topology.
    /// Identical to the NVLink default for TP-free fleets.
    pub fn normalized_capacities_with_link(
        &self,
        model: &ModelProfile,
        link: LinkKind,
    ) -> Vec<f64> {
        Self::normalize(
            self.instances
                .iter()
                .map(|s| s.reference_throughput_with_link(model, link))
                .collect(),
        )
    }

    /// Collective-free capacities normalized to the fleet maximum —
    /// the TP-aware planner's weights (see
    /// [`InstanceSpec::plan_capacity`] for why collectives are
    /// excluded here).
    pub fn plan_capacities(&self, model: &ModelProfile) -> Vec<f64> {
        Self::normalize(self.instances.iter().map(|s| s.plan_capacity(model)).collect())
    }

    fn normalize(raw: Vec<f64>) -> Vec<f64> {
        let max = raw.iter().copied().fold(f64::MIN, f64::max);
        assert!(max.is_finite() && max > 0.0, "fleet capacities must be positive");
        raw.into_iter().map(|c| c / max).collect()
    }

    /// Parse the `--fleet` grammar: comma-separated `GPU:COUNT` groups
    /// (count defaults to 1), each optionally followed by `speed=F` /
    /// `tp=N` options that apply to the group just announced.
    ///
    /// `h20:6,h100:2` — 6 H20s then 2 H100s.
    /// `h20:12,h100:4,speed=1.37` — the H100s run a 1.37x engine.
    /// `h20:4,tp=2,h20:2,tp=4` — four TP2 slices, then two TP4 slices.
    ///
    /// Malformed options — unknown keys, non-positive `tp`, bad
    /// numbers — are hard errors listing the valid keys (the same
    /// policy as unknown `--gpu`/`--model` names: never a silent
    /// fallback).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut instances: Vec<InstanceSpec> = Vec::new();
        let mut last_group: Option<(usize, usize)> = None; // [start, end) of the last group
        if s.trim().is_empty() {
            return Err("fleet spec is empty; expected e.g. h20:6,h100:2".into());
        }
        for seg in s.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                return Err(format!("empty fleet segment in `{s}`"));
            }
            if let Some((key, value)) = seg.split_once('=') {
                let (key, value) = (key.trim(), value.trim());
                let Some((start, end)) = last_group else {
                    return Err(format!(
                        "fleet option `{seg}` must follow a GPU:COUNT group"
                    ));
                };
                match key {
                    "speed" => {
                        let speed = value
                            .parse::<f64>()
                            .ok()
                            .filter(|v| *v > 0.0 && v.is_finite())
                            .ok_or_else(|| {
                                format!("fleet speed `{value}` is not a positive number")
                            })?;
                        for spec in &mut instances[start..end] {
                            spec.speed = speed;
                        }
                    }
                    "tp" => {
                        let tp = value
                            .parse::<u32>()
                            .ok()
                            .filter(|&v| v >= 1)
                            .ok_or_else(|| {
                                format!(
                                    "fleet tp `{value}` is not a positive integer \
                                     (tensor-parallel degree, e.g. tp=4)"
                                )
                            })?;
                        for spec in &mut instances[start..end] {
                            spec.tp = tp;
                        }
                    }
                    _ => {
                        return Err(format!(
                            "unknown fleet option `{key}`; valid: speed, tp"
                        ))
                    }
                }
                continue;
            }
            let (gpu_name, count) = match seg.split_once(':') {
                Some((g, c)) => {
                    let count = c.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(
                        || format!("fleet count `{c}` in `{seg}` is not a positive integer"),
                    )?;
                    (g.trim(), count)
                }
                None => (seg, 1),
            };
            let gpu = GpuProfile::by_name(gpu_name).ok_or_else(|| {
                format!(
                    "unknown fleet gpu `{gpu_name}`; valid: {}",
                    GpuProfile::NAMES.join("|")
                )
            })?;
            let start = instances.len();
            for _ in 0..count {
                instances.push(InstanceSpec::new(gpu));
            }
            last_group = Some((start, instances.len()));
        }
        Ok(Self { instances })
    }
}

impl fmt::Display for FleetSpec {
    /// Canonical run-length serialization:
    /// `H20:6,H100:2,speed=1.37` / `H20:4,tp=2,H20:2,tp=4`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut i = 0;
        while i < self.instances.len() {
            let spec = &self.instances[i];
            let mut j = i + 1;
            while j < self.instances.len() && self.instances[j] == *spec {
                j += 1;
            }
            if !first {
                write!(f, ",")?;
            }
            first = false;
            write!(f, "{}:{}", spec.gpu.name, j - i)?;
            if spec.speed != 1.0 {
                write!(f, ",speed={}", spec.speed)?;
            }
            if spec.tp != 1 {
                write!(f, ",tp={}", spec.tp)?;
            }
            i = j;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LLAMA_3B;

    #[test]
    fn parse_counts_and_order() {
        let f = FleetSpec::parse("h20:6,h100:2").unwrap();
        assert_eq!(f.len(), 8);
        assert!(f.instances[..6].iter().all(|s| s.gpu.name == "H20"));
        assert!(f.instances[6..].iter().all(|s| s.gpu.name == "H100"));
        assert!(!f.is_homogeneous());
    }

    #[test]
    fn parse_speed_applies_to_preceding_group() {
        let f = FleetSpec::parse("h20:12,h100:4,speed=1.37").unwrap();
        assert_eq!(f.len(), 16);
        assert!(f.instances[..12].iter().all(|s| s.speed == 1.0));
        assert!(f.instances[12..].iter().all(|s| s.speed == 1.37));
    }

    #[test]
    fn parse_bare_gpu_is_count_one() {
        let f = FleetSpec::parse("L40").unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f.instances[0].gpu.name, "L40");
        assert!(f.is_homogeneous());
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "h20:0",
            "h20:-1",
            "h20:two",
            "a100:4",
            "speed=1.2",
            "h20:2,speed=fast",
            "h20:2,speed=-1",
            "h20:2,turbo=on",
            "h20:2,,h100:1",
            "tp=2",
            "h20:2,tp=0",
            "h20:2,tp=-2",
            "h20:2,tp=four",
            "h20:2,tp=1.5",
            "h20:2,tp=",
        ] {
            let e = FleetSpec::parse(bad);
            assert!(e.is_err(), "`{bad}` should be rejected");
        }
        // Unknown GPUs name the valid choices.
        let msg = FleetSpec::parse("a100:4").unwrap_err();
        assert!(msg.contains("H20|L40|H100"), "{msg}");
        // Unknown option keys list the valid keys (hard-error policy).
        let msg = FleetSpec::parse("h20:2,turbo=on").unwrap_err();
        assert!(msg.contains("speed") && msg.contains("tp"), "{msg}");
        // A bad tp value says what a tp is.
        let msg = FleetSpec::parse("h20:2,tp=0").unwrap_err();
        assert!(msg.contains("tensor-parallel"), "{msg}");
        // Options before any group are rejected for tp like for speed.
        let msg = FleetSpec::parse("tp=2").unwrap_err();
        assert!(msg.contains("must follow"), "{msg}");
    }

    #[test]
    fn parse_tp_applies_to_preceding_group() {
        let f = FleetSpec::parse("h20:4,tp=2,h20:2,tp=4").unwrap();
        assert_eq!(f.len(), 6);
        assert!(f.instances[..4].iter().all(|s| s.tp == 2));
        assert!(f.instances[4..].iter().all(|s| s.tp == 4));
        assert!(f.has_tensor_parallel());
        assert_eq!(f.tp_degrees(), vec![2, 2, 2, 2, 4, 4]);
        // tp=1 is explicit legacy: no TP anywhere.
        let f = FleetSpec::parse("h20:4,tp=1").unwrap();
        assert!(!f.has_tensor_parallel());
        assert!(f.instances.iter().all(|s| s.tp == 1));
    }

    #[test]
    fn parse_speed_and_tp_combine_in_any_order() {
        let a = FleetSpec::parse("h100:4,speed=1.25,tp=4").unwrap();
        let b = FleetSpec::parse("h100:4,tp=4,speed=1.25").unwrap();
        assert_eq!(a, b);
        assert!(a.instances.iter().all(|s| s.speed == 1.25 && s.tp == 4));
        // Options bind to their own group only.
        let f = FleetSpec::parse("h20:2,tp=2,h100:1,speed=1.5").unwrap();
        assert_eq!(f.instances[0].tp, 2);
        assert_eq!(f.instances[0].speed, 1.0);
        assert_eq!(f.instances[2].tp, 1);
        assert_eq!(f.instances[2].speed, 1.5);
    }

    #[test]
    fn display_round_trips() {
        for s in [
            "H20:6,H100:2",
            "H20:12,H100:4,speed=1.37",
            "L40:1",
            "H20:4,tp=2,H20:2,tp=4",
            "H100:2,speed=1.25,tp=4",
        ] {
            let f = FleetSpec::parse(s).unwrap();
            assert_eq!(f.to_string(), s);
            assert_eq!(FleetSpec::parse(&f.to_string()).unwrap(), f);
        }
    }

    #[test]
    fn homogeneous_capacities_normalize_to_exactly_one() {
        let f = FleetSpec::homogeneous(GpuProfile::H20, EngineConfig::default(), 1.0, 5);
        let caps = f.normalized_capacities(&LLAMA_3B);
        assert!(caps.iter().all(|&c| c == 1.0), "{caps:?}");
        assert!(f.is_homogeneous());
    }

    #[test]
    fn h100_outranks_h20_on_reference_mix() {
        // The H100's compute advantage dominates the reference mix
        // (prefill is compute-bound), despite the H20's fatter HBM.
        let h20 = InstanceSpec::new(GpuProfile::H20).reference_throughput(&LLAMA_3B);
        let h100 = InstanceSpec::new(GpuProfile::H100).reference_throughput(&LLAMA_3B);
        assert!(
            h100 > 1.5 * h20,
            "expected H100 ({h100:.0} tok/s) well above H20 ({h20:.0} tok/s)"
        );
    }

    #[test]
    fn speed_scales_capacity_linearly() {
        let base = InstanceSpec::new(GpuProfile::H20).reference_throughput(&LLAMA_3B);
        let fast = InstanceSpec::new(GpuProfile::H20)
            .with_speed(1.25)
            .reference_throughput(&LLAMA_3B);
        assert!((fast / base - 1.25).abs() < 1e-12);
    }

    #[test]
    fn reference_is_majority_gpu() {
        let f = FleetSpec::parse("h20:6,h100:2").unwrap();
        assert_eq!(f.reference().gpu.name, "H20");
        let f = FleetSpec::parse("h100:3,h20:1").unwrap();
        assert_eq!(f.reference().gpu.name, "H100");
        // Tie: earliest appearance wins.
        let f = FleetSpec::parse("l40:2,h20:2").unwrap();
        assert_eq!(f.reference().gpu.name, "L40");
    }

    #[test]
    fn model_for_resolves_tp_and_preserves_legacy() {
        use crate::models::llama_70b;
        let base = llama_70b(1);
        // tp=1 returns the base untouched — even a base that already
        // carries a degree (the legacy model-level TP configurations).
        assert_eq!(InstanceSpec::new(GpuProfile::H20).model_for(&base), base);
        assert_eq!(
            InstanceSpec::new(GpuProfile::H20).model_for(&llama_70b(2)),
            llama_70b(2)
        );
        // tp>1 overrides whatever the base carries.
        let tp4 = InstanceSpec::new(GpuProfile::H20).with_tp(4);
        assert_eq!(tp4.model_for(&base), llama_70b(4));
        assert_eq!(tp4.model_for(&llama_70b(2)), llama_70b(4));
    }

    #[test]
    fn tp_sharding_raises_70b_capacity_sublinearly() {
        use crate::models::llama_70b;
        let base = llama_70b(1);
        let t1 = InstanceSpec::new(GpuProfile::H20).reference_throughput(&base);
        let t2 = InstanceSpec::new(GpuProfile::H20).with_tp(2).reference_throughput(&base);
        let t4 = InstanceSpec::new(GpuProfile::H20).with_tp(4).reference_throughput(&base);
        assert!(t1 < t2 && t2 < t4, "{t1} {t2} {t4}");
        // All-reduce premium: sharding never scales linearly.
        assert!(t4 < 4.0 * t1, "tp4 {t4} vs 4x tp1 {t1}");
    }

    #[test]
    fn plan_capacity_excludes_collectives() {
        use crate::models::llama_70b;
        let base = llama_70b(1);
        let tp4 = InstanceSpec::new(GpuProfile::H20).with_tp(4);
        // The planner weight strips the all-reduce premium (the DP
        // charges it separately), so it must exceed the comm-inclusive
        // throughput for a sharded instance...
        assert!(tp4.plan_capacity(&base) > tp4.reference_throughput(&base));
        // ...and match it exactly for a TP1 instance (both collective
        // terms are exactly 0.0).
        let tp1 = InstanceSpec::new(GpuProfile::H20);
        assert_eq!(
            tp1.plan_capacity(&LLAMA_3B).to_bits(),
            tp1.reference_throughput(&LLAMA_3B).to_bits()
        );
        // The link-aware variant agrees with the default at NVLink and
        // drops on slower links for sharded instances only.
        assert_eq!(
            tp4.reference_throughput_with_link(&base, LinkKind::NvLink).to_bits(),
            tp4.reference_throughput(&base).to_bits()
        );
        assert!(
            tp4.reference_throughput_with_link(&base, LinkKind::Pcie)
                < tp4.reference_throughput(&base)
        );
        assert_eq!(
            tp1.reference_throughput_with_link(&LLAMA_3B, LinkKind::Pcie).to_bits(),
            tp1.reference_throughput(&LLAMA_3B).to_bits()
        );
    }

    #[test]
    fn tp_comm_weight_is_zero_only_without_sharding() {
        use crate::models::llama_70b;
        let base = llama_70b(1);
        let tp1 = InstanceSpec::new(GpuProfile::H20);
        assert_eq!(tp1.tp_comm_s_per_token(&base, LinkKind::NvLink), 0.0);
        let tp4 = tp1.with_tp(4);
        let nv = tp4.tp_comm_s_per_token(&base, LinkKind::NvLink);
        let pcie = tp4.tp_comm_s_per_token(&base, LinkKind::Pcie);
        assert!(nv > 0.0);
        assert!(pcie > nv, "slower TP links must cost more: {pcie} vs {nv}");
        // A tp=1 instance serving an already-sliced base still pays
        // that slice's collectives.
        assert!(tp1.tp_comm_s_per_token(&llama_70b(2), LinkKind::NvLink) > 0.0);
    }

    #[test]
    fn mixed_fleet_normalized_caps_ordered() {
        let f = FleetSpec::parse("h20:2,h100:2").unwrap();
        let caps = f.normalized_capacities(&LLAMA_3B);
        assert_eq!(caps[2], 1.0);
        assert_eq!(caps[3], 1.0);
        assert!(caps[0] < 1.0 && caps[0] > 0.0);
        assert_eq!(caps[0], caps[1]);
    }
}
