//! Heterogeneous fleet description — per-instance GPU/engine/speed.
//!
//! The paper's testbeds are homogeneous (16 identical H20s or L40s),
//! but real multi-instance deployments mix GPU generations and engine
//! builds: the paper itself models a faster Llumnix engine with a
//! scalar `engine_speed` (§6.2 Fig. 8), and UELLM / slice-level
//! scheduling motivate serving across non-uniform resources.  This
//! module makes the fleet a first-class value:
//!
//! * [`InstanceSpec`] — one instance's hardware + runtime: a
//!   [`GpuProfile`], an [`EngineConfig`], and a relative engine speed.
//! * [`FleetSpec`] — the ordered instance list.  Order matters: the
//!   planner assigns instances to pipeline stages contiguously (the §5
//!   placement optimization), so `h20:6,h100:2` puts the H100s on the
//!   long-sequence end of the pipeline.
//!
//! The CLI grammar (`--fleet`) is a comma-separated list of
//! `GPU:COUNT` groups, each optionally followed by `speed=F` options
//! applying to the group, e.g. `h20:12,h100:4,speed=1.37` (12 stock
//! H20s plus 4 H100s running a 1.37x-faster engine build).
//!
//! Capacity: [`InstanceSpec::reference_throughput`] prices a reference
//! serving mix (prefill + steady-state decode) with the same analytic
//! cost model the engines execute under, so "capacity" is consistent
//! with what the simulator will actually measure.  The cluster
//! normalizes capacities to the fleet maximum; a homogeneous fleet
//! therefore gets exactly 1.0 everywhere and every capacity-normalized
//! code path reduces bit-identically to the legacy uniform one.

use crate::engine::EngineConfig;
use crate::gpu::GpuProfile;
use crate::kernelmodel::AttentionModel;
use crate::models::ModelProfile;
use crate::Tokens;

use std::fmt;

/// One instance's hardware + runtime configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceSpec {
    pub gpu: GpuProfile,
    /// Engine knobs; a `None` KV capacity is derived from *this
    /// instance's* GPU memory budget.
    pub engine: EngineConfig,
    /// Relative engine speed (1.0 = vLLM-class).  Composes with
    /// `ClusterConfig::engine_speed`, which acts as a fleet-wide
    /// multiplier (so policy-level speeds like Llumnix's 1.25 apply on
    /// top of per-instance hardware speeds).
    pub speed: f64,
}

/// Reference serving mix used to price relative capacity: a 1024-token
/// prompt producing 256 output tokens, decoded in a 64-deep batch of
/// 1280-token rows.  Chosen to exercise both the compute-bound prefill
/// regime (where an H100 crushes an H20) and the bandwidth-bound decode
/// regime (where the H20's fat HBM nearly evens the score).
const REF_INPUT: Tokens = 1024;
const REF_OUTPUT: f64 = 256.0;
const REF_BATCH: usize = 64;
const REF_ROW_LEN: Tokens = 1280;

impl InstanceSpec {
    pub fn new(gpu: GpuProfile) -> Self {
        Self { gpu, engine: EngineConfig::default(), speed: 1.0 }
    }

    pub fn with_speed(mut self, speed: f64) -> Self {
        self.speed = speed;
        self
    }

    /// Modeled output tokens/s of this instance on the reference
    /// serving mix — the capacity weight the planner, router, and
    /// bid-ask balancer normalize load by.  Deterministic (pure cost
    /// model, no profiling runs).
    pub fn reference_throughput(&self, model: &ModelProfile) -> f64 {
        let am = AttentionModel::new(self.gpu, *model);
        let t_prefill = am.prefill_latency(REF_INPUT);
        let t_iter = am.decode_iteration_latency(&[REF_ROW_LEN; REF_BATCH]);
        // Steady state: the prefill's compute is serialized per request,
        // decode tokens are amortized over the batch.
        let per_request = t_prefill + REF_OUTPUT * t_iter / REF_BATCH as f64;
        self.speed * REF_OUTPUT / per_request
    }
}

/// The ordered fleet: one [`InstanceSpec`] per instance id.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub instances: Vec<InstanceSpec>,
}

impl FleetSpec {
    /// A fleet of `n` identical instances (the legacy configuration).
    pub fn homogeneous(gpu: GpuProfile, engine: EngineConfig, speed: f64, n: usize) -> Self {
        Self { instances: vec![InstanceSpec { gpu, engine, speed }; n] }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// True when every instance shares one (GPU, engine, speed) — the
    /// capacity-normalized paths then reduce exactly to the legacy
    /// uniform behavior.
    pub fn is_homogeneous(&self) -> bool {
        self.instances.windows(2).all(|w| w[0] == w[1])
    }

    /// Per-instance GPU names, in instance-id order (report tags).
    pub fn gpu_names(&self) -> Vec<&'static str> {
        self.instances.iter().map(|s| s.gpu.name).collect()
    }

    /// The fleet's reference instance for shared calibration (QoE
    /// profiling fits one model): the majority GPU, ties broken by
    /// earliest appearance.  A homogeneous fleet returns its only kind.
    pub fn reference(&self) -> &InstanceSpec {
        assert!(!self.instances.is_empty(), "fleet must have instances");
        let mut best = &self.instances[0];
        let mut best_count = 0usize;
        for s in &self.instances {
            let count = self.instances.iter().filter(|o| o.gpu.name == s.gpu.name).count();
            if count > best_count {
                best = s;
                best_count = count;
            }
        }
        best
    }

    /// Raw per-instance capacities (modeled reference throughput).
    pub fn capacities(&self, model: &ModelProfile) -> Vec<f64> {
        self.instances.iter().map(|s| s.reference_throughput(model)).collect()
    }

    /// Capacities normalized to the fleet maximum, in (0, 1].  A
    /// homogeneous fleet yields exactly 1.0 per instance (x/x == 1.0
    /// in IEEE 754), so `load / cap` is bit-identical to the raw load
    /// and the legacy uniform behavior is preserved bit-for-bit.
    pub fn normalized_capacities(&self, model: &ModelProfile) -> Vec<f64> {
        let raw = self.capacities(model);
        let max = raw.iter().copied().fold(f64::MIN, f64::max);
        assert!(max.is_finite() && max > 0.0, "fleet capacities must be positive");
        raw.into_iter().map(|c| c / max).collect()
    }

    /// Parse the `--fleet` grammar: comma-separated `GPU:COUNT` groups
    /// (count defaults to 1), each optionally followed by `speed=F`
    /// options that apply to the group just announced.
    ///
    /// `h20:6,h100:2` — 6 H20s then 2 H100s.
    /// `h20:12,h100:4,speed=1.37` — the H100s run a 1.37x engine.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut instances: Vec<InstanceSpec> = Vec::new();
        let mut last_group: Option<(usize, usize)> = None; // [start, end) of the last group
        if s.trim().is_empty() {
            return Err("fleet spec is empty; expected e.g. h20:6,h100:2".into());
        }
        for seg in s.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                return Err(format!("empty fleet segment in `{s}`"));
            }
            if let Some((key, value)) = seg.split_once('=') {
                let (key, value) = (key.trim(), value.trim());
                let Some((start, end)) = last_group else {
                    return Err(format!(
                        "fleet option `{seg}` must follow a GPU:COUNT group"
                    ));
                };
                match key {
                    "speed" => {
                        let speed = value
                            .parse::<f64>()
                            .ok()
                            .filter(|v| *v > 0.0 && v.is_finite())
                            .ok_or_else(|| {
                                format!("fleet speed `{value}` is not a positive number")
                            })?;
                        for spec in &mut instances[start..end] {
                            spec.speed = speed;
                        }
                    }
                    _ => {
                        return Err(format!(
                            "unknown fleet option `{key}`; valid: speed"
                        ))
                    }
                }
                continue;
            }
            let (gpu_name, count) = match seg.split_once(':') {
                Some((g, c)) => {
                    let count = c.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(
                        || format!("fleet count `{c}` in `{seg}` is not a positive integer"),
                    )?;
                    (g.trim(), count)
                }
                None => (seg, 1),
            };
            let gpu = GpuProfile::by_name(gpu_name).ok_or_else(|| {
                format!(
                    "unknown fleet gpu `{gpu_name}`; valid: {}",
                    GpuProfile::NAMES.join("|")
                )
            })?;
            let start = instances.len();
            for _ in 0..count {
                instances.push(InstanceSpec::new(gpu));
            }
            last_group = Some((start, instances.len()));
        }
        Ok(Self { instances })
    }
}

impl fmt::Display for FleetSpec {
    /// Canonical run-length serialization: `H20:6,H100:2,speed=1.37`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut i = 0;
        while i < self.instances.len() {
            let spec = &self.instances[i];
            let mut j = i + 1;
            while j < self.instances.len() && self.instances[j] == *spec {
                j += 1;
            }
            if !first {
                write!(f, ",")?;
            }
            first = false;
            write!(f, "{}:{}", spec.gpu.name, j - i)?;
            if spec.speed != 1.0 {
                write!(f, ",speed={}", spec.speed)?;
            }
            i = j;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LLAMA_3B;

    #[test]
    fn parse_counts_and_order() {
        let f = FleetSpec::parse("h20:6,h100:2").unwrap();
        assert_eq!(f.len(), 8);
        assert!(f.instances[..6].iter().all(|s| s.gpu.name == "H20"));
        assert!(f.instances[6..].iter().all(|s| s.gpu.name == "H100"));
        assert!(!f.is_homogeneous());
    }

    #[test]
    fn parse_speed_applies_to_preceding_group() {
        let f = FleetSpec::parse("h20:12,h100:4,speed=1.37").unwrap();
        assert_eq!(f.len(), 16);
        assert!(f.instances[..12].iter().all(|s| s.speed == 1.0));
        assert!(f.instances[12..].iter().all(|s| s.speed == 1.37));
    }

    #[test]
    fn parse_bare_gpu_is_count_one() {
        let f = FleetSpec::parse("L40").unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f.instances[0].gpu.name, "L40");
        assert!(f.is_homogeneous());
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "h20:0",
            "h20:-1",
            "h20:two",
            "a100:4",
            "speed=1.2",
            "h20:2,speed=fast",
            "h20:2,speed=-1",
            "h20:2,turbo=on",
            "h20:2,,h100:1",
        ] {
            let e = FleetSpec::parse(bad);
            assert!(e.is_err(), "`{bad}` should be rejected");
        }
        // Unknown GPUs name the valid choices.
        let msg = FleetSpec::parse("a100:4").unwrap_err();
        assert!(msg.contains("H20|L40|H100"), "{msg}");
    }

    #[test]
    fn display_round_trips() {
        for s in ["H20:6,H100:2", "H20:12,H100:4,speed=1.37", "L40:1"] {
            let f = FleetSpec::parse(s).unwrap();
            assert_eq!(f.to_string(), s);
            assert_eq!(FleetSpec::parse(&f.to_string()).unwrap(), f);
        }
    }

    #[test]
    fn homogeneous_capacities_normalize_to_exactly_one() {
        let f = FleetSpec::homogeneous(GpuProfile::H20, EngineConfig::default(), 1.0, 5);
        let caps = f.normalized_capacities(&LLAMA_3B);
        assert!(caps.iter().all(|&c| c == 1.0), "{caps:?}");
        assert!(f.is_homogeneous());
    }

    #[test]
    fn h100_outranks_h20_on_reference_mix() {
        // The H100's compute advantage dominates the reference mix
        // (prefill is compute-bound), despite the H20's fatter HBM.
        let h20 = InstanceSpec::new(GpuProfile::H20).reference_throughput(&LLAMA_3B);
        let h100 = InstanceSpec::new(GpuProfile::H100).reference_throughput(&LLAMA_3B);
        assert!(
            h100 > 1.5 * h20,
            "expected H100 ({h100:.0} tok/s) well above H20 ({h20:.0} tok/s)"
        );
    }

    #[test]
    fn speed_scales_capacity_linearly() {
        let base = InstanceSpec::new(GpuProfile::H20).reference_throughput(&LLAMA_3B);
        let fast = InstanceSpec::new(GpuProfile::H20)
            .with_speed(1.25)
            .reference_throughput(&LLAMA_3B);
        assert!((fast / base - 1.25).abs() < 1e-12);
    }

    #[test]
    fn reference_is_majority_gpu() {
        let f = FleetSpec::parse("h20:6,h100:2").unwrap();
        assert_eq!(f.reference().gpu.name, "H20");
        let f = FleetSpec::parse("h100:3,h20:1").unwrap();
        assert_eq!(f.reference().gpu.name, "H100");
        // Tie: earliest appearance wins.
        let f = FleetSpec::parse("l40:2,h20:2").unwrap();
        assert_eq!(f.reference().gpu.name, "L40");
    }

    #[test]
    fn mixed_fleet_normalized_caps_ordered() {
        let f = FleetSpec::parse("h20:2,h100:2").unwrap();
        let caps = f.normalized_capacities(&LLAMA_3B);
        assert_eq!(caps[2], 1.0);
        assert_eq!(caps[3], 1.0);
        assert!(caps[0] < 1.0 && caps[0] > 0.0);
        assert_eq!(caps[0], caps[1]);
    }
}
