//! Workload generation — ShareGPT-like traffic with a long-context tail.
//!
//! The paper builds workloads from the ShareGPT52K dialogue dataset
//! (requests longer than 128K discarded) with Poisson arrivals (§6.1).
//! That dataset is not available offline, so this module synthesises a
//! distribution with the same *scheduling-relevant* shape (Fig. 1):
//! highly skewed — many short requests, a fat lognormal body, and a
//! rare-but-present Pareto tail reaching the 128K context limit.
//! All draws are seeded; traces can be saved/loaded as CSV so every
//! figure regenerates from the identical request set.

use crate::sim::{Exponential, LogNormal, ParetoTail, Rng};
use crate::{RequestId, Time, Tokens};

/// One inference request as the scheduler sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time (seconds since run start).
    pub arrival: Time,
    /// Prompt length in tokens.
    pub input_len: Tokens,
    /// Number of tokens the request will generate (ground truth known
    /// to the generator, *not* to the scheduler).
    pub output_len: Tokens,
}

impl Request {
    /// Total sequence length once fully decoded.
    pub fn final_len(&self) -> Tokens {
        self.input_len + self.output_len
    }
}

/// Parameters of the synthetic ShareGPT-like distribution.
#[derive(Debug, Clone, Copy)]
pub struct ShareGptLike {
    /// Median / sigma of the lognormal input-length body.
    pub input_median: f64,
    pub input_sigma: f64,
    /// Median / sigma of the lognormal output-length body.
    pub output_median: f64,
    pub output_sigma: f64,
    /// Probability a request comes from the long-context tail.
    pub tail_prob: f64,
    /// Pareto tail start / shape for the long-context inputs.
    pub tail_min: f64,
    pub tail_alpha: f64,
    /// Hard cap (the paper discards > 128K).
    pub max_len: Tokens,
}

impl Default for ShareGptLike {
    fn default() -> Self {
        // Medians follow the published ShareGPT statistics used by the
        // vLLM paper (mean input ~161, mean output ~338 tokens), with
        // the long-context tail the paper's Fig.1 adds on top.
        Self {
            input_median: 96.0,
            input_sigma: 1.1,
            output_median: 250.0,
            output_sigma: 0.9,
            tail_prob: 0.03,
            tail_min: 4096.0,
            tail_alpha: 0.9,
            max_len: 131_072,
        }
    }
}

impl ShareGptLike {
    /// A variant with a heavier tail, for stress ablations.
    pub fn heavy_tail() -> Self {
        Self { tail_prob: 0.08, tail_alpha: 0.7, ..Self::default() }
    }

    /// Short-context-only variant (the "uniform lengths" limitation
    /// scenario of §8).
    pub fn uniform_short() -> Self {
        Self { tail_prob: 0.0, input_sigma: 0.3, output_sigma: 0.3, ..Self::default() }
    }

    fn sample_input(&self, rng: &mut Rng) -> Tokens {
        let body = LogNormal::from_median(self.input_median, self.input_sigma);
        let tail = ParetoTail::new(self.tail_min, self.tail_alpha);
        // The paper *discards* requests longer than the context window
        // (Fig. 1 caption) — emulate by rejection-sampling the tail so
        // no probability mass piles up at max_len.
        let cap = self.max_len.saturating_sub(1024).max(1);
        for _ in 0..16 {
            let raw = if rng.next_f64() < self.tail_prob {
                tail.sample(rng)
            } else {
                body.sample(rng)
            };
            let t = raw.round() as Tokens;
            if t >= 1 && t <= cap {
                return t.max(1);
            }
        }
        cap / 2 // pathological distribution: fall back mid-range
    }

    fn sample_output(&self, rng: &mut Rng, input: Tokens) -> Tokens {
        let body = LogNormal::from_median(self.output_median, self.output_sigma);
        let raw = body.sample(rng).round() as Tokens;
        raw.clamp(1, self.max_len.saturating_sub(input).max(1))
    }
}

/// Generate `n` requests with Poisson arrivals at `rate` req/s.
///
/// Implemented by collecting [`WorkloadStream::poisson`], so the
/// materialized and streaming paths are request-identical by
/// construction.
pub fn generate(dist: &ShareGptLike, rate: f64, n: usize, seed: u64) -> Vec<Request> {
    WorkloadStream::poisson(*dist, rate, n, seed)
        .map(|r| r.expect("generator streams never fail"))
        .collect()
}

/// Generate requests covering a fixed duration instead of a count.
pub fn generate_for_duration(dist: &ShareGptLike, rate: f64, duration: Time, seed: u64) -> Vec<Request> {
    WorkloadStream::poisson_for_duration(*dist, rate, duration, seed)
        .map(|r| r.expect("generator streams never fail"))
        .collect()
}

/// Lazily generated request stream — the O(1)-memory counterpart of
/// [`WorkloadSpec::generate`] for planet-scale traces that must never
/// be materialized.  Yields requests in arrival order with exactly the
/// RNG draw sequence of the materializing path (which is implemented by
/// collecting this stream, so fingerprint identity holds by
/// construction).  Generator-backed streams never yield `Err`; CSV
/// replay surfaces IO/parse errors in-band as `Err` items.
pub struct WorkloadStream {
    kind: StreamKind,
}

enum StreamKind {
    /// Steady Poisson arrivals from one distribution, count-bounded.
    Poisson {
        dist: ShareGptLike,
        rng: Rng,
        gap: Exponential,
        t: Time,
        next_id: RequestId,
        remaining: usize,
    },
    /// Steady Poisson arrivals covering a fixed duration.
    PoissonDuration {
        dist: ShareGptLike,
        rng: Rng,
        gap: Exponential,
        t: Time,
        next_id: RequestId,
        duration: Time,
        done: bool,
    },
    /// Weighted mixture: each request draws its component by weight.
    Mixture {
        parts: Vec<(f64, ShareGptLike)>,
        total: f64,
        rng: Rng,
        gap: Exponential,
        t: Time,
        next_id: RequestId,
        remaining: usize,
    },
    /// Piecewise-Poisson on/off arrivals.
    Bursty {
        dist: ShareGptLike,
        rate: f64,
        on_s: f64,
        off_s: f64,
        off_rate: f64,
        rng: Rng,
        t: Time,
        next_id: RequestId,
        remaining: usize,
    },
    /// CSV trace replay, one buffered line at a time.
    Csv {
        lines: std::iter::Enumerate<std::io::Lines<std::io::BufReader<std::fs::File>>>,
    },
}

impl WorkloadStream {
    /// Steady Poisson arrivals from `dist`: exactly `n` requests.
    pub fn poisson(dist: ShareGptLike, rate: f64, n: usize, seed: u64) -> Self {
        WorkloadStream {
            kind: StreamKind::Poisson {
                dist,
                rng: Rng::new(seed),
                gap: Exponential::new(rate),
                t: 0.0,
                next_id: 0,
                remaining: n,
            },
        }
    }

    /// Steady Poisson arrivals from `dist` covering `duration` seconds.
    pub fn poisson_for_duration(
        dist: ShareGptLike,
        rate: f64,
        duration: Time,
        seed: u64,
    ) -> Self {
        WorkloadStream {
            kind: StreamKind::PoissonDuration {
                dist,
                rng: Rng::new(seed),
                gap: Exponential::new(rate),
                t: 0.0,
                next_id: 0,
                duration,
                done: false,
            },
        }
    }

    /// Replay a trace CSV one buffered line at a time (O(1) memory).
    pub fn csv(path: &str) -> std::io::Result<Self> {
        use std::io::BufRead;
        let f = std::io::BufReader::new(std::fs::File::open(path)?);
        Ok(WorkloadStream { kind: StreamKind::Csv { lines: f.lines().enumerate() } })
    }
}

impl Iterator for WorkloadStream {
    type Item = std::io::Result<Request>;

    fn next(&mut self) -> Option<Self::Item> {
        match &mut self.kind {
            StreamKind::Poisson { dist, rng, gap, t, next_id, remaining } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                *t += gap.sample(rng);
                let input_len = dist.sample_input(rng);
                let output_len = dist.sample_output(rng, input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Ok(Request { id, arrival: *t, input_len, output_len }))
            }
            StreamKind::PoissonDuration { dist, rng, gap, t, next_id, duration, done } => {
                if *done {
                    return None;
                }
                *t += gap.sample(rng);
                if *t > *duration {
                    *done = true;
                    return None;
                }
                let input_len = dist.sample_input(rng);
                let output_len = dist.sample_output(rng, input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Ok(Request { id, arrival: *t, input_len, output_len }))
            }
            StreamKind::Mixture { parts, total, rng, gap, t, next_id, remaining } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                *t += gap.sample(rng);
                // Weighted component draw, then that component's length
                // distributions — the exact draw order of the
                // materializing path.
                let mut u = rng.next_f64() * *total;
                let mut dist = parts[parts.len() - 1].1;
                for (w, d) in parts.iter() {
                    u -= w.max(0.0);
                    if u <= 0.0 {
                        dist = *d;
                        break;
                    }
                }
                let input_len = dist.sample_input(rng);
                let output_len = dist.sample_output(rng, input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Ok(Request { id, arrival: *t, input_len, output_len }))
            }
            StreamKind::Bursty { dist, rate, on_s, off_s, off_rate, rng, t, next_id, remaining } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                let period = *on_s + *off_s;
                // Piecewise-Poisson: sample a gap at the current phase's
                // rate; when it crosses the phase boundary, advance to
                // the boundary and resample there.
                loop {
                    let phase_t = *t % period;
                    let (r, boundary) = if phase_t < *on_s {
                        (*rate, *on_s - phase_t)
                    } else {
                        (*off_rate, period - phase_t)
                    };
                    let g = Exponential::new(r).sample(rng);
                    if g < boundary {
                        *t += g;
                        break;
                    }
                    *t += boundary;
                }
                let input_len = dist.sample_input(rng);
                let output_len = dist.sample_output(rng, input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Ok(Request { id, arrival: *t, input_len, output_len }))
            }
            StreamKind::Csv { lines } => loop {
                let (i, line) = lines.next()?;
                let line = match line {
                    Ok(l) => l,
                    Err(e) => return Some(Err(e)),
                };
                match parse_trace_line(i, &line) {
                    Ok(Some((req, _predicted))) => return Some(Ok(req)),
                    Ok(None) => continue,
                    Err(e) => return Some(Err(e)),
                }
            },
        }
    }
}

/// Declarative workload selection — the single vocabulary the
/// [`crate::experiment`] builder, the CLI (`--workload`), and config
/// files share.  Every variant generates the same [`Request`] stream
/// shape, fully determined by `(rate, n, seed)`.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// The paper's default ShareGPT-like distribution.
    ShareGpt(ShareGptLike),
    /// Heavier Pareto tail ([`ShareGptLike::heavy_tail`]).
    HeavyTail,
    /// Short-context-only ([`ShareGptLike::uniform_short`]).
    UniformShort,
    /// Replay a CSV trace saved by [`save_csv`] (arrivals, lengths and
    /// ids come from the file; `rate`/`n`/`seed` are ignored).
    CsvTrace(String),
    /// Mixture of distributions: each request draws its component by
    /// weight (weights need not sum to 1).
    Mixture(Vec<(f64, ShareGptLike)>),
    /// Bursty on/off arrivals: Poisson at `rate` for `on_s` seconds,
    /// then at `rate * off_rate_frac` for `off_s` seconds, repeating —
    /// the diurnal/bursty traffic scenario the steady Poisson default
    /// cannot express.
    Bursty { dist: ShareGptLike, on_s: f64, off_s: f64, off_rate_frac: f64 },
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec::ShareGpt(ShareGptLike::default())
    }
}

/// Invalid-parameter error for [`WorkloadSpec::generate`] (kept as
/// `io::Error` so the generation signature stays uniform with the
/// CSV-replay path).
fn invalid_spec(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidInput, msg)
}

impl WorkloadSpec {
    /// Canonical CLI/config names (plus `trace:FILE`).
    pub fn names() -> &'static [&'static str] {
        &["sharegpt", "heavytail", "uniformshort", "mix", "bursty", "trace:FILE"]
    }

    /// Parse a CLI/config workload name.
    pub fn parse(s: &str) -> Result<Self, String> {
        let trimmed = s.trim();
        let lower = trimmed.to_ascii_lowercase();
        // Prefix is case-insensitive (like every other name here); the
        // path keeps its original case.
        if lower.starts_with("trace:") {
            let path = &trimmed["trace:".len()..];
            if path.is_empty() {
                return Err("trace: needs a file path, e.g. trace:trace.csv".into());
            }
            return Ok(WorkloadSpec::CsvTrace(path.to_string()));
        }
        match lower.as_str() {
            "sharegpt" | "default" => Ok(WorkloadSpec::default()),
            "heavytail" | "heavy" => Ok(WorkloadSpec::HeavyTail),
            "uniformshort" | "short" => Ok(WorkloadSpec::UniformShort),
            "mix" | "mixture" => Ok(WorkloadSpec::Mixture(vec![
                (0.5, ShareGptLike::default()),
                (0.5, ShareGptLike::heavy_tail()),
            ])),
            "bursty" => Ok(WorkloadSpec::Bursty {
                dist: ShareGptLike::default(),
                on_s: 20.0,
                off_s: 20.0,
                off_rate_frac: 0.1,
            }),
            _ => Err(format!(
                "unknown workload `{s}`; valid: {}",
                Self::names().join("|")
            )),
        }
    }

    /// Open the spec as a lazy [`WorkloadStream`].  Fails on `CsvTrace`
    /// IO errors and on degenerate spec parameters (zero-mass mixtures,
    /// non-positive burst phases) — never panics on caller input.
    pub fn stream(&self, rate: f64, n: usize, seed: u64) -> std::io::Result<WorkloadStream> {
        let kind = match self {
            WorkloadSpec::ShareGpt(d) => return Ok(WorkloadStream::poisson(*d, rate, n, seed)),
            WorkloadSpec::HeavyTail => {
                return Ok(WorkloadStream::poisson(ShareGptLike::heavy_tail(), rate, n, seed))
            }
            WorkloadSpec::UniformShort => {
                return Ok(WorkloadStream::poisson(ShareGptLike::uniform_short(), rate, n, seed))
            }
            WorkloadSpec::CsvTrace(path) => return WorkloadStream::csv(path),
            WorkloadSpec::Mixture(parts) => {
                let total: f64 = parts.iter().map(|(w, _)| w.max(0.0)).sum();
                if total.is_nan() || total <= 0.0 {
                    return Err(invalid_spec("mixture weights must have positive mass"));
                }
                StreamKind::Mixture {
                    parts: parts.clone(),
                    total,
                    rng: Rng::new(seed),
                    gap: Exponential::new(rate),
                    t: 0.0,
                    next_id: 0,
                    remaining: n,
                }
            }
            WorkloadSpec::Bursty { dist, on_s, off_s, off_rate_frac } => {
                let phase_ok = |p: f64| p.is_finite() && p > 0.0;
                if !phase_ok(*on_s) || !phase_ok(*off_s) {
                    return Err(invalid_spec("burst phases must be positive"));
                }
                StreamKind::Bursty {
                    dist: *dist,
                    rate,
                    on_s: *on_s,
                    off_s: *off_s,
                    off_rate: (rate * off_rate_frac).max(1e-9),
                    rng: Rng::new(seed),
                    t: 0.0,
                    next_id: 0,
                    remaining: n,
                }
            }
        };
        Ok(WorkloadStream { kind })
    }

    /// Materialise the request stream — `self.stream(..)` collected, so
    /// the two paths yield bit-identical request sequences by
    /// construction.
    pub fn generate(&self, rate: f64, n: usize, seed: u64) -> std::io::Result<Vec<Request>> {
        self.stream(rate, n, seed)?.collect()
    }
}

/// Save a trace as CSV (`id,arrival,input_len,output_len`).
pub fn save_csv(path: &str, reqs: &[Request]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "id,arrival,input_len,output_len")?;
    for r in reqs {
        writeln!(f, "{},{:.6},{},{}", r.id, r.arrival, r.input_len, r.output_len)?;
    }
    Ok(())
}

/// Save a trace with a side-band predicted final length per request
/// (`id,arrival,input_len,output_len,predicted_len`).  The prediction
/// rides as an extra column rather than a [`Request`] field so the
/// scheduler-visible request type stays ground truth only; [`load_csv`]
/// reads these files too (ignoring the column), so prediction traces
/// stay drop-in everywhere a plain trace is accepted.
pub fn save_csv_predicted(
    path: &str,
    reqs: &[Request],
    predicted: &[Tokens],
) -> std::io::Result<()> {
    use std::io::Write;
    if reqs.len() != predicted.len() {
        return Err(invalid_spec(&format!(
            "predicted_len column has {} entries for {} requests",
            predicted.len(),
            reqs.len()
        )));
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "id,arrival,input_len,output_len,predicted_len")?;
    for (r, p) in reqs.iter().zip(predicted) {
        writeln!(f, "{},{:.6},{},{},{}", r.id, r.arrival, r.input_len, r.output_len, p)?;
    }
    Ok(())
}

/// Load a trace saved by [`save_csv`] or [`save_csv_predicted`],
/// discarding any predicted-length column.
pub fn load_csv(path: &str) -> std::io::Result<Vec<Request>> {
    Ok(load_csv_predicted(path)?.0)
}

/// Load a trace plus its optional predicted-length column: rows from a
/// [`save_csv_predicted`] file yield `Some(predicted_len)`, legacy
/// 4-column rows yield `None`.  Reads through a [`std::io::BufReader`]
/// one line at a time, so only the parsed rows (never the raw text) are
/// resident — multi-million-row traces load without a second copy of
/// the file in memory.
pub fn load_csv_predicted(path: &str) -> std::io::Result<(Vec<Request>, Vec<Option<Tokens>>)> {
    use std::io::BufRead;
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    let mut predicted = Vec::new();
    for (i, line) in f.lines().enumerate() {
        if let Some((req, pred)) = parse_trace_line(i, &line?)? {
            out.push(req);
            predicted.push(pred);
        }
    }
    Ok((out, predicted))
}

/// Parse one trace-CSV line — shared by the materializing loaders and
/// the streaming replay so both accept exactly the same files.  Returns
/// `Ok(None)` for the header row and blank lines.
fn parse_trace_line(
    i: usize,
    line: &str,
) -> std::io::Result<Option<(Request, Option<Tokens>)>> {
    if i == 0 && line.starts_with("id,") {
        return Ok(None);
    }
    if line.trim().is_empty() {
        return Ok(None);
    }
    let mut parts = line.split(',');
    let parse_err = || std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad trace line {i}: {line}"));
    let id = parts.next().and_then(|s| s.trim().parse().ok()).ok_or_else(parse_err)?;
    let arrival = parts.next().and_then(|s| s.trim().parse().ok()).ok_or_else(parse_err)?;
    let input_len = parts.next().and_then(|s| s.trim().parse().ok()).ok_or_else(parse_err)?;
    let output_len = parts.next().and_then(|s| s.trim().parse().ok()).ok_or_else(parse_err)?;
    // Optional 5th column; present -> it must parse.
    let predicted = match parts.next().map(str::trim).filter(|s| !s.is_empty()) {
        Some(s) => Some(s.parse().map_err(|_| parse_err())?),
        None => None,
    };
    Ok(Some((Request { id, arrival, input_len, output_len }, predicted)))
}

/// Count the data rows of a trace CSV in O(1) memory (header and blank
/// lines excluded) — the request total a streaming replay will deliver,
/// assuming every row parses.
pub fn count_csv_rows(path: &str) -> std::io::Result<usize> {
    use std::io::BufRead;
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut n = 0usize;
    for (i, line) in f.lines().enumerate() {
        let line = line?;
        if (i == 0 && line.starts_with("id,")) || line.trim().is_empty() {
            continue;
        }
        n += 1;
    }
    Ok(n)
}

/// Distribution summary used by planning: histogram of request counts
/// per exponential length bucket — the `n_{l',l}` of §4.2.
#[derive(Debug, Clone)]
pub struct LengthHistogram {
    /// Bucket upper bounds, ascending; bucket k covers
    /// [bounds[k-1], bounds[k]) with bounds[-1] = 0.
    pub bounds: Vec<Tokens>,
    /// Requests whose *final* length lands in each bucket, stored as
    /// (input_len, final_len) sums plus counts for QoE features.
    pub count: Vec<u64>,
    pub sum_input: Vec<f64>,
    pub sum_input_sq: Vec<f64>,
    pub sum_final: Vec<f64>,
}

impl LengthHistogram {
    /// Exponential bounds 2^k capped at `max_len` (§4.2's log-bucketing
    /// optimization: O(log L) candidate cut points).
    pub fn exponential_bounds(max_len: Tokens) -> Vec<Tokens> {
        let mut bounds = Vec::new();
        let mut b: Tokens = 2;
        while b < max_len {
            bounds.push(b);
            b *= 2;
        }
        bounds.push(max_len);
        bounds
    }

    pub fn new(bounds: Vec<Tokens>) -> Self {
        let n = bounds.len();
        Self {
            bounds,
            count: vec![0; n],
            sum_input: vec![0.0; n],
            sum_input_sq: vec![0.0; n],
            sum_final: vec![0.0; n],
        }
    }

    pub fn from_requests(reqs: &[Request], max_len: Tokens) -> Self {
        let mut h = Self::new(Self::exponential_bounds(max_len));
        for r in reqs {
            h.push(r.input_len, r.final_len());
        }
        h
    }

    pub fn bucket_of(&self, len: Tokens) -> usize {
        match self.bounds.binary_search(&len) {
            Ok(i) => (i + 1).min(self.bounds.len() - 1),
            Err(i) => i.min(self.bounds.len() - 1),
        }
    }

    pub fn push(&mut self, input_len: Tokens, final_len: Tokens) {
        let k = self.bucket_of(final_len);
        self.count[k] += 1;
        self.sum_input[k] += input_len as f64;
        self.sum_input_sq[k] += (input_len as f64) * (input_len as f64);
        self.sum_final[k] += final_len as f64;
    }

    pub fn total(&self) -> u64 {
        self.count.iter().sum()
    }

    /// Prefix sums over buckets [0, k): (count, sum_I, sum_I^2, sum_L).
    pub fn prefix(&self) -> Vec<(f64, f64, f64, f64)> {
        let mut acc = (0.0, 0.0, 0.0, 0.0);
        let mut out = vec![acc];
        for k in 0..self.bounds.len() {
            acc.0 += self.count[k] as f64;
            acc.1 += self.sum_input[k];
            acc.2 += self.sum_input_sq[k];
            acc.3 += self.sum_final[k];
            out.push(acc);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let d = ShareGptLike::default();
        let a = generate(&d, 10.0, 100, 42);
        let b = generate(&d, 10.0, 100, 42);
        assert_eq!(a, b);
        let c = generate(&d, 10.0, 100, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_are_increasing_poisson() {
        let reqs = generate(&ShareGptLike::default(), 20.0, 5000, 1);
        for w in reqs.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
        // Mean gap ~ 1/rate.
        let span = reqs.last().unwrap().arrival;
        let mean_gap = span / reqs.len() as f64;
        assert!((mean_gap * 20.0 - 1.0).abs() < 0.1, "gap {mean_gap}");
    }

    #[test]
    fn distribution_is_skewed_with_tail() {
        let reqs = generate(&ShareGptLike::default(), 10.0, 50_000, 7);
        let mut finals: Vec<u64> = reqs.iter().map(|r| r.final_len()).collect();
        finals.sort_unstable();
        let median = finals[finals.len() / 2];
        let p999 = finals[finals.len() * 999 / 1000];
        // Fig. 1 shape: median modest, extreme tail orders of magnitude up.
        assert!(median < 2_000, "median {median}");
        assert!(p999 > 10_000, "p99.9 {p999}");
        assert!(finals.iter().all(|&l| l <= 131_072));
        assert!(finals.iter().all(|&l| l >= 2));
    }

    #[test]
    fn uniform_short_has_no_tail() {
        let reqs = generate(&ShareGptLike::uniform_short(), 10.0, 20_000, 3);
        assert!(reqs.iter().all(|r| r.input_len < 4096));
    }

    #[test]
    fn duration_generation_bounded() {
        let reqs = generate_for_duration(&ShareGptLike::default(), 50.0, 10.0, 5);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|r| r.arrival <= 10.0));
        // ~ rate * duration requests.
        assert!((reqs.len() as f64 - 500.0).abs() < 100.0, "{}", reqs.len());
    }

    #[test]
    fn csv_roundtrip() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 64, 11);
        let path = std::env::temp_dir().join("cascade_trace_test.csv");
        let path = path.to_str().unwrap();
        save_csv(path, &reqs).unwrap();
        let back = load_csv(path).unwrap();
        assert_eq!(reqs.len(), back.len());
        for (a, b) in reqs.iter().zip(back.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.output_len, b.output_len);
            assert!((a.arrival - b.arrival).abs() < 1e-5);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn predicted_csv_round_trips() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 48, 23);
        let preds: Vec<Tokens> = reqs.iter().map(|r| r.final_len() + 10).collect();
        let path = std::env::temp_dir().join("cascade_predicted_trace.csv");
        let path = path.to_str().unwrap();
        save_csv_predicted(path, &reqs, &preds).unwrap();
        let (back, back_preds) = load_csv_predicted(path).unwrap();
        assert_eq!(back, {
            // Arrivals round through `{:.6}` formatting; compare the
            // integer fields exactly and arrivals approximately.
            let mut expect = reqs.clone();
            for (e, b) in expect.iter_mut().zip(back.iter()) {
                assert!((e.arrival - b.arrival).abs() < 1e-5);
                e.arrival = b.arrival;
            }
            expect
        });
        assert_eq!(back_preds, preds.iter().map(|&p| Some(p)).collect::<Vec<_>>());
        // The legacy loader accepts the 5-column file, dropping the
        // prediction column.
        let plain = load_csv(path).unwrap();
        assert_eq!(plain.len(), reqs.len());
        assert_eq!(plain[0].output_len, reqs[0].output_len);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_four_column_traces_load_with_no_predictions() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 16, 29);
        let path = std::env::temp_dir().join("cascade_legacy_trace.csv");
        let path = path.to_str().unwrap();
        save_csv(path, &reqs).unwrap();
        let (back, preds) = load_csv_predicted(path).unwrap();
        assert_eq!(back.len(), reqs.len());
        assert!(preds.iter().all(Option::is_none));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn predicted_csv_rejects_bad_inputs() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 4, 31);
        let path = std::env::temp_dir().join("cascade_predicted_bad.csv");
        let path = path.to_str().unwrap();
        // Mismatched column length never writes a file.
        assert!(save_csv_predicted(path, &reqs, &[1, 2]).is_err());
        // A malformed predicted_len cell is a hard parse error, not a
        // silent None.
        std::fs::write(path, "id,arrival,input_len,output_len,predicted_len\n0,0.5,10,20,oops\n")
            .unwrap();
        assert!(load_csv_predicted(path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn histogram_buckets_partition() {
        let reqs = generate(&ShareGptLike::default(), 10.0, 10_000, 13);
        let h = LengthHistogram::from_requests(&reqs, 131_072);
        assert_eq!(h.total(), 10_000);
        // Prefix sums end at the grand totals.
        let pref = h.prefix();
        let last = pref.last().unwrap();
        assert_eq!(last.0 as u64, 10_000);
        let sum_final: f64 = reqs.iter().map(|r| r.final_len() as f64).sum();
        assert!((last.3 - sum_final).abs() < 1e-6 * sum_final);
    }

    #[test]
    fn bucket_of_boundaries() {
        let h = LengthHistogram::new(vec![2, 4, 8, 16]);
        assert_eq!(h.bucket_of(1), 0);
        assert_eq!(h.bucket_of(2), 1); // [2,4)
        assert_eq!(h.bucket_of(3), 1);
        assert_eq!(h.bucket_of(4), 2);
        assert_eq!(h.bucket_of(100), 3); // clamped to last
    }

    #[test]
    fn workload_spec_parse_and_determinism() {
        for name in ["sharegpt", "heavytail", "uniformshort", "mix", "bursty"] {
            let spec = WorkloadSpec::parse(name).unwrap();
            let a = spec.generate(12.0, 300, 9).unwrap();
            let b = spec.generate(12.0, 300, 9).unwrap();
            assert_eq!(a, b, "{name} not deterministic");
            assert_eq!(a.len(), 300);
            for w in a.windows(2) {
                assert!(w[1].arrival >= w[0].arrival, "{name} arrivals must be ordered");
            }
        }
        assert!(WorkloadSpec::parse("nope").is_err());
        assert!(WorkloadSpec::parse("trace:").is_err());
        assert!(matches!(
            WorkloadSpec::parse("trace:foo.csv").unwrap(),
            WorkloadSpec::CsvTrace(p) if p == "foo.csv"
        ));
        // Prefix is case-insensitive; the path keeps its case.
        assert!(matches!(
            WorkloadSpec::parse("Trace:Dir/Run.csv").unwrap(),
            WorkloadSpec::CsvTrace(p) if p == "Dir/Run.csv"
        ));
    }

    #[test]
    fn invalid_spec_parameters_error_instead_of_panicking() {
        let empty = WorkloadSpec::Mixture(vec![]);
        assert!(empty.generate(10.0, 5, 1).is_err());
        let zero_mass = WorkloadSpec::Mixture(vec![(0.0, ShareGptLike::default())]);
        assert!(zero_mass.generate(10.0, 5, 1).is_err());
        let bad_burst = WorkloadSpec::Bursty {
            dist: ShareGptLike::default(),
            on_s: 0.0,
            off_s: 10.0,
            off_rate_frac: 0.1,
        };
        assert!(bad_burst.generate(10.0, 5, 1).is_err());
    }

    #[test]
    fn bursty_arrivals_cluster_in_on_phases() {
        let spec = WorkloadSpec::Bursty {
            dist: ShareGptLike::default(),
            on_s: 10.0,
            off_s: 10.0,
            off_rate_frac: 0.05,
        };
        let reqs = spec.generate(20.0, 2000, 3).unwrap();
        let in_on = reqs.iter().filter(|r| r.arrival % 20.0 < 10.0).count();
        // With a 20x on/off rate ratio, the overwhelming majority of
        // arrivals must land in the on-phase.
        assert!(in_on as f64 > reqs.len() as f64 * 0.9, "{in_on}/{}", reqs.len());
    }

    #[test]
    fn mixture_blends_components() {
        // A mixture of pure-short and pure-heavy components must land
        // between the two in tail mass.
        let spec = WorkloadSpec::Mixture(vec![
            (1.0, ShareGptLike::uniform_short()),
            (1.0, ShareGptLike::heavy_tail()),
        ]);
        let reqs = spec.generate(10.0, 8000, 5).unwrap();
        let long = reqs.iter().filter(|r| r.input_len >= 4096).count() as f64 / 8000.0;
        // heavy_tail alone has ~8% tail; the 50/50 blend about half that.
        assert!(long > 0.01 && long < 0.08, "tail fraction {long}");
    }

    #[test]
    fn csv_trace_spec_round_trips() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 32, 17);
        let path = std::env::temp_dir().join("cascade_spec_trace.csv");
        save_csv(path.to_str().unwrap(), &reqs).unwrap();
        let spec = WorkloadSpec::CsvTrace(path.to_str().unwrap().to_string());
        let back = spec.generate(0.0, 0, 0).unwrap();
        assert_eq!(back.len(), reqs.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn exponential_bounds_reach_max() {
        let b = LengthHistogram::exponential_bounds(131_072);
        assert_eq!(*b.last().unwrap(), 131_072);
        assert!(b.len() < 20, "O(log L) buckets, got {}", b.len());
    }

    #[test]
    fn stream_matches_materialized_for_every_spec() {
        for name in ["sharegpt", "heavytail", "uniformshort", "mix", "bursty"] {
            let spec = WorkloadSpec::parse(name).unwrap();
            let materialized = spec.generate(12.0, 300, 9).unwrap();
            let streamed: Vec<Request> = spec
                .stream(12.0, 300, 9)
                .unwrap()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(materialized, streamed, "{name} stream diverged");
        }
    }

    #[test]
    fn duration_stream_matches_materialized() {
        let d = ShareGptLike::default();
        let materialized = generate_for_duration(&d, 50.0, 10.0, 5);
        let streamed: Vec<Request> = WorkloadStream::poisson_for_duration(d, 50.0, 10.0, 5)
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(materialized, streamed);
    }

    #[test]
    fn csv_stream_matches_loader_and_row_count() {
        let reqs = generate(&ShareGptLike::default(), 5.0, 64, 19);
        let path = std::env::temp_dir().join("cascade_stream_trace.csv");
        let path = path.to_str().unwrap();
        save_csv(path, &reqs).unwrap();
        let loaded = load_csv(path).unwrap();
        let streamed: Vec<Request> =
            WorkloadStream::csv(path).unwrap().map(|r| r.unwrap()).collect();
        assert_eq!(loaded, streamed);
        assert_eq!(count_csv_rows(path).unwrap(), reqs.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_stream_surfaces_parse_errors_in_band() {
        let path = std::env::temp_dir().join("cascade_stream_bad.csv");
        let path = path.to_str().unwrap();
        std::fs::write(path, "id,arrival,input_len,output_len\n0,0.5,10,20\noops\n").unwrap();
        let items: Vec<std::io::Result<Request>> = WorkloadStream::csv(path).unwrap().collect();
        assert_eq!(items.len(), 2);
        assert!(items[0].is_ok());
        assert!(items[1].is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn degenerate_specs_fail_to_stream() {
        let zero_mass = WorkloadSpec::Mixture(vec![(0.0, ShareGptLike::default())]);
        assert!(zero_mass.stream(10.0, 5, 1).is_err());
        let bad_burst = WorkloadSpec::Bursty {
            dist: ShareGptLike::default(),
            on_s: -1.0,
            off_s: 10.0,
            off_rate_frac: 0.1,
        };
        assert!(bad_burst.stream(10.0, 5, 1).is_err());
    }
}
