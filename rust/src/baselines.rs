//! Baseline layouts and helpers used by the §6 comparisons.
//!
//! The scheduler-policy axes live in [`crate::cluster::policy`]; this
//! module holds the layout constructors that need the planner.

use crate::coordinator::plan::{Pipeline, Planner, StageSpec};
use crate::workload::LengthHistogram;

/// The "chain" ablation layout (Fig. 14): exactly one instance per
/// stage.  Cuts come from the planner's chain DP (phase 1 of the
/// two-phase heuristic) so the chain is as good as a chain can be.
pub fn chain_layout(planner: &Planner, hist: &LengthHistogram, e: usize) -> Pipeline {
    // Run the heuristic, then explode any multi-instance stage into
    // per-instance slices of its range (simple equal split in log
    // space, matching the exponential bucketing).
    let merged = planner.plan_heuristic(hist, e);
    let mut stages: Vec<StageSpec> = Vec::new();
    for s in merged.stages {
        if s.n_instances <= 1 {
            stages.push(s);
            continue;
        }
        let k = s.n_instances as u32;
        let lo = s.lo.max(1) as f64;
        let hi = s.hi as f64;
        let ratio = (hi / lo).powf(1.0 / k as f64);
        let mut cur = s.lo;
        for j in 0..k {
            let next = if j == k - 1 {
                s.hi
            } else {
                ((lo * ratio.powi(j as i32 + 1)).round() as u64).clamp(cur + 1, s.hi - 1)
            };
            stages.push(StageSpec { lo: cur, hi: next, n_instances: 1 });
            cur = next;
        }
    }
    // Fix any degenerate ranges produced by clamping.
    let mut cleaned: Vec<StageSpec> = Vec::new();
    for s in stages {
        if s.lo >= s.hi {
            if let Some(last) = cleaned.last_mut() {
                last.n_instances += s.n_instances;
            }
        } else {
            cleaned.push(s);
        }
    }
    let q = planner.pipeline_quality(hist, &Pipeline { stages: cleaned.clone(), predicted_quality: 0.0 });
    Pipeline { stages: cleaned, predicted_quality: q }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::MigrationCost;
    use crate::qoe::QoeModel;
    use crate::workload::{generate, ShareGptLike};

    fn setup() -> (Planner, LengthHistogram) {
        let qoe = QoeModel::new([5e-3, 2e-4, 1e-6, 1e-11, 2e-6]);
        let planner = Planner::new(qoe, MigrationCost::free());
        let reqs = generate(&ShareGptLike::default(), 10.0, 3000, 21);
        let hist = LengthHistogram::from_requests(&reqs, 131_072);
        (planner, hist)
    }

    #[test]
    fn chain_has_one_instance_per_stage() {
        let (planner, hist) = setup();
        let chain = chain_layout(&planner, &hist, 8);
        assert_eq!(chain.total_instances(), 8);
        assert!(chain.stages.iter().all(|s| s.n_instances == 1));
        assert_eq!(chain.stages.len(), 8);
    }

    #[test]
    fn chain_covers_full_range_contiguously() {
        let (planner, hist) = setup();
        let chain = chain_layout(&planner, &hist, 8);
        assert_eq!(chain.stages.first().unwrap().lo, 0);
        assert_eq!(chain.stages.last().unwrap().hi, 131_072);
        for w in chain.stages.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
            assert!(w[0].lo < w[0].hi);
        }
    }

    #[test]
    fn chain_quality_worse_or_equal_to_planned() {
        let (planner, hist) = setup();
        let planned = planner.plan_dp(&hist, 8);
        let chain = chain_layout(&planner, &hist, 8);
        let chain_q = planner.pipeline_quality(&hist, &chain);
        assert!(
            chain_q >= planned.predicted_quality * 0.999,
            "chain {} vs planned {}",
            chain_q,
            planned.predicted_quality
        );
    }
}
