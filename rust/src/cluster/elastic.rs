//! Fault injection and elasticity — seeded instance churn (§6-style
//! robustness runs the source paper does not have).
//!
//! A [`ChurnSpec`] is a deterministic schedule of membership events
//! plus an optional SLO-feedback autoscaler, parsed from the `--churn`
//! grammar and threaded through `Experiment` into `ClusterConfig`:
//!
//! * `spot:T@I` — **spot preemption**: instance `I` dies at time `T`
//!   mid-decode.  Its in-flight requests re-enter admission as
//!   re-prefills (prompt + generated prefix), retried with capped
//!   attempts ([`MAX_SPOT_RETRIES`]) under exponential backoff
//!   ([`READMIT_BACKOFF_BASE`]) before escalating to a counted
//!   rejection — graceful degradation, never a wedge.
//! * `drain:T@I[:DEADLINE]` — **graceful scale-in**: instance `I`
//!   stops admitting at `T`, evacuates KV through the bid-ask
//!   migration path, and leaves once empty.  A drain that is still
//!   holding work at `T + DEADLINE` (default
//!   [`DEFAULT_DRAIN_DEADLINE`]) is forcibly killed and recovers like
//!   a spot preemption.
//! * `join:T[@GPU]` — **scale-out**: a new instance starts booting at
//!   `T` and accepts work only after its weight load completes
//!   (model footprint over the topology's inter-node link).
//! * `auto:PERIOD:MIN..MAX` — **SLO-feedback autoscaler**: every
//!   `PERIOD` seconds a controller inspects windowed SLO attainment
//!   and queue depth and scales the live fleet within `MIN..MAX`.
//!
//! Determinism: all churn state lives in the calendar event queue and
//! in plain ordered containers — no entropy, no wall clock, no hash
//! iteration — so churn runs are bit-reproducible, and
//! [`ChurnSpec::none`] (the default) leaves every legacy code path
//! untouched bit-for-bit.

use crate::gpu::GpuProfile;
use crate::Time;

/// Re-admission attempts a preempted request gets before its retries
/// escalate to a counted rejection.
pub const MAX_SPOT_RETRIES: u32 = 3;

/// First re-admission delay after a preemption; attempt `k` waits
/// `READMIT_BACKOFF_BASE * 2^(k-1)`.
pub const READMIT_BACKOFF_BASE: Time = 0.25;

/// Drain deadline when the `drain:T@I` form omits one.
pub const DEFAULT_DRAIN_DEADLINE: Time = 10.0;

/// Cadence at which a draining instance re-offers its remaining work
/// and re-checks the empty/deadline exit conditions.
pub const DRAIN_PUMP_INTERVAL: Time = 0.1;

/// TTFT bound (seconds) of the SLO the autoscaler's windowed
/// attainment is measured against.
pub const AUTOSCALE_SLO_TTFT: f64 = 1.0;

/// TPOT bound (seconds/token) of the autoscaler's SLO.
pub const AUTOSCALE_SLO_TPOT: f64 = 0.1;

/// Autoscaler scale-out trigger: windowed SLO attainment below this.
pub const AUTOSCALE_ATTAIN_LOW: f64 = 0.9;

/// Autoscaler scale-in trigger: windowed SLO attainment at/above this
/// (with an empty queue).
pub const AUTOSCALE_ATTAIN_HIGH: f64 = 0.99;

/// Autoscaler scale-out trigger: total queued sequences exceeding
/// this multiple of the admitting-instance count.
pub const AUTOSCALE_QUEUE_FACTOR: usize = 4;

/// Lifecycle of one instance slot.  Slots for scheduled joins and
/// autoscaler headroom are pre-allocated `Absent` at construction so
/// churn never reallocates the instance table mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Membership {
    /// Pre-allocated slot that has not joined yet.
    Absent,
    /// Serving and admitting new work.
    Live,
    /// Serving its residue but admitting nothing (graceful scale-in).
    Draining,
    /// Departed; never returns.
    Dead,
}

/// One scheduled membership event.
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnEvent {
    /// Instance `instance` dies at `at` mid-decode.
    Spot { at: Time, instance: usize },
    /// Instance `instance` starts draining at `at`; forced kill at
    /// `at + deadline` if still non-empty.
    Drain { at: Time, instance: usize, deadline: Time },
    /// A new instance starts booting at `at`; `gpu` overrides the
    /// fleet's reference GPU for the joining slot.
    Join { at: Time, gpu: Option<&'static str> },
}

impl ChurnEvent {
    pub fn at(&self) -> Time {
        match self {
            ChurnEvent::Spot { at, .. }
            | ChurnEvent::Drain { at, .. }
            | ChurnEvent::Join { at, .. } => *at,
        }
    }
}

/// SLO-feedback autoscaler configuration (`auto:PERIOD:MIN..MAX`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSpec {
    /// Controller cadence in seconds.
    pub period: Time,
    /// The live-instance count is held within `min..=max`.
    pub min: usize,
    pub max: usize,
}

/// The full churn schedule for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnSpec {
    /// Scheduled events, sorted by time (stable: spec order breaks
    /// ties deterministically).
    pub events: Vec<ChurnEvent>,
    pub autoscale: Option<AutoscaleSpec>,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl ChurnSpec {
    /// The fault-free schedule — the hard bit-identity gate: a run
    /// under `ChurnSpec::none()` must fingerprint-match a run built
    /// before this module existed, for every registry scheduler.
    pub fn none() -> Self {
        Self { events: Vec::new(), autoscale: None }
    }

    /// True when no event and no autoscaler is configured — every
    /// churn code path is skipped.
    pub fn is_none(&self) -> bool {
        self.events.is_empty() && self.autoscale.is_none()
    }

    /// Number of scheduled `join:` events (slots to pre-allocate).
    pub fn scheduled_joins(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ChurnEvent::Join { .. })).count()
    }

    /// Registry of churn event kinds — the D4 coverage anchor: every
    /// name here must appear in the `tests/elastic.rs` coverage list,
    /// so a new fault kind cannot ship without a determinism pin.
    pub fn names() -> &'static [&'static str] {
        &["spot", "drain", "join", "auto"]
    }

    /// Parse the `--churn` grammar: a comma-separated list of
    /// `spot:T@I`, `drain:T@I[:DEADLINE]`, `join:T[@GPU]`, and at most
    /// one `auto:PERIOD:MIN..MAX`; the literal `none` is the empty
    /// schedule.  Malformed entries are hard errors naming the valid
    /// forms (same policy as `--fleet`: never a silent fallback).
    pub fn parse(s: &str) -> Result<Self, String> {
        let trimmed = s.trim();
        if trimmed.is_empty() {
            return Err("churn spec is empty; expected e.g. spot:2.0@1 or none".into());
        }
        if trimmed == "none" {
            return Ok(Self::none());
        }
        let mut spec = Self::none();
        for seg in trimmed.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                return Err(format!("empty churn segment in `{s}`"));
            }
            let (kind, rest) = seg
                .split_once(':')
                .ok_or_else(|| format!("churn segment `{seg}` has no `:`; valid kinds: spot, drain, join, auto"))?;
            match kind.trim() {
                "spot" => {
                    let (at, instance) = parse_time_at_instance(rest, seg)?;
                    spec.events.push(ChurnEvent::Spot { at, instance });
                }
                "drain" => {
                    let (head, deadline) = match rest.rsplit_once(':') {
                        Some((head, d)) if head.contains('@') => {
                            (head, parse_time(d, seg, "drain deadline")?)
                        }
                        _ => (rest, DEFAULT_DRAIN_DEADLINE),
                    };
                    if deadline <= 0.0 {
                        return Err(format!("drain deadline in `{seg}` must be positive"));
                    }
                    let (at, instance) = parse_time_at_instance(head, seg)?;
                    spec.events.push(ChurnEvent::Drain { at, instance, deadline });
                }
                "join" => {
                    let (t, gpu) = match rest.split_once('@') {
                        Some((t, g)) => {
                            let g = g.trim();
                            let gpu = GpuProfile::by_name(g).ok_or_else(|| {
                                format!(
                                    "unknown join gpu `{g}` in `{seg}`; valid: {}",
                                    GpuProfile::NAMES.join("|")
                                )
                            })?;
                            (t, Some(gpu.name))
                        }
                        None => (rest, None),
                    };
                    let at = parse_time(t, seg, "join time")?;
                    spec.events.push(ChurnEvent::Join { at, gpu });
                }
                "auto" => {
                    if spec.autoscale.is_some() {
                        return Err(format!("duplicate auto: segment in `{s}`"));
                    }
                    let (period, bounds) = rest.split_once(':').ok_or_else(|| {
                        format!("auto segment `{seg}` must be auto:PERIOD:MIN..MAX")
                    })?;
                    let period = parse_time(period, seg, "autoscale period")?;
                    if period <= 0.0 {
                        return Err(format!("autoscale period in `{seg}` must be positive"));
                    }
                    let (min, max) = bounds.split_once("..").ok_or_else(|| {
                        format!("auto bounds in `{seg}` must be MIN..MAX, e.g. 2..8")
                    })?;
                    let min = min.trim().parse::<usize>().ok().filter(|&v| v >= 1).ok_or_else(
                        || format!("autoscale min in `{seg}` is not a positive integer"),
                    )?;
                    let max = max.trim().parse::<usize>().ok().filter(|&v| v >= min).ok_or_else(
                        || format!("autoscale max in `{seg}` must be an integer >= min"),
                    )?;
                    spec.autoscale = Some(AutoscaleSpec { period, min, max });
                }
                other => {
                    return Err(format!(
                        "unknown churn kind `{other}` in `{seg}`; valid: spot, drain, join, auto"
                    ))
                }
            }
        }
        // Stable by-time sort: same-instant events fire in spec order.
        spec.events.sort_by(|a, b| a.at().total_cmp(&b.at()));
        Ok(spec)
    }
}

fn parse_time(s: &str, seg: &str, what: &str) -> Result<Time, String> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|v| v.is_finite() && *v >= 0.0)
        .ok_or_else(|| format!("{what} `{}` in `{seg}` is not a non-negative number", s.trim()))
}

fn parse_time_at_instance(s: &str, seg: &str) -> Result<(Time, usize), String> {
    let (t, i) = s
        .split_once('@')
        .ok_or_else(|| format!("churn segment `{seg}` must be KIND:TIME@INSTANCE"))?;
    let at = parse_time(t, seg, "churn time")?;
    let instance = i
        .trim()
        .parse::<usize>()
        .map_err(|_| format!("instance id `{}` in `{seg}` is not an integer", i.trim()))?;
    Ok((at, instance))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_default_and_empty() {
        assert!(ChurnSpec::none().is_none());
        assert_eq!(ChurnSpec::default(), ChurnSpec::none());
        assert_eq!(ChurnSpec::parse("none").unwrap(), ChurnSpec::none());
        assert_eq!(ChurnSpec::none().scheduled_joins(), 0);
    }

    #[test]
    fn parse_spot_drain_join_auto() {
        let spec = ChurnSpec::parse("spot:2.0@1,drain:4.5@2:3.0,join:6.0,auto:1.0:2..8").unwrap();
        assert_eq!(spec.events.len(), 3);
        assert_eq!(spec.events[0], ChurnEvent::Spot { at: 2.0, instance: 1 });
        assert_eq!(spec.events[1], ChurnEvent::Drain { at: 4.5, instance: 2, deadline: 3.0 });
        assert_eq!(spec.events[2], ChurnEvent::Join { at: 6.0, gpu: None });
        assert_eq!(spec.autoscale, Some(AutoscaleSpec { period: 1.0, min: 2, max: 8 }));
        assert_eq!(spec.scheduled_joins(), 1);
        assert!(!spec.is_none());
    }

    #[test]
    fn parse_defaults_and_gpu_joins() {
        let spec = ChurnSpec::parse("drain:1.0@0").unwrap();
        assert_eq!(
            spec.events[0],
            ChurnEvent::Drain { at: 1.0, instance: 0, deadline: DEFAULT_DRAIN_DEADLINE }
        );
        let spec = ChurnSpec::parse("join:3.0@h100").unwrap();
        assert_eq!(spec.events[0], ChurnEvent::Join { at: 3.0, gpu: Some("H100") });
    }

    #[test]
    fn events_sort_by_time_stably() {
        let spec = ChurnSpec::parse("join:5.0,spot:1.0@0,drain:5.0@1").unwrap();
        assert_eq!(spec.events[0].at(), 1.0);
        // Same-instant tie keeps spec order: join before drain.
        assert!(matches!(spec.events[1], ChurnEvent::Join { .. }));
        assert!(matches!(spec.events[2], ChurnEvent::Drain { .. }));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "spot",
            "spot:2.0",
            "spot:x@1",
            "spot:-1.0@1",
            "spot:2.0@one",
            "drain:2.0@1:0.0",
            "drain:2.0@1:-1",
            "join:nan",
            "join:2.0@a100",
            "auto:1.0",
            "auto:0.0:2..8",
            "auto:1.0:0..8",
            "auto:1.0:8..2",
            "auto:1.0:2-8",
            "auto:1.0:2..8,auto:2.0:2..8",
            "reboot:1.0@2",
            "spot:1.0@0,,join:2.0",
        ] {
            assert!(ChurnSpec::parse(bad).is_err(), "`{bad}` should be rejected");
        }
        let msg = ChurnSpec::parse("reboot:1.0@2").unwrap_err();
        assert!(msg.contains("spot") && msg.contains("auto"), "{msg}");
        let msg = ChurnSpec::parse("join:2.0@a100").unwrap_err();
        assert!(msg.contains("H20|L40|H100"), "{msg}");
    }

    #[test]
    fn names_registry_is_stable() {
        assert_eq!(ChurnSpec::names(), &["spot", "drain", "join", "auto"]);
    }
}
