//! Prefill/decode disaggregation — [`Layout::Disaggregated`].
//!
//! Splits the fleet into a **prefill pool** and a **decode pool**
//! (LAPS-style, "Length-Aware Prefill Scheduling"): prefill instances
//! run prompt phases only ([`crate::engine::Engine::set_prefill_only`]
//! parks each completed prefill with its KV resident), and the
//! completed prefill's KV hands off to a decode instance through the
//! *existing* [`crate::coordinator::migrate::MigrationManager`] cost
//! model over the configured [`crate::gpu::Topology`] link — PD
//! introduces no new transfer machinery, a handoff is a frozen-KV
//! migration (decode rate 0, single-round copy).
//!
//! Three LAPS levers shape the prefill side:
//!
//! * **Dual prefill queues**: arrivals with `input_len <=`
//!   [`PdSpec::short_boundary`] enter the short queue, the rest the
//!   long queue; flushes drain the short queue *first*, so short
//!   prompts never wait behind a long prefill that arrived earlier
//!   (the §2 head-of-line criticism, solved structurally).
//! * **Waiting window**: the first enqueue schedules one flush
//!   [`PdSpec::window_us`] later; everything accumulated by then is
//!   grouped into batches of *similar-length* prompts (within 2x of
//!   each other, capped at the engine's `max_batched_tokens`) and each
//!   batch lands on the least-loaded prefill instance as one unit —
//!   chunked-prefill batches stay homogeneous instead of mixing a 16K
//!   prompt into a batch of 100-token prompts.  `window_us = 0`
//!   degenerates to flush-on-arrival.
//! * **Dynamic re-allocation**: a periodic controller compares
//!   per-instance prefill backlog (queued prompt tokens + prefill-pool
//!   load) against decode backlog and, on a *sustained* (3-tick) 2x
//!   imbalance, moves one idle instance between the pools — toggling
//!   its prefill-only flag and resyncing the stage membership lists,
//!   the same structural path the elastic-membership re-plan uses.
//!   Gated off with `balance=off`.
//!
//! Admission mirrors the colocated reject-or-reroute contract per
//! pool: an arrival is rejected only when *no* prefill instance can
//! ever hold its prompt or *no* decode instance can ever hold its
//! (predicted) final length, with the under-prediction escalation
//! counted in [`super::RunStats::predict_escalations`] exactly like
//! the colocated path.
//!
//! **Bit-identity invariant**: every PD hook is gated on
//! `Cluster::pd.is_some()`.  Colocated layouts construct no `PdState`,
//! schedule no PD event, and leave every engine's prefill-only flag
//! false, so all registry schedulers and predictor families remain
//! fingerprint-bit-identical to the pre-PD tree —
//! `tests/pd_layout.rs` pins it.

use std::collections::VecDeque;

use crate::workload::Request;
use crate::{InstanceId, RequestId, Time, Tokens};

use super::driver::Event;
use super::router::effective_wait;
use super::Cluster;

/// Periodic pool re-allocation check interval (seconds).
pub(super) const PD_REBALANCE_INTERVAL: Time = 1.0;
/// Consecutive imbalanced rebalance ticks before an instance moves.
const PD_REBALANCE_STREAK: i32 = 3;
/// Per-instance backlog ratio that counts as imbalanced.
const PD_REBALANCE_RATIO: f64 = 2.0;
/// Retry delay after a handoff could not start (no dest slot / at the
/// migration concurrency cap).
const PD_PUMP_RETRY: Time = 0.05;

/// Parameters of a prefill/decode-disaggregated layout — the payload
/// of [`super::Layout::Disaggregated`].
///
/// Grammar (the `--layout` flag and the `custom:layout=` axis):
/// `pd[:P/D[:BOUNDARY[:WINDOW_US]]]` — bare `pd` auto-splits the
/// fleet; explicit pools must sum to the instance count.  All-integer
/// fields keep `Layout` `Copy + Eq`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PdSpec {
    /// Prefill-pool size; 0 (with `decode` 0) = auto-split.
    pub prefill: usize,
    /// Decode-pool size; 0 (with `prefill` 0) = auto-split.
    pub decode: usize,
    /// Prompts at or below this length enter the short prefill queue.
    pub short_boundary: Tokens,
    /// Waiting-window length in microseconds (0 = flush on arrival).
    pub window_us: u64,
}

impl PdSpec {
    /// Default short/long queue boundary (prompt tokens).
    pub const DEFAULT_SHORT_BOUNDARY: Tokens = 512;
    /// Default waiting window (20 ms).
    pub const DEFAULT_WINDOW_US: u64 = 20_000;
    /// The layout-axis grammar, quoted in parse errors and `USAGE`.
    pub const GRAMMAR: &'static str = "pd[:P/D[:BOUNDARY[:WINDOW_US]]]";

    /// Auto-split spec: pools resolved from the fleet size at
    /// construction, default boundary and window.
    pub fn auto() -> Self {
        Self {
            prefill: 0,
            decode: 0,
            short_boundary: Self::DEFAULT_SHORT_BOUNDARY,
            window_us: Self::DEFAULT_WINDOW_US,
        }
    }

    /// Parse a `pd[:P/D[:BOUNDARY[:WINDOW_US]]]` layout value.
    pub fn parse(value: &str) -> Result<Self, String> {
        if value == "pd" {
            return Ok(Self::auto());
        }
        let Some(body) = value.strip_prefix("pd:") else {
            return Err(format!("PD layout `{value}` (grammar: {})", Self::GRAMMAR));
        };
        let mut spec = Self::auto();
        let mut parts = body.split(':');
        let pools = parts.next().unwrap_or_default();
        let Some((p, d)) = pools.split_once('/') else {
            return Err(format!(
                "PD pools `{pools}` must be P/D, e.g. pd:2/2 (grammar: {})",
                Self::GRAMMAR
            ));
        };
        spec.prefill = p
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| format!("PD prefill pool `{p}` must be a positive integer"))?;
        spec.decode = d
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| format!("PD decode pool `{d}` must be a positive integer"))?;
        if let Some(b) = parts.next() {
            spec.short_boundary = b
                .parse::<Tokens>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| format!("PD short boundary `{b}` must be a positive integer"))?;
        }
        if let Some(w) = parts.next() {
            spec.window_us = w
                .parse::<u64>()
                .ok()
                .ok_or_else(|| format!("PD window `{w}` must be an integer (microseconds)"))?;
        }
        if parts.next().is_some() {
            return Err(format!("trailing segments in `{value}` (grammar: {})", Self::GRAMMAR));
        }
        Ok(spec)
    }

    /// Canonical serialization — parses back to the identical spec, so
    /// `custom:layout=<name()>` round-trips.  Defaulted trailing
    /// segments are omitted.
    pub fn name(&self) -> String {
        let mut s = String::from("pd");
        if self.prefill != 0 || self.decode != 0 {
            s.push_str(&format!(":{}/{}", self.prefill, self.decode));
            if self.short_boundary != Self::DEFAULT_SHORT_BOUNDARY
                || self.window_us != Self::DEFAULT_WINDOW_US
            {
                s.push_str(&format!(":{}", self.short_boundary));
                if self.window_us != Self::DEFAULT_WINDOW_US {
                    s.push_str(&format!(":{}", self.window_us));
                }
            }
        }
        s
    }

    /// Waiting window in seconds.
    pub fn window(&self) -> Time {
        self.window_us as f64 * 1e-6
    }

    /// Resolve `(prefill, decode)` pool sizes over an `e`-instance
    /// fleet.  Auto splits ~1/4 of the fleet (at least one instance)
    /// into the prefill pool: prefills are compute-bound and fast, the
    /// KV-bound decode residency dominates.
    pub fn pools(&self, e: usize) -> (usize, usize) {
        if self.prefill == 0 && self.decode == 0 {
            let p = (e / 4).max(1);
            (p, e - p)
        } else {
            (self.prefill, self.decode)
        }
    }
}

/// Runtime state of a disaggregated cluster — present iff the policy
/// layout is [`super::Layout::Disaggregated`].
#[derive(Debug, Clone)]
pub(super) struct PdState {
    pub(super) spec: PdSpec,
    /// Ascending instance ids running prompt phases only.
    pub(super) prefill_pool: Vec<InstanceId>,
    /// Ascending instance ids serving decode residency.
    pub(super) decode_pool: Vec<InstanceId>,
    /// Short-prompt prefill queue (drained first on flush).
    short_q: VecDeque<Request>,
    /// Long-prompt prefill queue.
    long_q: VecDeque<Request>,
    /// One `PdFlush` outstanding at a time.
    flush_scheduled: bool,
    /// One `PdPump` retry outstanding at a time.
    pump_scheduled: bool,
    /// Signed imbalance streak: positive ticks = prefill-starved,
    /// negative = decode-starved; an instance moves at +/-
    /// [`PD_REBALANCE_STREAK`].
    streak: i32,
}

impl PdState {
    pub(super) fn new(
        spec: PdSpec,
        prefill_pool: Vec<InstanceId>,
        decode_pool: Vec<InstanceId>,
    ) -> Self {
        debug_assert!(!prefill_pool.is_empty() && !decode_pool.is_empty());
        Self {
            spec,
            prefill_pool,
            decode_pool,
            short_q: VecDeque::new(),
            long_q: VecDeque::new(),
            flush_scheduled: false,
            pump_scheduled: false,
            streak: 0,
        }
    }
}

impl Cluster {
    /// PD admission: feasibility-check both pools, then park the
    /// arrival in the short or long prefill queue under the waiting
    /// window.  Called from `on_arrival` (the arena entry already
    /// exists) — the dispatch router is bypassed entirely.
    pub(super) fn pd_on_arrival(&mut self, now: Time, req: Request) {
        let pd = self.pd.as_ref().expect("pd_on_arrival requires a PD layout");
        let holds = |i: InstanceId, len: Tokens| self.instances[i].engine.can_ever_hold(len);
        // Prompt-side feasibility: prefill holds the prompt KV plus the
        // first emitted token.
        let prompt_len = req.input_len + 1;
        let prefill_target = pd.prefill_pool[0];
        let prefill_ok = pd.prefill_pool.iter().any(|&i| holds(i, prompt_len));
        // Decode-side feasibility mirrors the colocated admission
        // contract: the predicted final must fit some decode pool, and
        // an under-prediction whose true final never can escalates to
        // a counted rejection instead of wedging a decode instance.
        // Floored at the prompt length the handoff actually carries,
        // so a rank-only predictor can never admit a request the pump
        // could not place.
        let admit_len = self.predictor.admit_len(&req).max(prompt_len);
        let decode_target = pd.decode_pool[0];
        let admit_ok = pd.decode_pool.iter().any(|&i| holds(i, admit_len));
        let final_len = req.final_len();
        let escalated = admit_len < final_len;
        let final_ok = !escalated || pd.decode_pool.iter().any(|&i| holds(i, final_len));
        let short = req.input_len <= pd.spec.short_boundary;
        if !prefill_ok {
            self.reject(prefill_target, req.id, prompt_len);
            return;
        }
        if !admit_ok {
            self.reject(decode_target, req.id, admit_len);
            return;
        }
        if !final_ok {
            self.stats.predict_escalations += 1;
            self.reject(decode_target, req.id, final_len);
            return;
        }
        // Dual queues: short prompts drain first at the next flush.
        let flush_at = {
            let pd = self.pd.as_mut().expect("checked above");
            if short {
                pd.short_q.push_back(req);
            } else {
                pd.long_q.push_back(req);
            }
            if pd.spec.window_us == 0 {
                None // degenerate window: flush inline below
            } else if !pd.flush_scheduled {
                pd.flush_scheduled = true;
                Some(now + pd.spec.window())
            } else {
                return; // a flush is already pending; ride it
            }
        };
        match flush_at {
            None => self.on_pd_flush(now),
            Some(at) => self.events.schedule(at, Event::PdFlush),
        }
    }

    /// Waiting-window expiry: drain the short queue first, then the
    /// long queue, grouping runs of similar-length prompts (within 2x
    /// of each other, capped at the engine's batched-token budget)
    /// onto the least-loaded feasible prefill instance as one batch.
    pub(super) fn on_pd_flush(&mut self, now: Time) {
        let batch: Vec<Request> = {
            let pd = self.pd.as_mut().expect("PdFlush fires only under PD layouts");
            pd.flush_scheduled = false;
            let mut v = Vec::with_capacity(pd.short_q.len() + pd.long_q.len());
            v.extend(pd.short_q.drain(..));
            v.extend(pd.long_q.drain(..));
            v
        };
        if batch.is_empty() {
            return;
        }
        let cap = self.cfg.engine.max_batched_tokens;
        let fallback = self.pd.as_ref().expect("PD layout").prefill_pool[0];
        let mut touched: Vec<InstanceId> = Vec::new();
        let mut k = 0;
        while k < batch.len() {
            // Extend the group while lengths stay within 2x of each
            // other and the total prompt tokens fit one batch budget.
            let (mut gmin, mut gmax) = (batch[k].input_len, batch[k].input_len);
            let mut tokens = batch[k].input_len;
            let mut j = k + 1;
            while j < batch.len() {
                let l = batch[j].input_len;
                let (nmin, nmax) = (gmin.min(l), gmax.max(l));
                if nmax > nmin.saturating_mul(2) || tokens + l > cap {
                    break;
                }
                (gmin, gmax) = (nmin, nmax);
                tokens += l;
                j += 1;
            }
            match self.pd_prefill_target(gmax + 1) {
                Some(t) => {
                    for r in &batch[k..j] {
                        self.instances[t].engine.submit(*r);
                    }
                    if !touched.contains(&t) {
                        touched.push(t);
                    }
                }
                None => {
                    // Heterogeneous prefill pools: the group's largest
                    // member fits nowhere common — place each request
                    // on its own feasible instance (admission verified
                    // one existed; a re-allocation since then may have
                    // removed it, in which case reject, counted).
                    for r in &batch[k..j] {
                        match self.pd_prefill_target(r.input_len + 1) {
                            Some(t) => {
                                self.instances[t].engine.submit(*r);
                                if !touched.contains(&t) {
                                    touched.push(t);
                                }
                            }
                            None => self.reject(fallback, r.id, r.input_len + 1),
                        }
                    }
                }
            }
            k = j;
        }
        for t in touched {
            self.kick(now, t);
        }
    }

    /// Least-loaded admitting prefill instance whose KV pool can ever
    /// hold `len`; first index wins ties.
    fn pd_prefill_target(&self, len: Tokens) -> Option<InstanceId> {
        let pd = self.pd.as_ref().expect("PD layout");
        let mut best: Option<(f64, InstanceId)> = None;
        for &i in &pd.prefill_pool {
            let ins = &self.instances[i];
            if !ins.admits() || !ins.engine.can_ever_hold(len) {
                continue;
            }
            let w = effective_wait(ins, &self.migration);
            if best.is_none_or(|(bw, _)| w < bw) {
                best = Some((w, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Least-loaded admitting decode instance whose KV pool can ever
    /// hold `len` (inbound handoffs counted, herd-effect guard); first
    /// index wins ties.
    fn pd_decode_target(&self, len: Tokens) -> Option<InstanceId> {
        let pd = self.pd.as_ref().expect("PD layout");
        let mut best: Option<(f64, InstanceId)> = None;
        for &i in &pd.decode_pool {
            let ins = &self.instances[i];
            if !ins.admits() || !ins.engine.can_ever_hold(len) {
                continue;
            }
            let w = effective_wait(ins, &self.migration);
            if best.is_none_or(|(bw, _)| w < bw) {
                best = Some((w, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Handoff pump: start a KV transfer for every parked completed
    /// prefill not already in flight.  Runs after every dispatched
    /// event under PD (engine progress only happens inside event
    /// handlers, so no parked sequence can be stranded); a start that
    /// fails (no dest slot, migration concurrency cap) schedules one
    /// `PdPump` retry so the pump re-fires even if the queue would
    /// otherwise go quiet.
    pub(super) fn pd_pump(&mut self, now: Time) {
        let jobs: Vec<(InstanceId, RequestId, Tokens, Tokens)> = {
            let pd = self.pd.as_ref().expect("pd_pump requires a PD layout");
            let mut v = Vec::new();
            for &i in &pd.prefill_pool {
                for seq in self.instances[i].engine.handoff_ready() {
                    let rid = seq.req.id;
                    if self.in_flight.contains(&rid) || self.migration.is_migrating(rid) {
                        continue;
                    }
                    // The decode target must eventually hold the
                    // sequence's admission length, not just today's KV
                    // — the same floor admission checked, so a
                    // feasible target always exists once the pool
                    // drains.
                    let needed = self.predictor.admit_len(&seq.req).max(seq.current_len());
                    v.push((i, rid, seq.current_len(), needed));
                }
            }
            v
        };
        if jobs.is_empty() {
            return;
        }
        let mut stalled = false;
        for (from, rid, len, needed) in jobs {
            let Some(to) = self.pd_decode_target(needed) else {
                stalled = true;
                continue;
            };
            let link = self.topology.link_between(from, to);
            let dest_free = self.instances[to].engine.kv().can_allocate(len + 64);
            // Frozen KV: the parked sequence no longer decodes on the
            // prefill instance, so the transfer is a single-round copy
            // (decode rate 0) priced by the existing migration model.
            let started = self.migration.try_start(now, rid, from, to, len, link, 0.0, dest_free);
            if let Some(t) = started {
                let finish_at = t.finish_at;
                self.in_flight.insert(rid);
                let done = Event::MigrationDone { request: rid, from, to };
                self.events.schedule(finish_at, done);
            } else {
                stalled = true;
            }
        }
        if stalled {
            let pd = self.pd.as_mut().expect("PD layout");
            if !pd.pump_scheduled {
                pd.pump_scheduled = true;
                self.events.schedule(now + PD_PUMP_RETRY, Event::PdPump);
            }
        }
    }

    /// `PdPump` retry fired: clear the outstanding-retry gate (the
    /// post-dispatch pump does the actual work).
    pub(super) fn on_pd_pump_timer(&mut self) {
        if let Some(pd) = self.pd.as_mut() {
            pd.pump_scheduled = false;
        }
    }

    /// Periodic dynamic re-allocation: on a sustained per-instance
    /// backlog imbalance between the pools, move one *idle* instance
    /// across — toggling its prefill-only flag and resyncing the stage
    /// membership lists (the structural membership path).
    pub(super) fn on_pd_rebalance(&mut self, now: Time) {
        self.events.schedule(now + PD_REBALANCE_INTERVAL, Event::PdRebalance);
        let (p_avg, d_avg) = {
            let pd = self.pd.as_ref().expect("PdRebalance fires only under PD layouts");
            let queued: Tokens = pd.short_q.iter().chain(&pd.long_q).map(|r| r.input_len).sum();
            let p_load: Tokens =
                pd.prefill_pool.iter().map(|&i| self.instances[i].engine.token_load()).sum();
            let d_load: Tokens = pd
                .decode_pool
                .iter()
                .map(|&i| {
                    self.instances[i].engine.token_load() + self.migration.inbound_tokens(i)
                })
                .sum();
            (
                (queued + p_load) as f64 / pd.prefill_pool.len().max(1) as f64,
                d_load as f64 / pd.decode_pool.len().max(1) as f64,
            )
        };
        {
            let pd = self.pd.as_mut().expect("PD layout");
            // A floor of one token's worth of backlog keeps near-idle
            // noise from accumulating a streak.
            if p_avg > PD_REBALANCE_RATIO * d_avg && p_avg >= 1.0 {
                pd.streak = pd.streak.max(0) + 1;
            } else if d_avg > PD_REBALANCE_RATIO * p_avg && d_avg >= 1.0 {
                pd.streak = pd.streak.min(0) - 1;
            } else {
                pd.streak = 0;
            }
        }
        let streak = self.pd.as_ref().expect("PD layout").streak;
        if streak >= PD_REBALANCE_STREAK {
            if let Some(donor) = self.pd_idle_decode_donor() {
                self.pd_move_instance(donor, true);
            }
        } else if streak <= -PD_REBALANCE_STREAK {
            if let Some(donor) = self.pd_idle_prefill_donor() {
                self.pd_move_instance(donor, false);
            }
        }
    }

    /// Highest-id idle decode instance safe to donate to the prefill
    /// pool: the remaining decode pool must keep an instance with at
    /// least the donor's KV capacity, so no admitted sequence loses
    /// its only feasible decode home (trivially true on homogeneous
    /// pools).
    fn pd_idle_decode_donor(&self) -> Option<InstanceId> {
        let pd = self.pd.as_ref().expect("PD layout");
        if pd.decode_pool.len() <= 1 {
            return None;
        }
        pd.decode_pool.iter().rev().copied().find(|&i| {
            if self.instances[i].engine.has_work()
                || !self.migration.transfers_touching(i).is_empty()
            {
                return false;
            }
            let cap = self.instances[i].engine.kv().capacity_tokens();
            pd.decode_pool
                .iter()
                .filter(|&&x| x != i)
                .any(|&x| self.instances[x].engine.kv().capacity_tokens() >= cap)
        })
    }

    /// Highest-id idle prefill instance to donate to the decode pool
    /// (same remaining-capacity guard for the prompt side).
    fn pd_idle_prefill_donor(&self) -> Option<InstanceId> {
        let pd = self.pd.as_ref().expect("PD layout");
        if pd.prefill_pool.len() <= 1 {
            return None;
        }
        pd.prefill_pool.iter().rev().copied().find(|&i| {
            if self.instances[i].engine.has_work()
                || !self.migration.transfers_touching(i).is_empty()
            {
                return false;
            }
            let cap = self.instances[i].engine.kv().capacity_tokens();
            pd.prefill_pool
                .iter()
                .filter(|&&x| x != i)
                .any(|&x| self.instances[x].engine.kv().capacity_tokens() >= cap)
        })
    }

    /// Move instance `i` between the pools (`to_prefill` names the
    /// destination), toggle its engine mode, and resync the stage
    /// membership lists the rest of the cluster observes.
    fn pd_move_instance(&mut self, i: InstanceId, to_prefill: bool) {
        {
            let pd = self.pd.as_mut().expect("PD layout");
            if to_prefill {
                pd.decode_pool.retain(|&x| x != i);
                pd.prefill_pool.push(i);
                pd.prefill_pool.sort_unstable();
            } else {
                pd.prefill_pool.retain(|&x| x != i);
                pd.decode_pool.push(i);
                pd.decode_pool.sort_unstable();
            }
            pd.streak = 0;
        }
        self.instances[i].engine.set_prefill_only(to_prefill);
        self.stats.pd_reallocations += 1;
        self.pd_sync_stages();
    }

    /// Mirror the PD pools into the stage structures: the routing /
    /// churn-facing `stages` holds the decode pool only (decode work
    /// must never land on a prefill instance), while the reporting
    /// copy shows both pools.
    pub(super) fn pd_sync_stages(&mut self) {
        let pd = self.pd.as_ref().expect("PD layout");
        self.stages = vec![pd.decode_pool.clone()];
        self.stats.stages = vec![pd.prefill_pool.clone(), pd.decode_pool.clone()];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_defaults_and_full_grammar() {
        assert_eq!(PdSpec::parse("pd").unwrap(), PdSpec::auto());
        let s = PdSpec::parse("pd:2/2").unwrap();
        assert_eq!((s.prefill, s.decode), (2, 2));
        assert_eq!(s.short_boundary, PdSpec::DEFAULT_SHORT_BOUNDARY);
        assert_eq!(s.window_us, PdSpec::DEFAULT_WINDOW_US);
        let s = PdSpec::parse("pd:3/1:256:5000").unwrap();
        assert_eq!((s.prefill, s.decode, s.short_boundary, s.window_us), (3, 1, 256, 5000));
        // Window may be zero (flush-on-arrival); boundary may not.
        assert_eq!(PdSpec::parse("pd:2/2:64:0").unwrap().window_us, 0);
    }

    #[test]
    fn parse_rejects_malformed() {
        let bad = [
            "pd:",
            "pd:2",
            "pd:x",
            "pd:0/4",
            "pd:4/0",
            "pd:2/2:0",
            "pd:2/2:256:5000:extra",
            "pancake",
        ];
        for case in bad {
            assert!(PdSpec::parse(case).is_err(), "`{case}` should be rejected");
        }
    }

    #[test]
    fn name_round_trips() {
        for s in ["pd", "pd:2/2", "pd:3/1:256", "pd:3/1:256:5000", "pd:2/2:64:0"] {
            let spec = PdSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s, "canonical form");
            assert_eq!(PdSpec::parse(&spec.name()).unwrap(), spec);
        }
        // Defaulted trailing segments serialize away.
        let mut spec = PdSpec::parse("pd:2/2").unwrap();
        assert_eq!(spec.name(), "pd:2/2");
        spec.short_boundary = 256;
        assert_eq!(spec.name(), "pd:2/2:256");
    }

    #[test]
    fn pools_auto_split() {
        assert_eq!(PdSpec::auto().pools(2), (1, 1));
        assert_eq!(PdSpec::auto().pools(4), (1, 3));
        assert_eq!(PdSpec::auto().pools(8), (2, 6));
        assert_eq!(PdSpec::parse("pd:3/1").unwrap().pools(4), (3, 1));
    }

    #[test]
    fn window_converts_to_seconds() {
        assert!((PdSpec::auto().window() - 0.02).abs() < 1e-12);
        assert_eq!(PdSpec::parse("pd:2/2:64:0").unwrap().window(), 0.0);
    }
}
