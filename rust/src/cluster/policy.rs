//! Scheduler policy taxonomy: CascadeInfer, its ablations, and the
//! §6.1 baselines, expressed as orthogonal (layout, refinement,
//! balancing) axes so the ablation figures (14–16) toggle exactly one
//! axis at a time.

/// Stage layout policy (Fig. 14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// §4.2 DP-planned multi-stage pipeline.
    Planned,
    /// One instance per stage (the "chain" ablation).
    Chain,
    /// All instances in a single stage ("no-pipeline").
    Flat,
}

/// Boundary refinement policy (Fig. 15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefinePolicy {
    /// §4.3 QoE-optimal split with EMA + low-traffic freeze.
    Adaptive,
    /// Equalise request counts per stage.
    Quantity,
    /// Equalise cached-token memory per stage.
    Memory,
    Off,
}

/// Intra-/inter-stage balancing policy (Fig. 16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BalancePolicy {
    /// §4.4 bid-ask for both inter-stage handover and intra-stage
    /// outlier rebalancing.
    Full,
    /// Bid-ask on inter-stage handover only.
    InterStageOnly,
    /// Round-robin receiver choice (protocol ablation).
    RoundRobinIntra,
    Off,
}

/// Top-level scheduler selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// CascadeInfer: planned layout + adaptive refinement + full bid-ask.
    Cascade,
    /// vLLM-style instances behind a round-robin balancer.
    RoundRobin,
    /// SGLang-style instances behind a round-robin balancer (different
    /// engine speed is configured via `ClusterConfig::engine_speed`).
    SgLangLike,
    /// Llumnix: load-aware dispatch + length-agnostic rebalancing.
    LlumnixLike,
    /// Ablation: chain layout (one instance per stage).
    Chain,
    /// Ablation: single stage holding every instance.
    NoPipeline,
    /// Ablation: quantity-based refinement.
    CascadeQuantityRefine,
    /// Ablation: memory-based refinement.
    CascadeMemoryRefine,
    /// Ablation: inter-stage bid-ask only (no intra-stage rebalance).
    CascadeInterStageOnly,
    /// Ablation: round-robin receiver selection instead of bid-ask.
    CascadeRoundRobinIntra,
}

impl SchedulerKind {
    pub fn layout(&self) -> Layout {
        match self {
            SchedulerKind::Chain => Layout::Chain,
            SchedulerKind::NoPipeline
            | SchedulerKind::RoundRobin
            | SchedulerKind::SgLangLike
            | SchedulerKind::LlumnixLike => Layout::Flat,
            _ => Layout::Planned,
        }
    }

    pub fn refine_policy(&self) -> RefinePolicy {
        match self {
            SchedulerKind::Cascade
            | SchedulerKind::Chain
            | SchedulerKind::CascadeInterStageOnly
            | SchedulerKind::CascadeRoundRobinIntra => RefinePolicy::Adaptive,
            SchedulerKind::CascadeQuantityRefine => RefinePolicy::Quantity,
            SchedulerKind::CascadeMemoryRefine => RefinePolicy::Memory,
            _ => RefinePolicy::Off,
        }
    }

    pub fn balance_policy(&self) -> BalancePolicy {
        match self {
            SchedulerKind::Cascade
            | SchedulerKind::Chain
            | SchedulerKind::NoPipeline
            | SchedulerKind::CascadeQuantityRefine
            | SchedulerKind::CascadeMemoryRefine => BalancePolicy::Full,
            SchedulerKind::CascadeInterStageOnly => BalancePolicy::InterStageOnly,
            SchedulerKind::CascadeRoundRobinIntra => BalancePolicy::RoundRobinIntra,
            SchedulerKind::RoundRobin | SchedulerKind::SgLangLike | SchedulerKind::LlumnixLike => {
                BalancePolicy::Off
            }
        }
    }

    /// Does this policy exchange LoadTracker gossip?
    pub fn uses_gossip(&self) -> bool {
        self.is_cascade()
    }

    /// Any CascadeInfer variant (incl. ablations).
    pub fn is_cascade(&self) -> bool {
        !matches!(
            self,
            SchedulerKind::RoundRobin | SchedulerKind::SgLangLike | SchedulerKind::LlumnixLike
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Cascade => "CascadeInfer",
            SchedulerKind::RoundRobin => "vLLM+RR",
            SchedulerKind::SgLangLike => "SGLang+RR",
            SchedulerKind::LlumnixLike => "Llumnix",
            SchedulerKind::Chain => "Chain",
            SchedulerKind::NoPipeline => "NoPipeline",
            SchedulerKind::CascadeQuantityRefine => "QuantityRefine",
            SchedulerKind::CascadeMemoryRefine => "MemoryRefine",
            SchedulerKind::CascadeInterStageOnly => "InterStageOnly",
            SchedulerKind::CascadeRoundRobinIntra => "RRIntra",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cascade_axes() {
        let k = SchedulerKind::Cascade;
        assert_eq!(k.layout(), Layout::Planned);
        assert_eq!(k.refine_policy(), RefinePolicy::Adaptive);
        assert_eq!(k.balance_policy(), BalancePolicy::Full);
        assert!(k.is_cascade());
        assert!(k.uses_gossip());
    }

    #[test]
    fn baselines_are_flat_and_gossip_free() {
        for k in [SchedulerKind::RoundRobin, SchedulerKind::SgLangLike, SchedulerKind::LlumnixLike]
        {
            assert_eq!(k.layout(), Layout::Flat);
            assert_eq!(k.balance_policy(), BalancePolicy::Off);
            assert!(!k.uses_gossip());
            assert!(!k.is_cascade());
        }
    }

    #[test]
    fn ablations_toggle_one_axis() {
        assert_eq!(SchedulerKind::Chain.layout(), Layout::Chain);
        assert_eq!(SchedulerKind::Chain.refine_policy(), RefinePolicy::Adaptive);
        assert_eq!(SchedulerKind::NoPipeline.layout(), Layout::Flat);
        assert_eq!(SchedulerKind::CascadeQuantityRefine.refine_policy(), RefinePolicy::Quantity);
        assert_eq!(SchedulerKind::CascadeMemoryRefine.refine_policy(), RefinePolicy::Memory);
        assert_eq!(
            SchedulerKind::CascadeInterStageOnly.balance_policy(),
            BalancePolicy::InterStageOnly
        );
        assert_eq!(
            SchedulerKind::CascadeRoundRobinIntra.balance_policy(),
            BalancePolicy::RoundRobinIntra
        );
    }

    #[test]
    fn names_unique() {
        let all = [
            SchedulerKind::Cascade,
            SchedulerKind::RoundRobin,
            SchedulerKind::SgLangLike,
            SchedulerKind::LlumnixLike,
            SchedulerKind::Chain,
            SchedulerKind::NoPipeline,
            SchedulerKind::CascadeQuantityRefine,
            SchedulerKind::CascadeMemoryRefine,
            SchedulerKind::CascadeInterStageOnly,
            SchedulerKind::CascadeRoundRobinIntra,
        ];
        let mut names: Vec<&str> = all.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }
}
