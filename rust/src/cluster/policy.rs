//! Scheduler policy taxonomy: CascadeInfer, its ablations, and the
//! §6.1 baselines, expressed as orthogonal (layout, refinement,
//! balancing, dispatch) axes so the ablation figures (14–16) toggle
//! exactly one axis at a time.
//!
//! # The open taxonomy: [`PolicySpec`]
//!
//! Every scheduling scenario is a first-class **`PolicySpec`** value —
//! a bag of orthogonal axes the cluster branches on.  The event loop
//! ([`super::driver`]), the arrival router ([`super::router`]), and the
//! bid-ask handlers never compare against a scheduler *kind*; they read
//! `spec.layout`, `spec.refine`, `spec.balance`, `spec.dispatch`, and
//! `spec.gossip`.  Adding a new scenario therefore never touches the
//! event loop: define a spec (or type a `custom:` string on the CLI)
//! and run it.
//!
//! Specs are obtained three ways:
//!
//! 1. **Registry names** — [`PolicySpec::resolve`] maps every paper
//!    scheduler/ablation name (and a few aliases) to its spec:
//!    `cascade`, `vllm`, `sglang`, `llumnix`, `chain`, `nopipeline`,
//!    `quantity`, `memory`, `interstage`, `rrintra`, `sjf`.
//! 2. **Custom axis strings** — ad-hoc combinations the closed enum
//!    could never express, e.g.
//!    `custom:layout=planned,refine=memory,balance=rrintra` or
//!    `custom:layout=flat,dispatch=shortestfirst,gossip=off`.
//! 3. **The [`SchedulerKind`] compat shim** — the legacy closed enum
//!    survives for existing call sites and converts losslessly via
//!    `From<SchedulerKind> for PolicySpec`.

use super::pd::PdSpec;
use crate::predict::PredictorSpec;

use std::fmt;

/// Stage layout policy (Fig. 14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// §4.2 DP-planned multi-stage pipeline.
    Planned,
    /// One instance per stage (the "chain" ablation).
    Chain,
    /// All instances in a single stage ("no-pipeline").
    Flat,
    /// Prefill/decode disaggregation: the fleet splits into a prefill
    /// pool and a decode pool, completed prefills hand their KV off to
    /// a decode instance through the migration cost model (see
    /// [`super::pd`]).
    Disaggregated(PdSpec),
}

/// Parse a layout axis value — the `--layout` flag and the
/// `custom:layout=` axis share this grammar.
pub fn parse_layout(value: &str) -> Result<Layout, String> {
    match value {
        "planned" => Ok(Layout::Planned),
        "chain" => Ok(Layout::Chain),
        "flat" => Ok(Layout::Flat),
        v if v == "pd" || v.starts_with("pd:") => Ok(Layout::Disaggregated(PdSpec::parse(v)?)),
        _ => Err(format!(
            "unknown layout `{value}`; valid: planned|chain|flat|{}",
            PdSpec::GRAMMAR
        )),
    }
}

/// Boundary refinement policy (Fig. 15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefinePolicy {
    /// §4.3 QoE-optimal split with EMA + low-traffic freeze.
    Adaptive,
    /// Equalise request counts per stage.
    Quantity,
    /// Equalise cached-token memory per stage.
    Memory,
    Off,
}

/// Intra-/inter-stage balancing policy (Fig. 16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BalancePolicy {
    /// §4.4 bid-ask for both inter-stage handover and intra-stage
    /// outlier rebalancing.
    Full,
    /// Bid-ask on inter-stage handover only.
    InterStageOnly,
    /// Round-robin receiver choice (protocol ablation).
    RoundRobinIntra,
    /// Llumnix-style periodic, length-agnostic rebalance: every 250 ms
    /// move one sequence from the most- to the least-memory-loaded
    /// instance (the §2.4 criticism, reproduced as a baseline).
    PeriodicLengthAgnostic,
    Off,
}

impl BalancePolicy {
    /// Does this policy participate in the §4.4 bid-ask protocol
    /// (inter-stage handover + per-step rebalance hooks)?
    pub fn uses_bid_ask(&self) -> bool {
        matches!(
            self,
            BalancePolicy::Full | BalancePolicy::InterStageOnly | BalancePolicy::RoundRobinIntra
        )
    }
}

/// Arrival dispatch policy — which instance an incoming request lands
/// on.  This axis was previously hard-coded per `SchedulerKind` inside
/// the router; opening it makes SJF-style and queue-separation
/// scenarios (vllm-ltr, slice-level scheduling) pure spec changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate across all instances (vLLM/SGLang-style balancer).
    RoundRobin,
    /// Least memory demand across all instances (Llumnix's
    /// virtual-usage heuristic, simplified).
    LeastLoaded,
    /// §3.2: earliest stage covering the prompt length; within the
    /// stage, least token load (or round-robin under the Fig. 16
    /// `RoundRobinIntra` balance ablation).
    StageRouted,
    /// SJF-flavoured shortest-expected-wait dispatch (vllm-ltr's
    /// length ranking collapsed to placement): route each arrival to
    /// the instance with the least outstanding work — running tokens +
    /// queued prompt tokens + in-flight migration arrivals — so short
    /// requests never queue behind a long backlog when an emptier
    /// instance exists.
    ShortestFirst,
}

/// A first-class scheduling policy: the open, composable counterpart
/// of the closed [`SchedulerKind`] enum.  See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    /// Registry key (`"cascade"`, `"llumnix"`, …) or the canonical
    /// `custom:` serialization for ad-hoc specs.
    pub name: String,
    pub layout: Layout,
    pub refine: RefinePolicy,
    pub balance: BalancePolicy,
    pub dispatch: DispatchPolicy,
    /// Exchange §3.2 LoadTracker gossip between instances.
    pub gossip: bool,
    /// Relative engine speed (1.0 = vLLM-class; Llumnix's newer engine
    /// runs faster — §6.2 Fig. 8).  Seeds `ClusterConfig::engine_speed`.
    pub engine_speed: f64,
    /// Length predictor every scheduling consumer reads request
    /// lengths through (`oracle` = ground truth, the legacy default —
    /// see [`crate::predict`]).  Orthogonal to every other axis: any
    /// registry scheduler composes with any predictor.
    pub predictor: PredictorSpec,
}

/// Error resolving or parsing a policy name.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyError(pub String);

impl fmt::Display for PolicyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for PolicyError {}

impl PolicySpec {
    /// CascadeInfer: planned layout + adaptive refinement + full
    /// bid-ask + stage-routed dispatch.
    pub fn cascade() -> Self {
        Self {
            name: "cascade".into(),
            layout: Layout::Planned,
            refine: RefinePolicy::Adaptive,
            balance: BalancePolicy::Full,
            dispatch: DispatchPolicy::StageRouted,
            gossip: true,
            engine_speed: 1.0,
            predictor: PredictorSpec::Oracle,
        }
    }

    fn flat_rr(name: &str) -> Self {
        Self {
            name: name.into(),
            layout: Layout::Flat,
            refine: RefinePolicy::Off,
            balance: BalancePolicy::Off,
            dispatch: DispatchPolicy::RoundRobin,
            gossip: false,
            engine_speed: 1.0,
            predictor: PredictorSpec::Oracle,
        }
    }

    /// Canonical registry names, in presentation order.
    pub fn names() -> &'static [&'static str] {
        &[
            "cascade",
            "vllm",
            "sglang",
            "llumnix",
            "chain",
            "nopipeline",
            "quantity",
            "memory",
            "interstage",
            "rrintra",
            "sjf",
        ]
    }

    /// Resolve a scheduler name: a registry key (or alias), or a
    /// `custom:` axis string.  Errors list the valid choices.
    pub fn resolve(name: &str) -> Result<Self, PolicyError> {
        let lower = name.trim().to_ascii_lowercase();
        if let Some(body) = lower.strip_prefix("custom:") {
            return Self::parse_custom(body);
        }
        let spec = match lower.as_str() {
            "cascade" | "cascadeinfer" => Self::cascade(),
            "vllm" | "rr" | "roundrobin" => Self::flat_rr("vllm"),
            "sglang" => Self::flat_rr("sglang"),
            "llumnix" => Self {
                name: "llumnix".into(),
                dispatch: DispatchPolicy::LeastLoaded,
                balance: BalancePolicy::PeriodicLengthAgnostic,
                // Llumnix's newer engine runs faster (§6.2 Fig. 8).
                engine_speed: 1.25,
                ..Self::flat_rr("llumnix")
            },
            "chain" => Self {
                name: "chain".into(),
                layout: Layout::Chain,
                ..Self::cascade()
            },
            "nopipeline" | "flat" => Self {
                name: "nopipeline".into(),
                layout: Layout::Flat,
                refine: RefinePolicy::Off,
                ..Self::cascade()
            },
            "quantity" => Self {
                name: "quantity".into(),
                refine: RefinePolicy::Quantity,
                ..Self::cascade()
            },
            "memory" => Self {
                name: "memory".into(),
                refine: RefinePolicy::Memory,
                ..Self::cascade()
            },
            "interstage" => Self {
                name: "interstage".into(),
                balance: BalancePolicy::InterStageOnly,
                ..Self::cascade()
            },
            "rrintra" => Self {
                name: "rrintra".into(),
                balance: BalancePolicy::RoundRobinIntra,
                ..Self::cascade()
            },
            // Length-ranked SJF-style dispatch over flat instances
            // (vllm-ltr, "Efficient LLM Scheduling by Learning to
            // Rank") — a scenario the closed enum could not express.
            "sjf" | "shortestfirst" => Self {
                name: "sjf".into(),
                dispatch: DispatchPolicy::ShortestFirst,
                ..Self::flat_rr("sjf")
            },
            _ => {
                return Err(PolicyError(format!(
                    "unknown scheduler `{name}`; valid: {}, or custom:layout=..,refine=..,\
                     balance=..,dispatch=..[,gossip=on|off][,speed=F][,predictor=P]",
                    Self::names().join("|")
                )))
            }
        };
        Ok(spec)
    }

    /// Parse the body of a `custom:` spec: comma-separated `axis=value`
    /// pairs.  Unspecified axes default to CascadeInfer's. The spec's
    /// `name` is the canonical serialization, so `resolve(spec.name)`
    /// round-trips.
    fn parse_custom(body: &str) -> Result<Self, PolicyError> {
        let mut spec = Self::cascade();
        if body.trim().is_empty() {
            return Err(PolicyError("custom: spec needs at least one axis=value pair".into()));
        }
        for pair in body.split(',') {
            let pair = pair.trim();
            let (key, value) = pair.split_once('=').ok_or_else(|| {
                PolicyError(format!("custom axis `{pair}` is not of the form axis=value"))
            })?;
            let (key, value) = (key.trim(), value.trim());
            let bad = |valid: &str| {
                PolicyError(format!("unknown {key} value `{value}`; valid: {valid}"))
            };
            match key {
                "layout" => {
                    // `pd:2/2`-style values survive the comma split
                    // intact — PD parameters separate with `:` and `/`.
                    spec.layout = parse_layout(value).map_err(PolicyError)?;
                }
                "refine" => {
                    spec.refine = match value {
                        "adaptive" => RefinePolicy::Adaptive,
                        "quantity" => RefinePolicy::Quantity,
                        "memory" => RefinePolicy::Memory,
                        "off" => RefinePolicy::Off,
                        _ => return Err(bad("adaptive|quantity|memory|off")),
                    }
                }
                "balance" => {
                    spec.balance = match value {
                        "full" => BalancePolicy::Full,
                        "interstage" => BalancePolicy::InterStageOnly,
                        "rrintra" => BalancePolicy::RoundRobinIntra,
                        "periodic" => BalancePolicy::PeriodicLengthAgnostic,
                        "off" => BalancePolicy::Off,
                        _ => return Err(bad("full|interstage|rrintra|periodic|off")),
                    }
                }
                "dispatch" => {
                    spec.dispatch = match value {
                        "roundrobin" | "rr" => DispatchPolicy::RoundRobin,
                        "leastloaded" => DispatchPolicy::LeastLoaded,
                        "stagerouted" => DispatchPolicy::StageRouted,
                        "shortestfirst" | "sjf" => DispatchPolicy::ShortestFirst,
                        _ => return Err(bad("roundrobin|leastloaded|stagerouted|shortestfirst")),
                    }
                }
                "gossip" => {
                    spec.gossip = match value {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        _ => return Err(bad("on|off")),
                    }
                }
                "speed" => {
                    spec.engine_speed = value.parse::<f64>().ok().filter(|s| *s > 0.0).ok_or_else(
                        || PolicyError(format!("speed `{value}` is not a positive number")),
                    )?;
                }
                "predictor" => {
                    // `noisy:0.5`-style values survive the comma split
                    // intact — the parameter separator is `:`.
                    spec.predictor = PredictorSpec::parse(value).map_err(PolicyError)?;
                }
                _ => {
                    return Err(PolicyError(format!(
                        "unknown custom axis `{key}`; valid: \
                         layout|refine|balance|dispatch|gossip|speed|predictor"
                    )))
                }
            }
        }
        spec.name = spec.custom_name();
        Ok(spec)
    }

    /// Canonical `custom:` serialization of this spec's axes.
    pub fn custom_name(&self) -> String {
        let layout = match self.layout {
            Layout::Planned => "planned".to_string(),
            Layout::Chain => "chain".to_string(),
            Layout::Flat => "flat".to_string(),
            Layout::Disaggregated(pd) => pd.name(),
        };
        let refine = match self.refine {
            RefinePolicy::Adaptive => "adaptive",
            RefinePolicy::Quantity => "quantity",
            RefinePolicy::Memory => "memory",
            RefinePolicy::Off => "off",
        };
        let balance = match self.balance {
            BalancePolicy::Full => "full",
            BalancePolicy::InterStageOnly => "interstage",
            BalancePolicy::RoundRobinIntra => "rrintra",
            BalancePolicy::PeriodicLengthAgnostic => "periodic",
            BalancePolicy::Off => "off",
        };
        let dispatch = match self.dispatch {
            DispatchPolicy::RoundRobin => "roundrobin",
            DispatchPolicy::LeastLoaded => "leastloaded",
            DispatchPolicy::StageRouted => "stagerouted",
            DispatchPolicy::ShortestFirst => "shortestfirst",
        };
        let gossip = if self.gossip { "on" } else { "off" };
        let mut s = format!(
            "custom:layout={layout},refine={refine},balance={balance},\
             dispatch={dispatch},gossip={gossip}"
        );
        if self.engine_speed != 1.0 {
            s.push_str(&format!(",speed={}", self.engine_speed));
        }
        if !self.predictor.is_oracle() {
            s.push_str(&format!(",predictor={}", self.predictor.name()));
        }
        s
    }
}

/// Top-level scheduler selection — the **legacy closed enum**, kept as
/// a thin compatibility shim.  Each variant maps into the registry via
/// [`SchedulerKind::spec`] / `From<SchedulerKind> for PolicySpec`; all
/// cluster behavior is derived from the spec's axes, never from the
/// variant itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// CascadeInfer: planned layout + adaptive refinement + full bid-ask.
    Cascade,
    /// vLLM-style instances behind a round-robin balancer.
    RoundRobin,
    /// SGLang-style instances behind a round-robin balancer (different
    /// engine speed is configured via `ClusterConfig::engine_speed`).
    SgLangLike,
    /// Llumnix: load-aware dispatch + length-agnostic rebalancing.
    LlumnixLike,
    /// Ablation: chain layout (one instance per stage).
    Chain,
    /// Ablation: single stage holding every instance.
    NoPipeline,
    /// Ablation: quantity-based refinement.
    CascadeQuantityRefine,
    /// Ablation: memory-based refinement.
    CascadeMemoryRefine,
    /// Ablation: inter-stage bid-ask only (no intra-stage rebalance).
    CascadeInterStageOnly,
    /// Ablation: round-robin receiver selection instead of bid-ask.
    CascadeRoundRobinIntra,
}

impl SchedulerKind {
    /// Registry key this legacy variant maps to.
    pub fn registry_name(&self) -> &'static str {
        match self {
            SchedulerKind::Cascade => "cascade",
            SchedulerKind::RoundRobin => "vllm",
            SchedulerKind::SgLangLike => "sglang",
            SchedulerKind::LlumnixLike => "llumnix",
            SchedulerKind::Chain => "chain",
            SchedulerKind::NoPipeline => "nopipeline",
            SchedulerKind::CascadeQuantityRefine => "quantity",
            SchedulerKind::CascadeMemoryRefine => "memory",
            SchedulerKind::CascadeInterStageOnly => "interstage",
            SchedulerKind::CascadeRoundRobinIntra => "rrintra",
        }
    }

    /// The full spec for this variant.
    ///
    /// `engine_speed` is normalised to 1.0 — historically
    /// `ClusterConfig::new` never set a speed for any kind and callers
    /// (benches, figures) applied their own, so the shim preserves that
    /// exactly.  Resolving the registry *name* instead (`llumnix`)
    /// yields the speed the CLI always applied (1.25).
    pub fn spec(&self) -> PolicySpec {
        let mut spec = PolicySpec::resolve(self.registry_name())
            .expect("legacy kinds are always registered");
        spec.engine_speed = 1.0;
        spec
    }

    pub fn layout(&self) -> Layout {
        self.spec().layout
    }

    pub fn refine_policy(&self) -> RefinePolicy {
        self.spec().refine
    }

    pub fn balance_policy(&self) -> BalancePolicy {
        self.spec().balance
    }

    /// Does this policy exchange LoadTracker gossip?
    pub fn uses_gossip(&self) -> bool {
        self.spec().gossip
    }

    /// Any CascadeInfer variant (incl. ablations).
    pub fn is_cascade(&self) -> bool {
        !matches!(
            self,
            SchedulerKind::RoundRobin | SchedulerKind::SgLangLike | SchedulerKind::LlumnixLike
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Cascade => "CascadeInfer",
            SchedulerKind::RoundRobin => "vLLM+RR",
            SchedulerKind::SgLangLike => "SGLang+RR",
            SchedulerKind::LlumnixLike => "Llumnix",
            SchedulerKind::Chain => "Chain",
            SchedulerKind::NoPipeline => "NoPipeline",
            SchedulerKind::CascadeQuantityRefine => "QuantityRefine",
            SchedulerKind::CascadeMemoryRefine => "MemoryRefine",
            SchedulerKind::CascadeInterStageOnly => "InterStageOnly",
            SchedulerKind::CascadeRoundRobinIntra => "RRIntra",
        }
    }

    /// All legacy variants (compat tests iterate this).
    pub fn all() -> [SchedulerKind; 10] {
        [
            SchedulerKind::Cascade,
            SchedulerKind::RoundRobin,
            SchedulerKind::SgLangLike,
            SchedulerKind::LlumnixLike,
            SchedulerKind::Chain,
            SchedulerKind::NoPipeline,
            SchedulerKind::CascadeQuantityRefine,
            SchedulerKind::CascadeMemoryRefine,
            SchedulerKind::CascadeInterStageOnly,
            SchedulerKind::CascadeRoundRobinIntra,
        ]
    }
}

impl From<SchedulerKind> for PolicySpec {
    fn from(k: SchedulerKind) -> Self {
        k.spec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cascade_axes() {
        let k = SchedulerKind::Cascade;
        assert_eq!(k.layout(), Layout::Planned);
        assert_eq!(k.refine_policy(), RefinePolicy::Adaptive);
        assert_eq!(k.balance_policy(), BalancePolicy::Full);
        assert!(k.is_cascade());
        assert!(k.uses_gossip());
        assert_eq!(k.spec().dispatch, DispatchPolicy::StageRouted);
    }

    #[test]
    fn baselines_are_flat_and_gossip_free() {
        for k in [SchedulerKind::RoundRobin, SchedulerKind::SgLangLike, SchedulerKind::LlumnixLike]
        {
            assert_eq!(k.layout(), Layout::Flat);
            assert!(!k.balance_policy().uses_bid_ask());
            assert!(!k.uses_gossip());
            assert!(!k.is_cascade());
        }
        assert_eq!(SchedulerKind::RoundRobin.balance_policy(), BalancePolicy::Off);
        assert_eq!(
            SchedulerKind::LlumnixLike.balance_policy(),
            BalancePolicy::PeriodicLengthAgnostic
        );
        assert_eq!(SchedulerKind::LlumnixLike.spec().dispatch, DispatchPolicy::LeastLoaded);
    }

    #[test]
    fn ablations_toggle_one_axis() {
        assert_eq!(SchedulerKind::Chain.layout(), Layout::Chain);
        assert_eq!(SchedulerKind::Chain.refine_policy(), RefinePolicy::Adaptive);
        assert_eq!(SchedulerKind::NoPipeline.layout(), Layout::Flat);
        assert_eq!(SchedulerKind::CascadeQuantityRefine.refine_policy(), RefinePolicy::Quantity);
        assert_eq!(SchedulerKind::CascadeMemoryRefine.refine_policy(), RefinePolicy::Memory);
        assert_eq!(
            SchedulerKind::CascadeInterStageOnly.balance_policy(),
            BalancePolicy::InterStageOnly
        );
        assert_eq!(
            SchedulerKind::CascadeRoundRobinIntra.balance_policy(),
            BalancePolicy::RoundRobinIntra
        );
    }

    #[test]
    fn names_unique() {
        let all = SchedulerKind::all();
        let mut names: Vec<&str> = all.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
        let mut keys: Vec<&str> = all.iter().map(|k| k.registry_name()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), all.len());
    }

    #[test]
    fn registry_round_trips_every_name() {
        for &name in PolicySpec::names() {
            let spec = PolicySpec::resolve(name).unwrap();
            assert_eq!(spec.name, name, "canonical name must round-trip");
            assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
        }
    }

    #[test]
    fn legacy_kinds_map_into_registry() {
        for k in SchedulerKind::all() {
            let via_registry = PolicySpec::resolve(k.registry_name()).unwrap();
            let mut shim = k.spec();
            // The shim normalises speed (see `SchedulerKind::spec`);
            // all other axes must agree with the registry.
            shim.engine_speed = via_registry.engine_speed;
            assert_eq!(shim, via_registry, "{k:?}");
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(PolicySpec::resolve("RR").unwrap().name, "vllm");
        assert_eq!(PolicySpec::resolve("CascadeInfer").unwrap().name, "cascade");
        assert_eq!(PolicySpec::resolve("flat").unwrap().name, "nopipeline");
        assert_eq!(PolicySpec::resolve("shortestfirst").unwrap().name, "sjf");
        assert!(PolicySpec::resolve("bogus").is_err());
    }

    #[test]
    fn custom_spec_parses_and_round_trips() {
        let spec =
            PolicySpec::resolve("custom:layout=planned,refine=memory,balance=rrintra").unwrap();
        assert_eq!(spec.layout, Layout::Planned);
        assert_eq!(spec.refine, RefinePolicy::Memory);
        assert_eq!(spec.balance, BalancePolicy::RoundRobinIntra);
        assert_eq!(spec.dispatch, DispatchPolicy::StageRouted); // default
        assert!(spec.gossip);
        // name is the canonical serialization and resolves back to the
        // identical spec.
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
    }

    #[test]
    fn custom_spec_speed_and_gossip() {
        let spec = PolicySpec::resolve(
            "custom:layout=flat,dispatch=sjf,gossip=off,speed=1.25,refine=off,balance=off",
        )
        .unwrap();
        assert_eq!(spec.dispatch, DispatchPolicy::ShortestFirst);
        assert!(!spec.gossip);
        assert_eq!(spec.engine_speed, 1.25);
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
    }

    #[test]
    fn pd_layout_axis_parses_and_round_trips() {
        // Bare `pd` = auto split, default boundary/window.
        let spec = PolicySpec::resolve("custom:layout=pd").unwrap();
        assert_eq!(spec.layout, Layout::Disaggregated(PdSpec::auto()));
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
        // Explicit pools: the `:`/`/` separators survive the comma
        // split exactly like `predictor=noisy:0.5`.
        let spec = PolicySpec::resolve("custom:layout=pd:2/2,balance=off").unwrap();
        match spec.layout {
            Layout::Disaggregated(pd) => {
                assert_eq!((pd.prefill, pd.decode), (2, 2));
                assert_eq!(pd.short_boundary, PdSpec::DEFAULT_SHORT_BOUNDARY);
                assert_eq!(pd.window_us, PdSpec::DEFAULT_WINDOW_US);
            }
            other => panic!("expected Disaggregated, got {other:?}"),
        }
        assert!(spec.name.contains("layout=pd:2/2"), "{}", spec.name);
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
        // Full grammar: pools, short/long boundary, waiting window.
        let spec = PolicySpec::resolve("custom:layout=pd:3/1:256:5000").unwrap();
        match spec.layout {
            Layout::Disaggregated(pd) => {
                assert_eq!((pd.prefill, pd.decode), (3, 1));
                assert_eq!(pd.short_boundary, 256);
                assert_eq!(pd.window_us, 5000);
            }
            other => panic!("expected Disaggregated, got {other:?}"),
        }
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
        // The `--layout` flag shares the same parser.
        assert_eq!(parse_layout("flat").unwrap(), Layout::Flat);
        assert_eq!(parse_layout("pd").unwrap(), Layout::Disaggregated(PdSpec::auto()));
        assert!(parse_layout("pancake").is_err());
    }

    #[test]
    fn malformed_custom_specs_are_rejected() {
        for bad in [
            "custom:",
            "custom:layout",
            "custom:layout=weird",
            "custom:layout=pd:0/4",
            "custom:layout=pd:4/0",
            "custom:layout=pd:x",
            "custom:layout=pd:2",
            "custom:layout=pd:2/2:0",
            "custom:layout=pd:2/2:256:5000:extra",
            "custom:refine=speedy",
            "custom:balance=maybe",
            "custom:dispatch=psychic",
            "custom:gossip=sometimes",
            "custom:speed=fast",
            "custom:speed=-1.0",
            "custom:engine=v8",
            "custom:predictor=psychic",
            "custom:predictor=noisy",
            "custom:predictor=noisy:fast",
            "custom:predictor=bucket:1.5",
            "custom:predictor=ltr:-0.1",
        ] {
            assert!(PolicySpec::resolve(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn predictor_axis_parses_and_round_trips() {
        let spec = PolicySpec::resolve("custom:layout=planned,predictor=noisy:0.5").unwrap();
        assert_eq!(spec.predictor, PredictorSpec::Noisy { cv: 0.5 });
        assert!(spec.name.contains("predictor=noisy:0.5"), "{}", spec.name);
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
        // The `:` parameter separator survives the comma split.
        let spec = PolicySpec::resolve("custom:predictor=ltr:0.8,dispatch=sjf").unwrap();
        assert_eq!(spec.predictor, PredictorSpec::Ltr { pacc: 0.8 });
        assert_eq!(PolicySpec::resolve(&spec.name).unwrap(), spec);
    }

    #[test]
    fn every_registry_scheduler_defaults_to_the_oracle_predictor() {
        for &name in PolicySpec::names() {
            let spec = PolicySpec::resolve(name).unwrap();
            assert!(spec.predictor.is_oracle(), "{name} must default to oracle");
        }
        // The oracle default serializes away: no predictor axis in the
        // canonical custom name.
        assert!(!PolicySpec::cascade().custom_name().contains("predictor"));
    }
}
