//! Request routing & admission — the "router" layer of the cluster
//! split.
//!
//! Decides which instance an arriving request lands on under each
//! scheduler policy (§3.2 for CascadeInfer: earliest stage covering the
//! prompt length, least-loaded member within it), and owns the shared
//! round-robin counter that both RR dispatch and the Fig. 16
//! round-robin-intra ablation rotate on.  Every load probe used here
//! ([`crate::engine::Engine::token_load`],
//! [`crate::coordinator::MigrationManager::inbound_tokens`]) is an O(1)
//! running aggregate, so routing costs O(stage members) per arrival
//! rather than O(stage members x batch).

use crate::cluster::policy::{BalancePolicy, SchedulerKind};
use crate::coordinator::MigrationManager;
use crate::workload::Request;
use crate::{InstanceId, Time, Tokens};

use super::state::InstanceState;
use super::Cluster;

/// Index of the stage whose `[lo, hi)` range covers `len` (clamps to
/// the last stage — §3.2 routes to the earliest covering stage).
pub fn stage_for_len(ranges: &[(Tokens, Tokens)], len: Tokens) -> usize {
    for (i, &(_, hi)) in ranges.iter().enumerate() {
        if len < hi {
            return i;
        }
    }
    ranges.len() - 1
}

/// Stateful router: dispatch policy + the shared round-robin counter.
#[derive(Debug, Clone, Default)]
pub struct Router {
    rr_counter: usize,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take the next round-robin ticket (post-increment).
    pub fn next_rr(&mut self) -> usize {
        let v = self.rr_counter;
        self.rr_counter += 1;
        v
    }

    /// Pick the target instance for an arrival.
    pub fn route(
        &mut self,
        kind: SchedulerKind,
        req: &Request,
        stages: &[Vec<InstanceId>],
        ranges: &[(Tokens, Tokens)],
        instances: &[InstanceState],
        migration: &MigrationManager,
    ) -> InstanceId {
        match kind {
            SchedulerKind::RoundRobin | SchedulerKind::SgLangLike => {
                self.next_rr() % instances.len()
            }
            SchedulerKind::LlumnixLike => {
                // Load-aware, length-agnostic dispatch: least memory
                // demand (Llumnix's virtual-usage heuristic, simplified).
                (0..instances.len())
                    .min_by(|&a, &b| {
                        instances[a]
                            .engine
                            .memory_demand()
                            .total_cmp(&instances[b].engine.memory_demand())
                    })
                    .expect("cluster has instances")
            }
            _ => {
                // CascadeInfer: earliest stage covering the prompt
                // length (§3.2); within the stage, least-loaded member
                // — except under the Fig. 16 round-robin ablation,
                // which dispatches regardless of instance load.
                let s = stage_for_len(ranges, req.input_len);
                if kind.balance_policy() == BalancePolicy::RoundRobinIntra {
                    stages[s][self.next_rr() % stages[s].len()]
                } else {
                    // Counting in-flight migration arrivals prevents the
                    // herd effect on a momentarily-least-loaded member.
                    *stages[s]
                        .iter()
                        .min_by_key(|&&i| {
                            instances[i].engine.token_load() + migration.inbound_tokens(i)
                        })
                        .expect("stage has members")
                }
            }
        }
    }
}

impl Cluster {
    /// Admission: route the arrival per the scheduler policy, submit it
    /// to the chosen engine, and kick that engine if idle.
    pub(super) fn on_arrival(&mut self, now: Time, req: Request) {
        let target = self.router.route(
            self.cfg.scheduler,
            &req,
            &self.stages,
            &self.ranges,
            &self.instances,
            &self.migration,
        );
        self.instances[target].engine.submit(req);
        self.kick(now, target);
    }
}
