//! Request routing & admission — the "router" layer of the cluster
//! split.
//!
//! Decides which instance an arriving request lands on.  The choice is
//! driven purely by the spec's [`DispatchPolicy`] axis (plus the
//! balance axis for the Fig. 16 round-robin-intra ablation) — the
//! router never inspects a scheduler *kind*, so new dispatch scenarios
//! are pure [`crate::cluster::PolicySpec`] additions.  The router also
//! owns the shared round-robin counter that both RR dispatch and the
//! round-robin-intra ablation rotate on.  Every load probe used here
//! ([`crate::engine::Engine::token_load`],
//! [`crate::coordinator::MigrationManager::inbound_tokens`]) is an O(1)
//! running aggregate, so routing costs O(stage members) per arrival
//! rather than O(stage members x batch).  Load-aware choices compare
//! *capacity-normalized* loads ([`effective_wait`]), so heterogeneous
//! fleets route proportionally more work to faster instances while
//! homogeneous fleets (capacity exactly 1.0) behave bit-identically to
//! the raw-token comparison.

use crate::cluster::policy::{BalancePolicy, DispatchPolicy, PolicySpec};
use crate::coordinator::MigrationManager;
use crate::predict::LengthPredictor;
use crate::sim::RequestArena;
use crate::workload::Request;
use crate::{InstanceId, RequestId, Time, Tokens};

use super::state::InstanceState;
use super::Cluster;

/// Outstanding work on an instance, normalized by its relative
/// capacity: raw token load (running + queued) plus in-flight
/// migration arrivals, divided by capacity.  With capacity exactly 1.0
/// (homogeneous fleets) this equals the raw integer load as f64, so
/// orderings — including ties — match the legacy u64 comparison
/// bit for bit.
pub(super) fn effective_wait(ins: &InstanceState, migration: &MigrationManager) -> f64 {
    (ins.engine.token_load() + migration.inbound_tokens(ins.id)) as f64 / ins.capacity
}

/// Outstanding work as the *predictor* sees it: each resident sequence
/// is priced at its predicted final length (never below what it has
/// already grown to), each queued request at its predicted final, plus
/// in-flight migration arrivals — capacity-normalized like
/// [`effective_wait`].  O(resident sequences) rather than O(1), so it
/// is consulted only for predictors that claim absolute lengths
/// ([`LengthPredictor::predicts_absolute`]); `oracle` and `ltr`
/// dispatch keep the legacy observable load, bit for bit.  Predictions
/// come from the arena's cached column (every live sequence was
/// interned at admission); the recompute fallback is bit-identical
/// because the predictor is a pure seeded hash.
fn predicted_wait(
    ins: &InstanceState,
    migration: &MigrationManager,
    predictor: &LengthPredictor,
    arena: &RequestArena,
) -> f64 {
    let predicted = |req: &Request| {
        arena.predicted(req.id).unwrap_or_else(|| predictor.predicted_final(req))
    };
    let running: Tokens =
        ins.engine.running().iter().map(|s| predicted(&s.req).max(s.current_len())).sum();
    let queued: Tokens = ins.engine.queued().map(|s| predicted(&s.req)).sum();
    (running + queued + migration.inbound_tokens(ins.id)) as f64 / ins.capacity
}

/// Dispatch-time wait estimate: predicted outstanding work when the
/// predictor produces absolute lengths, the legacy observable load
/// otherwise.
fn wait_estimate(
    ins: &InstanceState,
    migration: &MigrationManager,
    predictor: &LengthPredictor,
    arena: &RequestArena,
) -> f64 {
    if predictor.predicts_absolute() {
        predicted_wait(ins, migration, predictor, arena)
    } else {
        effective_wait(ins, migration)
    }
}

/// Index of the stage whose `[lo, hi)` range covers `len` (clamps to
/// the last stage — §3.2 routes to the earliest covering stage).
/// Binary search over the ascending `hi` boundaries: this runs per
/// arrival and per outgrown-sequence probe, and the cached ranges are
/// kept sorted by construction ([`super::Cluster`]'s `rebuild_ranges`).
pub fn stage_for_len(ranges: &[(Tokens, Tokens)], len: Tokens) -> usize {
    debug_assert!(
        ranges.windows(2).all(|w| w[0].1 <= w[1].1),
        "stage ranges must have ascending upper bounds: {ranges:?}"
    );
    // An empty range list (momentary under re-planning/churn) maps to
    // stage 0 instead of underflowing `len() - 1` on usize.
    if ranges.is_empty() {
        return 0;
    }
    ranges.partition_point(|&(_, hi)| hi <= len).min(ranges.len() - 1)
}

/// Stateful router: dispatch policy + the shared round-robin counter,
/// plus a scratch buffer of per-candidate wait estimates so each
/// candidate's wait is computed exactly once per arrival (a `min_by`
/// over [`wait_estimate`] re-evaluates `predicted_wait` — O(resident
/// sequences) — roughly twice per comparison under absolute
/// predictors).
#[derive(Debug, Clone, Default)]
pub struct Router {
    rr_counter: usize,
    wait_scratch: Vec<f64>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take the next round-robin ticket (post-increment).
    pub fn next_rr(&mut self) -> usize {
        let v = self.rr_counter;
        self.rr_counter += 1;
        v
    }

    /// Member with the least [`wait_estimate`], each candidate priced
    /// exactly once into the scratch buffer.  First index wins ties —
    /// the same order `Iterator::min_by` returns ("if several elements
    /// are equally minimum, the first element is returned"), so the
    /// precompute is bit-identical to the former per-comparison scan.
    #[allow(clippy::too_many_arguments)]
    fn least_wait(
        &mut self,
        members: &[InstanceId],
        instances: &[InstanceState],
        migration: &MigrationManager,
        predictor: &LengthPredictor,
        arena: &RequestArena,
    ) -> InstanceId {
        debug_assert!(!members.is_empty(), "least_wait needs candidates");
        self.wait_scratch.clear();
        self.wait_scratch.extend(
            members.iter().map(|&i| wait_estimate(&instances[i], migration, predictor, arena)),
        );
        let mut best = 0;
        for (k, w) in self.wait_scratch.iter().enumerate().skip(1) {
            if *w < self.wait_scratch[best] {
                best = k;
            }
        }
        members[best]
    }

    /// Pick the target instance for an arrival, per the spec's
    /// dispatch axis.
    ///
    /// `live` is the ascending list of *admitting* instance ids (the
    /// whole fleet on a churn-free run, where it is exactly `0..n` and
    /// every choice below reduces bit-identically to the legacy
    /// whole-fleet scan).  Under churn, draining/dead/absent instances
    /// are simply not in the list, so dispatch can never land on them.
    #[allow(clippy::too_many_arguments)]
    pub fn route(
        &mut self,
        spec: &PolicySpec,
        req: &Request,
        stages: &[Vec<InstanceId>],
        ranges: &[(Tokens, Tokens)],
        instances: &[InstanceState],
        live: &[InstanceId],
        migration: &MigrationManager,
        predictor: &LengthPredictor,
        arena: &RequestArena,
    ) -> InstanceId {
        match spec.dispatch {
            DispatchPolicy::RoundRobin => live[self.next_rr() % live.len()],
            DispatchPolicy::LeastLoaded => {
                // Load-aware, length-agnostic dispatch: least memory
                // demand (Llumnix's virtual-usage heuristic, simplified).
                live.iter()
                    .copied()
                    .min_by(|&a, &b| {
                        instances[a]
                            .engine
                            .memory_demand()
                            .total_cmp(&instances[b].engine.memory_demand())
                    })
                    .expect("cluster has admitting instances")
            }
            DispatchPolicy::ShortestFirst => {
                // SJF-flavoured shortest-expected-wait: least total
                // outstanding work — `token_load` counts running *and*
                // queued tokens, plus in-flight migration arrivals —
                // normalized by instance capacity, so a fast instance
                // with proportionally more queued tokens still reads
                // as the shorter wait; first index on ties —
                // deterministic.  Short requests never queue behind a
                // long backlog when an effectively-emptier instance
                // exists.
                self.least_wait(live, instances, migration, predictor, arena)
            }
            DispatchPolicy::StageRouted => {
                // CascadeInfer: earliest stage covering the routing
                // length (§3.2) — the prompt length under `oracle`
                // (legacy behavior, bit-identical), the predicted
                // *final* length under absolute predictors, or a rank
                // quantile under `ltr` (which never sees absolute
                // lengths: rank r maps to stage ⌊r·n⌋).  Within the
                // stage, least-loaded member — except under the
                // Fig. 16 round-robin ablation, which dispatches
                // regardless of instance load.
                let s = match predictor.stage_rank(req) {
                    Some(rank) => ((rank * ranges.len() as f64) as usize).min(ranges.len() - 1),
                    None => stage_for_len(ranges, predictor.route_len(req)),
                };
                // Under churn a stage can be momentarily memberless
                // (fewer live instances than stages); fall back to the
                // whole admitting fleet.  Churn-free, stages are never
                // empty and this binds `&stages[s]` unchanged.
                let members: &[InstanceId] =
                    if stages[s].is_empty() { live } else { &stages[s] };
                if spec.balance == BalancePolicy::RoundRobinIntra {
                    members[self.next_rr() % members.len()]
                } else {
                    // Counting in-flight migration arrivals prevents the
                    // herd effect on a momentarily-least-loaded member;
                    // capacity normalization keeps a fast member
                    // preferred until it carries its fair (larger)
                    // share.
                    self.least_wait(members, instances, migration, predictor, arena)
                }
            }
        }
    }
}

impl Cluster {
    /// Admission: route the arrival per the policy spec, submit it to
    /// the chosen engine, and kick that engine if idle.
    ///
    /// A request whose *final* length exceeds the routed instance's
    /// total KV pool can never be admitted by the FCFS engine — it
    /// would sit at the queue head and wedge the instance forever
    /// (reachable through small TP slices, e.g. 70B at TP2 on an H100
    /// pools only ~28K tokens).  Such requests are rejected here with
    /// a diagnostic instead of submitted.
    ///
    /// The check reads the length through the policy's predictor
    /// ([`LengthPredictor::admit_len`]): the true final under `oracle`
    /// (legacy, bit-identical), the predicted final under absolute
    /// predictors.  An *under-prediction* that slips past the predicted
    /// check but whose true final can never fit the pool escalates
    /// through the same reject path — counted in
    /// `RunStats::predict_escalations` — instead of wedging the
    /// instance mid-decode.
    pub(super) fn on_arrival(&mut self, now: Time, req: Request) {
        // A fleet can be momentarily admission-less under churn (every
        // instance draining while a join still boots).  Park the
        // arrival on the capped readmission/backoff path instead of
        // indexing into an empty live list; unreachable churn-free.
        if !self.cfg.churn.is_none() && self.admitting.is_empty() {
            self.schedule_readmit(now, req);
            return;
        }
        // Arena lifetime starts here: intern the request with its
        // cached prediction before routing, so every downstream
        // consumer (predicted-wait dispatch, misprediction accounting)
        // reads the SoA columns instead of re-hashing.
        let predicted = self.predictor.predicted_final(&req);
        self.arena.intern(&req, predicted);
        // Disaggregated layouts bypass the dispatch router: arrivals
        // enter the short/long prefill queues instead (see `super::pd`).
        if self.pd.is_some() {
            self.pd_on_arrival(now, req);
            return;
        }
        let mut target = self.router.route(
            &self.cfg.policy,
            &req,
            &self.stages,
            &self.ranges,
            &self.instances,
            &self.admitting,
            &self.migration,
            &self.predictor,
            &self.arena,
        );
        let admit_len = self.predictor.admit_len(&req);
        if !self.instances[target].engine.can_ever_hold(admit_len) {
            // Reject-or-reroute: the routed pool can never hold the
            // request, but a sibling with a larger pool (mixed-TP
            // fleets) may.  Only fleets where the routed pool would
            // have rejected reach this scan, so uniformly-sized fleets
            // behave bit-identically to the reject-only path.
            match self.admit_reroute(admit_len) {
                Some(alt) => {
                    self.stats.admit_reroutes += 1;
                    target = alt;
                }
                None => {
                    self.reject(target, req.id, admit_len);
                    return;
                }
            }
        }
        // Escalation: the predicted length fit, but the true final
        // never can.  Under `oracle` `admit_len == final_len`, so this
        // branch is unreachable and admission is exactly the legacy
        // single check.  The true final gets the same reroute chance
        // before the escalation is recorded as a rejection.
        let final_len = req.final_len();
        if admit_len < final_len && !self.instances[target].engine.can_ever_hold(final_len) {
            match self.admit_reroute(final_len) {
                Some(alt) => {
                    self.stats.admit_reroutes += 1;
                    target = alt;
                }
                None => {
                    self.stats.predict_escalations += 1;
                    self.reject(target, req.id, final_len);
                    return;
                }
            }
        }
        self.instances[target].engine.submit(req);
        self.kick(now, target);
    }

    /// Least-loaded *admitting* instance whose KV pool can ever hold
    /// `len` — the reroute fallback consulted only after the routed
    /// target's own pool has refused.  Load is the capacity-normalized
    /// observable wait ([`effective_wait`]); first index wins ties.
    pub(super) fn admit_reroute(&self, len: Tokens) -> Option<InstanceId> {
        let mut best: Option<(f64, InstanceId)> = None;
        for &i in &self.admitting {
            let ins = &self.instances[i];
            if !ins.engine.can_ever_hold(len) {
                continue;
            }
            let w = effective_wait(ins, &self.migration);
            if best.is_none_or(|(bw, _)| w < bw) {
                best = Some((w, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Record an admission rejection (shared by the predicted-length
    /// check and the under-prediction escalation path).
    pub(super) fn reject(&mut self, target: InstanceId, request: RequestId, final_len: Tokens) {
        // Rejection ends the request's arena lifetime (never submitted).
        self.arena.release(request);
        self.stats.rejected += 1;
        if self.stats.rejections.len() < super::MAX_REJECTION_DETAILS {
            self.stats.rejections.push(super::RejectedRequest {
                request,
                instance: target,
                final_len,
                pool_tokens: self.instances[target].engine.kv().capacity_tokens(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::stage_for_len;

    #[test]
    fn stage_for_len_clamps_and_guards_empty() {
        let ranges = [(0, 512), (512, 4096), (4096, 131_072)];
        assert_eq!(stage_for_len(&ranges, 0), 0);
        assert_eq!(stage_for_len(&ranges, 511), 0);
        assert_eq!(stage_for_len(&ranges, 512), 1);
        assert_eq!(stage_for_len(&ranges, 131_072), 2, "past the last hi clamps");
        // An empty range list must not underflow `len() - 1`.
        assert_eq!(stage_for_len(&[], 1024), 0);
    }
}
