//! MILS cluster — the discrete-event testbed every figure runs on.
//!
//! Ties together the substrate: N continuous-batching engine instances
//! ([`crate::engine`]) priced by the attention cost model, organised by
//! a scheduler policy.  For CascadeInfer the instances are partitioned
//! into length-specialized stages (§4.2), gossip load reports (§3.2),
//! refine stage boundaries (§4.3), and migrate sequences through the
//! decentralized bid-ask protocol (§4.4) with live KV migration (§5).
//! Baseline policies (round-robin, Llumnix-like, chain, no-pipeline,
//! naive refinement) share the same event loop so comparisons are
//! apples-to-apples.
//!
//! # Architecture: policy / driver / router / state
//!
//! The simulator is layered across five files so the event loop, the
//! dispatch policy, and per-instance bookkeeping evolve independently.
//! Scheduling behavior is driven entirely by the axes of a
//! [`PolicySpec`] (`policy.rs`) — layout, refinement, balancing,
//! dispatch, gossip — never by comparing a scheduler *kind*, so new
//! scenarios are spec values (or `custom:` CLI strings), not event-loop
//! edits:
//!
//! * `cluster/driver.rs` — the **driver**: the event alphabet, the
//!   discrete-event clock and dispatch loop ([`Cluster::run`]), and the
//!   periodic timers (gossip / refine / replan / baseline rebalance).
//! * `cluster/router.rs` — the **router**: request routing & admission
//!   (§3.2 stage selection, least-loaded member, the shared
//!   round-robin counter the ablations rotate on).
//! * `cluster/state.rs` — the **state**: `InstanceState`, the
//!   per-instance bundle (engine, load tracker, bid-ask state machine,
//!   busy flag, offer cooldown).  Load, memory demand, and batch
//!   composition are maintained as *running aggregates* — the engine
//!   keeps `token_load` incrementally, the migration manager keeps
//!   per-instance inbound/outbound sums, the receiver queue keeps its
//!   buffered length — so every `StepDone`/gossip/bid probe is O(1)
//!   amortized instead of an O(batch) rescan of live sequences.
//! * this file — configuration, cluster construction (offline pipeline
//!   planning), the §4.4 bid-ask + §5 live-migration protocol
//!   handlers, and the public API ([`run_experiment`]).
//!
//! # Simulation core: the two-level macro-stepped loop
//!
//! The driver runs a **two-level loop**.  The outer level is a classic
//! discrete-event loop over *interesting* instants only — arrivals,
//! periodic timers (gossip / refine / replan / baseline rebalance), and
//! §4.4 protocol deliveries.  The inner level advances each engine
//! **inline between those instants**: when instance `i` finishes an
//! iteration that ends before every queued event, its `StepDone` would
//! have popped next anyway, so the driver handles the iteration
//! boundary (snapshot marks, §4.4 post-step hooks) and starts the next
//! iteration immediately — zero event-queue pushes/pops, zero dispatch
//! branches, zero timer checks per decode iteration.  Policies with no
//! per-iteration hooks (no bid-ask balancing) go further and batch
//! whole stretches through [`crate::engine::Engine::run_until`], which
//! returns a compact [`crate::engine::MacroOutcome`] (completions with
//! exact timestamps, iterations run, tokens advanced).  The
//! [`crate::sim::EventQueue`] backs the outer level with a one-slot
//! front register so the residual schedule-then-pop pattern also skips
//! the heap.
//!
//! **Bit-identity invariant**: macro-stepping is a *traversal* change,
//! never a *semantics* change.  Per-iteration latencies, float
//! arithmetic order, admission/preemption decisions, FIFO tie-breaks
//! (an inline boundary corresponds to a `StepDone` that would have
//! carried the youngest insertion seq, so it loses every timestamp
//! tie — exactly like the inline path, which yields to any queued
//! event at or before its end), gossip sampling instants, and record
//! order are all preserved exactly.  `ClusterConfig::micro_step`
//! (CLI `sim --micro-step`) retains the historical
//! one-event-per-iteration loop, and `tests/macro_equivalence.rs`
//! asserts equal `Report::fingerprint()`s between the two paths for
//! every registry scheduler on sharegpt, heavytail, and bursty
//! workloads.
//!
//! # Simulation core: planet-scale storage tiers
//!
//! Three representation choices keep the core O(live state), not
//! O(trace length), at 1000+ instances — all pure representation
//! changes with pinned bit-identity:
//!
//! * **Calendar event queue** ([`crate::sim::EventQueue`]): under the
//!   front register sit a 512-slot x 2 ms **calendar wheel** for
//!   near-future events (decode completions, gossip ticks, the next
//!   arrival — O(1) insert/pop instead of O(log n) heap sifts) and a
//!   far-tier `BinaryHeap` for everything beyond the ~1 s horizon
//!   (refine/replan timers).  All three tiers share one total order —
//!   `(timestamp, insertion seq)` with two seq lanes: arrivals take the
//!   reserved *front-class* lane (`schedule_front_class`) so they win
//!   every same-instant tie against runtime events exactly as the
//!   pre-scheduled path did.  `tests/calendar_queue.rs` pins pop order
//!   bit-identical to a reference min-scan model.
//! * **Streaming workloads** ([`Cluster::run_stream`]): arrivals are
//!   pulled lazily from a [`crate::workload::WorkloadStream`] — exactly
//!   one pending `Arrival` event at a time, scheduled *before* the
//!   popped arrival dispatches so macro horizons and tie-breaks see the
//!   identical queue state as [`Cluster::run`] (which is the same loop
//!   over a pre-materialized slice).  Requires non-decreasing arrival
//!   times (asserted); unsorted traces must use the materialized path.
//! * **Arena request storage** ([`crate::sim::RequestArena`]): live
//!   request metadata — arrival, lengths, and the cached predictor
//!   output — lives in parallel SoA columns behind dense recycled
//!   slots.  **Lifetime rule**: intern at admission (`on_arrival`,
//!   before routing), release at completion recording or admission
//!   rejection; the arena therefore tracks *in-flight* requests
//!   (`RunStats::arena_high_water` reports the peak), never the trace.
//!   Caching `predicted_final` is bit-identical because every
//!   [`crate::predict::LengthPredictor`] is a pure seeded hash of the
//!   request.  The companion [`crate::sim::RecentWindow`] bounds the
//!   re-plan's completion log to the newest `REPLAN_WINDOW` samples —
//!   the only ones `on_replan` ever read.
//!
//! # Heterogeneous fleets
//!
//! The fleet need not be uniform: [`ClusterConfig::fleet`] takes a
//! [`FleetSpec`] (one `{gpu, engine, speed, tp}` [`InstanceSpec`] per
//! instance; CLI grammar `--fleet h20:6,h100:2[,speed=F][,tp=N]`), and
//! [`ClusterConfig::topology`] makes the node layout — and therefore
//! the [`MigrationCost`] link bandwidth — configurable instead of the
//! old hardcoded `Topology::sequential(e, 8, NvLink)`.  Construction
//! builds one attention model / scaled backend / derived KV capacity
//! *per instance*; the §4.2 DP partitions over per-instance capacity
//! weights ([`crate::coordinator::plan::Planner::plan_dp_weighted`]);
//! and every load comparison (router least-loaded, §4.4 bids, overload
//! outliers) is *capacity-normalized* so a fast H100 correctly outbids
//! a saturating H20.  Capacities are normalized to the fleet maximum,
//! so a homogeneous fleet gets exactly 1.0 everywhere and reduces
//! bit-identically to the legacy single-GPU path (enforced by
//! `tests/experiment_api.rs` and `tests/golden_seed.rs`).
//!
//! # Tensor-parallel stages
//!
//! Each [`InstanceSpec`] additionally carries a **TP degree** (CLI
//! `--fleet h20:4,tp=2,h20:2,tp=4`): a `tp=N` instance serves the
//! configured model re-sliced at degree `N`
//! ([`crate::fleet::InstanceSpec::model_for`]).  Three things change
//! per instance:
//!
//! * its cost backend prices the slice — per-GPU weight and KV
//!   traffic shrink `N`x, but every forward pass pays two per-layer
//!   all-reduces over the topology's intra-node link
//!   ([`crate::kernelmodel::AttentionModel::tp_comm_latency`]), so
//!   the speedup is sublinear;
//! * its derived KV pool grows ~`N`x (the slice's per-token bytes
//!   shrink while the per-GPU budget is fixed) — the only way a
//!   70B-class model holds 128K-token KV on single-GPU memory;
//! * its capacity weight reflects both, so routing/bidding shift the
//!   right share of load onto the sharded instances.
//!
//! Planning goes through the TP-aware DP
//! ([`crate::coordinator::plan::Planner::plan_dp_instances`]): stage
//! cost scales by a KV feasibility pressure (`max(1, hi / min member
//! KV)`) and adds the members' collective premium on the range's
//! generated tokens, so long-sequence stages gravitate to TP-sharded
//! instances that can actually hold their KV.  List sharded instances
//! *last* in the fleet: stages are contiguous in instance order and
//! the long ranges sit at the end.  Inter-instance KV migration is
//! priced from the **sender's** resolved TP slice — a TP4 sender
//! streams 4x fewer bytes per token than the base model
//! ([`MigrationManager::set_instance_footprints`]); only the *offline
//! planner's* [`MigrationCost`] keeps the base-model footprint, a
//! deliberately conservative bound.  Fleets with `tp=1` everywhere
//! never touch these
//! paths — construction and re-planning gate on
//! [`crate::fleet::FleetSpec::has_tensor_parallel`], and
//! `tests/tp_fleet.rs` pins fingerprint-equality against the legacy
//! no-TP path for every registry scheduler.
//!
//! Caveat: a configuration whose per-instance KV pool is smaller than
//! a sequence's *final* length cannot ever admit that sequence
//! (reachable through small TP slices, e.g. 70B at TP2 on an H100
//! pools only ~28K tokens).  The router rejects such requests at
//! admission — counted in [`RunStats::rejected`] with per-request
//! diagnostics in [`RunStats::rejections`] — instead of letting the
//! FCFS queue head wedge the instance forever.  The KV pressure term
//! keeps the *planner* from creating such stages in the first place;
//! pick TP degrees so the long-stage instances hold `max_len` if every
//! request must complete.
//!
//! # Prediction & misprediction recovery
//!
//! Real systems never know a request's output length up front, so the
//! policy carries a **length predictor** axis
//! ([`PolicySpec::resolve`] grammar `predictor=oracle|noisy:CV|`
//! `bucket:ACC|ltr:PACC` — see [`crate::predict`]).  The split of who
//! sees what is the whole design:
//!
//! * **Predicted lengths** drive every *scheduling* consumer: §3.2
//!   stage routing and the admission-reject check (`router.rs`),
//!   shortest-first/least-wait dispatch, the §4.2 planner histogram at
//!   construction, and the live re-plan's length statistics
//!   (`driver.rs`).  The `ltr` family is rank-only: routing consumes
//!   quantiles of its rank score and admission falls back to the
//!   prompt length — absolute lengths never leak in.
//! * **True lengths** keep driving *execution*: decode progress, KV
//!   growth, completion, and the engine's admission of resident
//!   sequences are untouched, so a bad prediction becomes an
//!   observable event rather than a silent re-simulation.
//!
//! Recovery rides machinery that already exists.  A decode that
//! outgrows the stage its predicted length routed it to is handed to
//! the next stage through the ordinary §4.4 bid-ask migration — the
//! outgrown scan in [`Cluster`]'s post-step hook counts it once per
//! request in [`RunStats::predict_reroutes`].  An under-prediction
//! whose true final can never fit the routed instance's KV pool
//! escalates through the admission-reject path
//! ([`RunStats::predict_escalations`]) instead of wedging the FCFS
//! queue head.  Completions whose true final exceeded the prediction
//! count [`RunStats::mispredictions`].  The `oracle` predictor (the
//! default) reproduces the legacy consumers expression-for-expression
//! — `tests/predict.rs` pins fingerprint identity for every registry
//! scheduler.
//!
//! # Elastic fleets
//!
//! The fleet is not static: a [`ChurnSpec`] (CLI `--churn`, parsed by
//! [`crate::cluster::elastic`]) schedules deterministic membership
//! events, and every slot the schedule can ever need — one per
//! `join:` event plus the autoscaler's `max` headroom — is
//! pre-allocated `Absent` at construction, so churn never reallocates
//! the instance table mid-run.  The membership lifecycle is
//! `Absent -> Live -> (Draining ->) Dead`
//! ([`elastic::Membership`] on [`state::InstanceState`]):
//!
//! * **Scale-out** (`join:T[@GPU]`, `InstanceJoin` event): the slot
//!   boots at `T` and goes `Live` only after its weight load — the
//!   resolved model slice's weight bytes streamed over the topology's
//!   inter-node link — so a join never serves before it could have
//!   loaded the model.
//! * **Graceful scale-in** (`drain:T@I[:DEADLINE]`, `DrainStart`
//!   event): the instance goes `Draining` — it stops *admitting*
//!   (router dispatch and migration destinations skip it) but keeps
//!   *serving* its residue.  A periodic drain pump re-queues its
//!   waiting sequences onto live instances directly and offers its
//!   running sequences through the ordinary §4.4 bid-ask path; the
//!   instance leaves (`Dead`) once empty, or is forcibly killed at
//!   the deadline and recovers like a spot preemption.
//! * **Spot preemption** (`spot:T@I`, `InstanceGone` event): the
//!   instance dies mid-decode.  Its KV is gone; every resident
//!   sequence re-enters admission as a *re-prefill* (prompt plus the
//!   generated prefix, logical progress preserved — the same
//!   recompute semantics as engine preemption), scheduled through
//!   `Readmit` events with exponential backoff and at most
//!   [`elastic::MAX_SPOT_RETRIES`] attempts before escalating to a
//!   counted rejection — graceful degradation, never a wedge.
//!   In-flight migrations touching the dead endpoint are aborted; a
//!   dead *destination* leaves the sequence serving on its source, a
//!   dead *source* recovers the sequence through the re-prefill path.
//! * **SLO-feedback autoscaler** (`auto:PERIOD:MIN..MAX`,
//!   `AutoscaleTick` event): a periodic controller reads windowed SLO
//!   attainment and total queue depth, scaling out (lowest absent
//!   slot joins, boot latency priced) under SLO misses / queue
//!   pressure and draining the highest live slot when comfortably
//!   over-provisioned, always within `MIN..MAX`.
//!
//! Every layer that assumed a fixed fleet observes membership: the
//! router dispatches over *admitting* instances only, gossip skips
//! non-serving instances and [`LoadTracker`] forgets departed peers
//! (plus the age-expiry below), the §4.2 re-plan runs over live
//! membership on every join/leave, and the §4.4/§5 protocol handlers
//! drop negotiations whose endpoint left.  The hard invariant is
//! that [`ChurnSpec::none`] (the default) takes *zero* churn code
//! paths: construction pre-allocates nothing, no churn event is ever
//! scheduled, and every guard degenerates to the all-`Live` case —
//! `tests/elastic.rs` pins `Report::fingerprint()` identity against
//! the churn-free path for every registry scheduler and predictor
//! family.
//!
//! Related fix that benefits static fleets too: gossip overload
//! comparisons ignore [`crate::coordinator::loadtracker::LoadReport`]s
//! older than three gossip periods, so an instance that goes silent
//! (dead, draining, or wedged) cannot keep winning outlier
//! comparisons with a stale load figure.
//!
//! # Prefill/decode disaggregation
//!
//! [`Layout::Disaggregated`] (`--layout pd[:P/D[:BOUNDARY[:WINDOW_US]]]`)
//! splits the fleet into a prefill pool and a decode pool: prefill
//! instances run prompt phases only and park each completed prefill
//! with its KV resident
//! ([`crate::engine::Engine::set_prefill_only`]); a post-dispatch pump
//! hands the frozen KV off to the least-loaded feasible decode
//! instance as a zero-decode-rate migration priced by the *existing*
//! [`MigrationManager`] cost model over the configured [`Topology`]
//! link.  The prefill side applies the LAPS levers — dual short/long
//! prefill queues (short drains first), a waiting window batching
//! similar-length prompts, and periodic dynamic P/D re-allocation on
//! sustained backlog imbalance (disabled by `balance=off`).  See
//! [`pd`] for the mechanics and [`pd::PdSpec`] for the grammar.  PD
//! does not compose with `--churn` or a forced pipeline (construction
//! rejects the combination).  Invariant: every PD hook is gated on
//! `Cluster::pd.is_some()`, so colocated layouts stay
//! fingerprint-bit-identical for every registry scheduler and
//! predictor — `tests/pd_layout.rs` pins it.
//!
//! # Determinism invariants
//!
//! Every regression this repo leans on — golden-seed checksums,
//! macro-vs-`--micro-step` bit-identity, TP fingerprint equivalence —
//! requires a run to be a pure function of `(config, trace, seed)`.
//! The `detlint` binary (`cargo run --release --bin detlint`, gated in
//! CI) statically enforces that contract over simulator-scoped code
//! (`cluster/`, `coordinator/`, `sim/`, `engine/`, `fleet.rs`,
//! `kernelmodel.rs`, `workload.rs`, `metrics.rs`):
//!
//! * **D1** — no `HashMap`/`HashSet` *iteration*: entries come out in
//!   hash order, which is not stable across std versions or hasher
//!   seeds.  Keyed lookup is fine; anything scheduler-visible that
//!   iterates must use `BTreeMap`/sorted order (`retry_after`,
//!   `offers`, `promises` here, and the `MigrationManager` maps, are
//!   `BTreeMap` for exactly this reason).
//! * **D2** — no `.partial_cmp(..)` calls on floats: a NaN collapses
//!   to `Equal` (or panics through `unwrap`) and the resulting order
//!   depends on comparison sequence; use `f64::total_cmp`.
//! * **D3** — no `Instant::now` / `SystemTime` / `thread_rng` /
//!   `from_entropy` outside `main.rs`, `bin/`, and the pjrt-gated
//!   `server/`: simulated time flows from the event queue and
//!   randomness from the seeded [`crate::sim::Rng`].
//! * **D4** — every scheduler name in the [`PolicySpec`] registry,
//!   every predictor family in the [`crate::predict`] registry, and
//!   every churn event kind in the [`ChurnSpec`] registry
//!   ([`ChurnSpec::names`]) must appear in the coverage lists of
//!   `tests/golden_seed.rs` *and* `tests/macro_equivalence.rs`, so a
//!   new policy, predictor, or churn axis cannot ship with its seeded
//!   behavior unpinned.
//!
//! A finding is suppressed only by a justified annotation on the
//! offending line — `// detlint: allow(<rule>) -- <reason>` — and
//! `detlint --list-allows` prints the audit trail.  See
//! [`crate::lint`] for the rule implementations and their (lexical)
//! approximations.

pub mod elastic;
pub mod pd;
pub mod policy;

mod driver;
mod router;
mod state;

pub use elastic::{AutoscaleSpec, ChurnEvent, ChurnSpec, Membership};
pub use pd::PdSpec;
pub use policy::{
    parse_layout, BalancePolicy, DispatchPolicy, Layout, PolicyError, PolicySpec, RefinePolicy,
    SchedulerKind,
};

use crate::baselines;
use crate::coordinator::balance::{Ask, Bid, BidAskScheduler, PendingPull, PullAction};
use crate::coordinator::migrate::MigrationManager;
use crate::coordinator::plan::{MigrationCost, Pipeline, PlanInstance, Planner};
use crate::coordinator::refine::{RangeRefiner, RefineConfig};
use crate::coordinator::LoadTracker;
use crate::engine::{CostModelBackend, Engine, EngineConfig, ExecBackend, Phase, Sequence};
use crate::fleet::{FleetSpec, InstanceSpec};
use crate::gpu::{GpuProfile, Topology};
use crate::kernelmodel::AttentionModel;
use crate::metrics::{InstanceCounters, Report, RequestRecord};
use crate::models::ModelProfile;
use crate::predict::LengthPredictor;
use crate::qoe::{self, QoeModel};
use crate::sim::{EventQueue, RecentWindow, RequestArena};
use crate::workload::{LengthHistogram, Request};
use crate::{InstanceId, RequestId, Time, Tokens};

/// How many of the most recent completion samples the periodic re-plan
/// consumes (`driver.rs` `on_replan`) — and therefore the capacity of
/// the [`RecentWindow`] that retains them.
pub(crate) const REPLAN_WINDOW: usize = 4000;

use driver::Event;
use router::Router;
use state::InstanceState;

/// Cluster-level configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// GPU profile of a *homogeneous* fleet (ignored for construction
    /// when [`ClusterConfig::fleet`] is set, but kept as the display /
    /// compat default).
    pub gpu: GpuProfile,
    pub model: ModelProfile,
    pub n_instances: usize,
    /// Per-instance hardware when the fleet is heterogeneous.  `None`
    /// replicates `(gpu, engine, speed 1.0)` across `n_instances` —
    /// the legacy homogeneous configuration, bit-identical to the
    /// pre-fleet behavior.  When `Some`, its length must equal
    /// `n_instances` and each instance gets its own attention cost
    /// model, engine speed, and derived KV capacity.
    pub fleet: Option<FleetSpec>,
    /// Physical placement of instances onto nodes.  `None` keeps the
    /// historical default (`Topology::sequential(e, 8, NvLink)` — the
    /// paper's H20 testbed shape); set it to model PCIe nodes, other
    /// node widths, etc.  The inter-stage [`MigrationCost`] takes its
    /// link bandwidth from this topology.
    pub topology: Option<Topology>,
    /// The scheduling policy, as orthogonal axes.  Construct from a
    /// [`PolicySpec`] directly, a registry name via
    /// [`PolicySpec::resolve`], or a legacy [`SchedulerKind`] (which
    /// converts via `Into`).
    pub policy: PolicySpec,
    /// Engine knobs; a `None` KV capacity is derived from the GPU
    /// memory budget.  Like `gpu`, this describes the *homogeneous*
    /// fleet and is ignored for construction when
    /// [`ClusterConfig::fleet`] is set — each [`InstanceSpec`] then
    /// carries its own `EngineConfig` (the experiment builder stamps
    /// builder-level engine knobs into every spec of a parsed fleet).
    pub engine: EngineConfig,
    /// Relative engine speed (1.0 = vLLM-class; Llumnix's newer engine
    /// runs faster — §6.2 Fig. 8).  Seeded from the policy spec;
    /// override after construction to model a different runtime.
    pub engine_speed: f64,
    pub gossip_interval: Time,
    pub refine_interval: Time,
    /// Periodic full re-planning interval (§4.2 "periodically
    /// thereafter"); 0 disables.
    pub replan_interval: Time,
    /// §4.4: trigger intra-stage rebalancing when an instance's load is
    /// this fraction above the stage average.
    pub overload_threshold: f64,
    pub seed: u64,
    /// How many head-of-trace requests feed the offline stage planner.
    pub plan_sample: usize,
    pub max_len: Tokens,
    /// Bypass planning with an explicit layout (ablation experiments,
    /// e.g. the paper's forced 4-stage x 4-instance Fig. 16 pipeline).
    /// Disables periodic re-planning.
    pub forced_pipeline: Option<Pipeline>,
    /// Debug path: drive every engine iteration through its own
    /// `StepDone` queue event (the pre-macro-step hot loop) instead of
    /// the inline macro-step loop.  Reports are bit-identical either
    /// way — `tests/macro_equivalence.rs` enforces it — so this exists
    /// purely to *prove* that equivalence and to bisect any future
    /// divergence.  CLI: `sim --micro-step`.
    pub micro_step: bool,
    /// Deterministic fault-injection / elasticity schedule (CLI
    /// `--churn`; see [`crate::cluster::elastic`]).  The default
    /// [`ChurnSpec::none`] takes zero churn code paths and is
    /// fingerprint-bit-identical to the pre-elastic behavior.
    pub churn: ChurnSpec,
}

impl ClusterConfig {
    pub fn new(
        gpu: GpuProfile,
        model: ModelProfile,
        n_instances: usize,
        policy: impl Into<PolicySpec>,
    ) -> Self {
        let policy = policy.into();
        let engine_speed = policy.engine_speed;
        Self {
            gpu,
            model,
            n_instances,
            fleet: None,
            topology: None,
            policy,
            engine: EngineConfig::default(),
            engine_speed,
            gossip_interval: 0.05,
            refine_interval: 5.0,
            replan_interval: 10.0,
            overload_threshold: 0.25,
            seed: 42,
            plan_sample: 2000,
            max_len: 131_072,
            forced_pipeline: None,
            micro_step: false,
            churn: ChurnSpec::none(),
        }
    }

    /// The effective per-instance fleet: the explicit one, or
    /// `n_instances` copies of `(gpu, engine, speed 1.0)`.
    pub fn resolved_fleet(&self) -> FleetSpec {
        match &self.fleet {
            Some(f) => {
                assert_eq!(
                    f.len(),
                    self.n_instances,
                    "fleet size must match n_instances"
                );
                f.clone()
            }
            None => FleetSpec::homogeneous(self.gpu, self.engine, 1.0, self.n_instances),
        }
    }

    /// Engine knobs for one instance: explicit KV capacity is honoured,
    /// `None` derives it from *that instance's* GPU memory budget under
    /// *that instance's* resolved model slice — a TP4 instance's
    /// per-GPU weights and KV bytes shrink 4x, so its pool derives 4x
    /// the per-instance token headroom from the same device memory.
    fn engine_config_for(&self, spec: &InstanceSpec) -> EngineConfig {
        let mut e = spec.engine;
        if e.kv_capacity_tokens.is_none() {
            let model = spec.model_for(&self.model);
            let budget = model.kv_budget_bytes(spec.gpu.mem_bytes, 0.9);
            e.kv_capacity_tokens = Some(model.kv_capacity_tokens(budget).max(1024));
        }
        e
    }
}

/// Speed-scaled cost backend (models newer/slower engine runtimes).
#[derive(Debug, Clone, Copy)]
pub struct ScaledBackend {
    inner: CostModelBackend,
    speed: f64,
}

impl ExecBackend for ScaledBackend {
    fn prefill_cost(&self, chunks: &[(Tokens, Tokens)]) -> Time {
        self.inner.prefill_cost(chunks) / self.speed
    }

    fn decode_cost(&self, lens: &[Tokens]) -> Time {
        self.inner.decode_cost(lens) / self.speed
    }
}

/// One request turned away at router admission: its final length
/// exceeds the routed instance's *total* KV pool, so admitting it
/// would wedge the instance's FCFS queue head forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RejectedRequest {
    pub request: RequestId,
    pub instance: InstanceId,
    /// `input_len + output_len` — the KV the sequence would need.
    pub final_len: Tokens,
    /// The routed instance's total KV pool.
    pub pool_tokens: Tokens,
}

/// Detail rows kept in [`RunStats::rejections`]; the count in
/// [`RunStats::rejected`] is always exact.
pub const MAX_REJECTION_DETAILS: usize = 32;

/// Run statistics beyond the per-request report.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Requests rejected at admission (never submitted, no record).
    pub rejected: u64,
    /// Per-rejection diagnostics, capped at [`MAX_REJECTION_DETAILS`].
    pub rejections: Vec<RejectedRequest>,
    pub migrations: u64,
    pub migration_tokens: Tokens,
    pub migrations_skipped: u64,
    pub preemptions: u64,
    pub refinements: u64,
    /// Completions whose true final length exceeded the predicted one
    /// (always 0 under the `oracle` predictor).
    pub mispredictions: u64,
    /// Sequences re-routed after outgrowing their *predicted* stage
    /// boundary (counted once per request; 0 under `oracle`).
    pub predict_reroutes: u64,
    /// Under-predictions rejected at admission: the predicted length
    /// fit the routed instance's KV pool but the true final never
    /// could (0 under `oracle`, whose admission check *is* the truth).
    pub predict_escalations: u64,
    /// Scheduled spot preemptions that actually killed a serving
    /// instance (drains that hit their deadline take the same
    /// kill/evacuate path but count [`RunStats::drains_forced`]).
    pub spot_kills: u64,
    /// Requests evicted by an instance death (each re-enters admission
    /// as a re-prefill).
    pub preempted_requests: u64,
    /// Preempted requests successfully re-admitted on a live instance.
    pub recovered: u64,
    /// Generated tokens thrown away by instance deaths (the re-prefill
    /// recomputes them).
    pub lost_tokens: Tokens,
    /// Graceful scale-ins started / finished empty / forcibly killed
    /// at the drain deadline.
    pub drains_started: u64,
    pub drains_completed: u64,
    pub drains_forced: u64,
    /// Instances that finished booting and went live.
    pub joins: u64,
    /// Autoscaler controller invocations / scale-out joins it
    /// initiated / scale-in drains it initiated.
    pub autoscale_ticks: u64,
    pub scale_outs: u64,
    pub scale_ins: u64,
    /// Arrivals the new-request router re-routed to a non-preferred
    /// instance because the preferred target's KV pool could never
    /// hold them (reject-or-reroute admission; 0 whenever every pool
    /// fits every request).
    pub admit_reroutes: u64,
    /// Completed-prefill KV handoffs (prefill pool -> decode pool)
    /// and the tokens they moved.  0 under colocated layouts.
    pub pd_handoffs: u64,
    pub pd_handoff_tokens: Tokens,
    /// Requests that completed *on* a prefill instance (single-token
    /// outputs reaped at prefill — no handoff needed).
    pub pd_local_completions: u64,
    /// Dynamic P/D re-allocations: instances moved between the pools
    /// on sustained backlog imbalance.
    pub pd_reallocations: u64,
    /// Total engine iterations simulated across all instances — the
    /// numerator of the perf harness's iterations-per-wall-second
    /// cluster throughput metric (`BENCH_hotpath.json`).
    pub engine_iterations: u64,
    /// Peak simultaneous live requests in the [`RequestArena`] — the
    /// measurable O(in-flight) memory bound of the streaming path.
    pub arena_high_water: u64,
    pub final_boundaries: Vec<Tokens>,
    /// Per-instance output tokens (Fig. 16).
    pub counters: InstanceCounters,
    /// Per-instance GPU tags, in instance-id order (mixed fleets).
    pub instance_gpus: Vec<&'static str>,
    /// Per-instance tensor-parallel degrees, in instance-id order
    /// (all 1 on TP-free fleets).
    pub instance_tp: Vec<u32>,
    /// Per-instance relative capacity (normalized to the fleet
    /// maximum; all 1.0 on homogeneous fleets).
    pub instance_capacity: Vec<f64>,
    /// Per-instance token load averaged over gossip ticks — the
    /// steady-state load share of the per-instance report.  Empty when
    /// the policy never gossips (no sampling clock).
    pub mean_token_load: Vec<f64>,
    /// stage -> member instances.
    pub stages: Vec<Vec<InstanceId>>,
    /// Batch length snapshots: (sim progress fraction, lens) — Fig. 1.
    pub batch_snapshots: Vec<(f64, Vec<Tokens>)>,
}

/// The cluster simulator.
pub struct Cluster {
    pub cfg: ClusterConfig,
    /// Per-instance bookkeeping (engine + tracker + bid-ask state).
    instances: Vec<InstanceState>,
    /// Pipeline stage structure (single stage for flat baselines).
    pub pipeline: Pipeline,
    stage_of: Vec<usize>,
    stages: Vec<Vec<InstanceId>>,
    /// Cached `[lo, hi)` range per stage, derived from the refiners'
    /// boundaries.  Rebuilt only when a boundary moves (refine/replan)
    /// so per-event range lookups are O(1) allocation-free.
    ranges: Vec<(Tokens, Tokens)>,
    refiners: Vec<RangeRefiner>,
    topology: Topology,
    migration: MigrationManager,
    /// Requests currently mid-transfer.
    in_flight: std::collections::HashSet<RequestId>,
    events: EventQueue<Event>,
    records: Vec<RequestRecord>,
    pub stats: RunStats,
    qoe: QoeModel,
    /// Dispatch policy + shared round-robin counter.
    router: Router,
    /// Length predictor every scheduling consumer reads request
    /// lengths through (`oracle` = ground truth, bit-identical legacy).
    predictor: LengthPredictor,
    /// Requests already counted in `RunStats::predict_reroutes` — the
    /// once-per-request gate for misprediction re-routing.
    rerouted: std::collections::BTreeSet<RequestId>,
    n_requests_total: usize,
    snapshot_marks: Vec<f64>,
    /// Planner kept for periodic re-planning.
    planner: Planner,
    /// Failed-handover retry gate: request -> earliest next attempt.
    retry_after: std::collections::BTreeMap<RequestId, Time>,
    /// Open offers: request -> (sender, seq_len at offer, sender's
    /// capacity-normalized load).
    offers: std::collections::BTreeMap<RequestId, (InstanceId, Tokens, f64)>,
    /// Starvation promises per sender: (pull, receiver) to send
    /// immediately after the current transmission completes.
    promises: std::collections::BTreeMap<InstanceId, Vec<(PendingPull, InstanceId)>>,
    /// (input_len, final_len) of the [`REPLAN_WINDOW`] most recently
    /// completed requests — the workload statistics the periodic
    /// re-plan consumes (it never read past the newest window, so the
    /// ring is bit-identical to the old unbounded log).
    observed: RecentWindow<(Tokens, Tokens)>,
    /// SoA columns for live request metadata + cached predictions:
    /// interned at admission, released at completion/rejection.
    arena: RequestArena,
    /// Per-instance relative capacities (normalized; all 1.0 on
    /// homogeneous fleets).  The periodic re-plan partitions over
    /// these.
    caps: Vec<f64>,
    /// TP-aware per-instance planning inputs — `Some` only when the
    /// fleet actually shards (the re-plan then runs the TP-aware DP;
    /// TP-free fleets keep the exact legacy `plan_dp_weighted` path).
    plan_insts: Option<Vec<PlanInstance>>,
    /// Accumulators for `RunStats::mean_token_load` (sampled at gossip
    /// ticks — read-only instrumentation, never consulted by policy).
    load_sample_sum: Vec<f64>,
    load_samples: u64,
    pub replans: u64,
    /// Scheduled churn events with join boot latency already resolved:
    /// `(fire time, event)` pairs the driver enqueues at run start.
    /// Empty under [`ChurnSpec::none`].
    churn_schedule: Vec<(Time, Event)>,
    /// Drain deadline *duration* per scheduled drain target (the
    /// absolute deadline is stamped when `DrainStart` fires).
    drain_spec: std::collections::BTreeMap<InstanceId, Time>,
    /// Per-slot weight-load boot latency: the slot's resolved model
    /// slice streamed over the inter-node link.  Charged before an
    /// `Absent` slot goes live (scheduled joins and autoscaler
    /// scale-outs).
    boot_latency: Vec<Time>,
    /// Re-admission attempts per spot-preempted request (removed on
    /// completion or final rejection).
    spot_attempts: std::collections::BTreeMap<RequestId, u32>,
    /// Slots currently booting — counted by the autoscaler so it does
    /// not scale out again while a join is in flight.
    pending_joins: usize,
    /// The booting slots themselves (scheduled joins at construction,
    /// autoscaler scale-outs later), so a slot is never double-booked
    /// while its `InstanceJoin` is in flight.
    booting: std::collections::BTreeSet<InstanceId>,
    /// Index into `records` where the autoscaler's current SLO
    /// observation window starts.
    autoscale_watermark: usize,
    /// Cached ascending list of admitting (`Live`) instance ids — the
    /// set the router dispatches over.  Rebuilt on every membership
    /// transition; exactly `0..n_instances` for the whole of a
    /// churn-free run, so legacy dispatch orderings are preserved bit
    /// for bit.
    admitting: Vec<InstanceId>,
    /// Prefill/decode disaggregation state — `Some` iff the layout is
    /// [`Layout::Disaggregated`].  Every PD code path is gated on it,
    /// so colocated layouts stay bit-identical.
    pd: Option<pd::PdState>,
}

impl Cluster {
    /// Build a cluster for `cfg`, planning the pipeline from
    /// `plan_trace` (pass the workload itself or a historical slice).
    pub fn new(cfg: ClusterConfig, plan_trace: &[Request]) -> Self {
        let e = cfg.n_instances;
        let mut fleet = cfg.resolved_fleet();
        // Elastic fleets: pre-allocate every slot the churn schedule
        // can ever need — one per `join:` event plus the autoscaler's
        // headroom above the initial size — so membership changes
        // never reallocate the instance table mid-run.  Zero extras
        // under `ChurnSpec::none()`: the table is exactly the legacy
        // fixed fleet, bit for bit.
        let churn_extras = if cfg.churn.is_none() {
            0
        } else {
            cfg.churn.scheduled_joins()
                + cfg.churn.autoscale.map(|a| a.max.saturating_sub(e)).unwrap_or(0)
        };
        if churn_extras > 0 {
            let reference = *fleet.reference();
            for ev in &cfg.churn.events {
                if let ChurnEvent::Join { gpu, .. } = ev {
                    let mut spec = reference;
                    if let Some(name) = gpu {
                        spec.gpu =
                            GpuProfile::by_name(name).expect("join gpu validated at parse");
                    }
                    fleet.instances.push(spec);
                }
            }
            for _ in 0..churn_extras.saturating_sub(cfg.churn.scheduled_joins()) {
                fleet.instances.push(reference);
            }
        }
        let total = e + churn_extras;
        let mut topology = match cfg.topology.clone() {
            Some(t) => {
                assert_eq!(t.node_of.len(), e, "topology must cover every instance");
                t
            }
            None => Topology::sequential(total, 8, crate::gpu::LinkKind::NvLink),
        };
        // Churn slots continue the sequential node fill of an explicit
        // topology that only covered the initial fleet.
        while topology.node_of.len() < total {
            let i = topology.node_of.len();
            topology.node_of.push(i / topology.gpus_per_node);
        }
        // Shared calibration (QoE profile) runs on the fleet's
        // reference instance — the majority GPU, serving its *resolved*
        // model slice (TP collectives priced over the intra-node link);
        // the per-instance cost of *executing* always uses each
        // instance's own GPU + slice below.
        let reference = *fleet.reference();
        let am = AttentionModel::new(reference.gpu, reference.model_for(&cfg.model))
            .with_tp_link(topology.intra_node);
        let (qoe_model, _) =
            qoe::profile_and_fit(&am, 64, cfg.max_len, reference.engine.max_batch.min(512));
        // Relative capacities (1.0 everywhere for homogeneous fleets):
        // the planner partitions over them and every load comparison
        // normalizes by them.  TP-sharded instances price their slice
        // (faster weight/KV streaming minus the all-reduce premium,
        // collectives over the same intra-node link the backends use).
        let caps = fleet.normalized_capacities_with_link(&cfg.model, topology.intra_node);
        // TP-aware planning inputs, built only when some instance is
        // actually sharded: TP-free fleets take the exact legacy
        // `plan_dp_weighted` path (bit-identity gate, same pattern as
        // the uniform-capacity fast path inside the DP).  Planner
        // capacities are *collective-free* — the DP prices collectives
        // through `comm_s_per_token`, and a comm-inclusive capacity
        // would double-count the premium.
        let plan_insts: Option<Vec<PlanInstance>> = fleet.has_tensor_parallel().then(|| {
            let plan_caps = fleet.plan_capacities(&cfg.model);
            fleet
                .instances
                .iter()
                .enumerate()
                .map(|(i, spec)| PlanInstance {
                    cap: plan_caps[i],
                    kv_tokens: cfg
                        .engine_config_for(spec)
                        .kv_capacity_tokens
                        .expect("engine_config_for always resolves a KV capacity")
                        as f64,
                    comm_s_per_token: spec.tp_comm_s_per_token(&cfg.model, topology.intra_node),
                })
                .collect()
        });

        // Build the stage layout per the scheduler policy.  The
        // planner's histogram is fed *predicted* final lengths — under
        // `oracle` this is exactly `LengthHistogram::from_requests`
        // (bit-identical legacy planning).
        let predictor = LengthPredictor::new(cfg.policy.predictor, cfg.seed, cfg.max_len);
        let sample = &plan_trace[..plan_trace.len().min(cfg.plan_sample)];
        let hist = predictor.histogram(sample, cfg.max_len);
        let mig_cost = MigrationCost::new(
            cfg.model.kv_bytes_per_token() as f64,
            topology.intra_node.bytes_per_s(),
        );
        let planner = Planner::new(qoe_model, mig_cost);
        let pipeline = match (&cfg.forced_pipeline, cfg.policy.layout) {
            (Some(p), _) => {
                assert_eq!(p.total_instances(), e, "forced pipeline must use all instances");
                // Routing does a binary search over stage boundaries
                // (`Pipeline::stage_for`, router `stage_for_len`), so a
                // hand-built ablation layout must be length-ordered —
                // reject it here, in release builds too, rather than
                // silently misrouting.
                assert!(
                    p.stages.windows(2).all(|w| w[0].hi <= w[1].hi),
                    "forced pipeline stages must have ascending upper bounds: {:?}",
                    p.stages
                );
                p.clone()
            }
            (None, Layout::Planned) => match &plan_insts {
                // Plan over the *initial* fleet only — churn slots
                // beyond `e` are Absent until their join fires (the
                // membership re-plan folds them in then).  Identical
                // slices when there are no churn extras.
                Some(insts) => planner.plan_dp_instances(&hist, &insts[..e]),
                None => planner.plan_dp_weighted(&hist, &caps[..e]),
            },
            (None, Layout::Chain) => baselines::chain_layout(&planner, &hist, e),
            // Disaggregated layouts carry no length-ranged stages: the
            // PD pools are resolved below and the decode pool becomes
            // the single routing stage.
            (None, Layout::Flat) | (None, Layout::Disaggregated(_)) => {
                Pipeline::no_pipeline(e, cfg.max_len)
            }
        };

        // Assign instances to stages contiguously (co-locates adjacent
        // stages on nodes — the §5 placement optimization; for a mixed
        // fleet the weighted DP already planned against this exact
        // instance order).
        let mut stage_of = Vec::with_capacity(total);
        let mut stages: Vec<Vec<InstanceId>> = Vec::new();
        for spec in pipeline.stages.iter() {
            let mut members = Vec::new();
            for _ in 0..spec.n_instances {
                members.push(stage_of.len());
                stage_of.push(stages.len());
            }
            stages.push(members);
        }
        // Absent churn slots carry a placeholder stage until their
        // join's membership re-plan assigns a real one.
        stage_of.resize(total, 0);

        // One engine + cost backend + KV pool *per instance*: each is
        // priced by its own GPU's attention model over its own
        // resolved model slice (TP collectives ride the intra-node
        // link) and runs at its own engine speed (the config-level
        // `engine_speed` composes as a fleet-wide multiplier).
        let mut instances: Vec<InstanceState> = fleet
            .instances
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let engine_cfg = cfg.engine_config_for(spec);
                let backend = ScaledBackend {
                    inner: CostModelBackend::new(
                        AttentionModel::new(spec.gpu, spec.model_for(&cfg.model))
                            .with_tp_link(topology.intra_node),
                    ),
                    speed: spec.speed * cfg.engine_speed,
                };
                InstanceState::new(
                    i,
                    Engine::new(engine_cfg, backend),
                    LoadTracker::new(i, 10.0),
                    BidAskScheduler::new(i, 4),
                    spec.gpu.name,
                    caps[i],
                )
            })
            .collect();
        for ins in instances.iter_mut().skip(e) {
            ins.membership = Membership::Absent;
        }

        // Prefill/decode disaggregation: resolve the pools, flip the
        // prefill engines into prompt-only mode, and expose the decode
        // pool as the single routing stage (decode residency must
        // never land on a prefill instance).  Colocated layouts build
        // no `PdState` and skip every line here.
        let pd_state = match cfg.policy.layout {
            Layout::Disaggregated(spec) => {
                assert!(
                    cfg.forced_pipeline.is_none(),
                    "pd layout does not compose with a forced pipeline"
                );
                assert!(cfg.churn.is_none(), "pd layout does not compose with --churn");
                assert!(e >= 2, "pd layout needs at least 2 instances");
                let (p, d) = spec.pools(e);
                assert_eq!(p + d, e, "pd pools {p}/{d} must sum to the fleet size ({e})");
                let prefill_pool: Vec<InstanceId> = (0..p).collect();
                let decode_pool: Vec<InstanceId> = (p..e).collect();
                for &i in &prefill_pool {
                    instances[i].engine.set_prefill_only(true);
                }
                stages = vec![decode_pool.clone()];
                Some(pd::PdState::new(spec, prefill_pool, decode_pool))
            }
            _ => None,
        };

        // Resolve the churn schedule once: join boot latency is the
        // slot's resolved model slice streamed over the inter-node
        // link, so a join never serves before it could have loaded
        // weights.
        let boot_latency: Vec<Time> = fleet
            .instances
            .iter()
            .map(|spec| {
                spec.model_for(&cfg.model).weight_bytes() as f64
                    / topology.inter_node.bytes_per_s()
            })
            .collect();
        let mut churn_schedule: Vec<(Time, Event)> = Vec::new();
        let mut drain_spec = std::collections::BTreeMap::new();
        let mut next_join_slot = e;
        for ev in &cfg.churn.events {
            match ev {
                ChurnEvent::Spot { at, instance } => {
                    assert!(*instance < total, "spot target {instance} out of range");
                    churn_schedule.push((*at, Event::InstanceGone(*instance)));
                }
                ChurnEvent::Drain { at, instance, deadline } => {
                    assert!(*instance < total, "drain target {instance} out of range");
                    drain_spec.insert(*instance, *deadline);
                    churn_schedule.push((*at, Event::DrainStart(*instance)));
                }
                ChurnEvent::Join { at, .. } => {
                    churn_schedule
                        .push((*at + boot_latency[next_join_slot], Event::InstanceJoin(next_join_slot)));
                    next_join_slot += 1;
                }
            }
        }
        let pending_joins = cfg.churn.scheduled_joins();

        // One refiner per stage boundary, initialised from the plan.
        let refiners: Vec<RangeRefiner> = pipeline
            .boundaries()
            .iter()
            .map(|&b| RangeRefiner::new(qoe_model, b, RefineConfig::default()))
            .collect();

        let mut migration = MigrationManager::new(cfg.model.kv_bytes_per_token() as f64);
        if fleet.has_tensor_parallel() {
            // Price each transfer from the *sender's* resolved TP
            // slice: a TP4 sender moves 4x fewer bytes per token than
            // the base model.  TP-free fleets skip the table and keep
            // the single-footprint legacy path bit-identically.
            migration.set_instance_footprints(
                fleet
                    .instances
                    .iter()
                    .map(|spec| spec.model_for(&cfg.model).kv_bytes_per_token() as f64)
                    .collect(),
            );
        }
        let mut stats = RunStats {
            stages: stages.clone(),
            instance_gpus: fleet.gpu_names(),
            instance_tp: fleet.tp_degrees(),
            instance_capacity: caps.clone(),
            ..Default::default()
        };
        if let Some(pd) = &pd_state {
            // The reporting copy shows both pools; the routing copy
            // (`Self::stages`) holds the decode pool only.
            stats.stages = vec![pd.prefill_pool.clone(), pd.decode_pool.clone()];
        }

        let mut cluster = Self {
            cfg,
            instances,
            pipeline,
            stage_of,
            stages,
            ranges: Vec::new(),
            refiners,
            topology,
            migration,
            in_flight: Default::default(),
            events: EventQueue::new(),
            records: Vec::new(),
            stats,
            qoe: qoe_model,
            router: Router::new(),
            predictor,
            rerouted: Default::default(),
            n_requests_total: 0,
            snapshot_marks: vec![0.2, 0.4, 0.6, 0.8],
            planner,
            retry_after: Default::default(),
            offers: Default::default(),
            promises: Default::default(),
            observed: RecentWindow::new(REPLAN_WINDOW),
            arena: RequestArena::new(),
            caps,
            plan_insts,
            load_sample_sum: vec![0.0; total],
            load_samples: 0,
            replans: 0,
            churn_schedule,
            drain_spec,
            boot_latency,
            spot_attempts: Default::default(),
            pending_joins,
            booting: (e..e + pending_joins).collect(),
            autoscale_watermark: 0,
            admitting: (0..e).collect(),
            pd: pd_state,
        };
        cluster.rebuild_ranges();
        cluster
    }

    /// Recompute the cached per-stage ranges from the refiner
    /// boundaries.  Called on construction and whenever a boundary
    /// moves (refine / replan) — never on the per-event hot path.
    fn rebuild_ranges(&mut self) {
        let mut out = Vec::with_capacity(self.pipeline.stages.len());
        let mut lo = 0;
        for i in 0..self.pipeline.stages.len() {
            let hi = if i < self.refiners.len() {
                self.refiners[i].boundary
            } else {
                self.cfg.max_len
            };
            out.push((lo, hi));
            lo = hi;
        }
        self.ranges = out;
    }

    /// Current stage ranges (after refinement).
    pub fn stage_ranges(&self) -> Vec<(Tokens, Tokens)> {
        self.ranges.clone()
    }

    fn stage_for_len(&self, len: Tokens) -> usize {
        router::stage_for_len(&self.ranges, len)
    }

    // ----- §4.4 bid-ask + §5 migration protocol handlers ---------------

    /// CascadeInfer per-iteration coordination: hand over outgrown
    /// sequences to the next stage, rebalance within the stage.
    fn cascade_post_step(&mut self, now: Time, i: InstanceId) {
        // Disaggregated layouts have no inter-stage handover or
        // intra-stage bid-ask: every transfer is a prefill->decode
        // handoff driven by the PD pump.
        if self.pd.is_some() {
            return;
        }
        let stage = self.stage_of[i];
        let (_, hi) = self.ranges[stage];
        let last_stage = stage + 1 >= self.stages.len();

        // --- Inter-stage handover: sequences that outgrew the range.
        // Gate the O(batch) scan on the engine's monotone length bound:
        // while every row is provably below `hi` the scan would find
        // nothing, and this check is O(1) per iteration.  When the scan
        // does run, re-tighten the bound so a departed long sequence
        // stops triggering it.
        if !last_stage && self.instances[i].engine.max_len_upper() >= hi {
            let outgrown: Vec<(Request, Tokens)> = self.instances[i]
                .engine
                .running()
                .iter()
                .filter(|s| {
                    s.phase == Phase::Decoding
                        && s.current_len() >= hi
                        && !self.migration.is_migrating(s.req.id)
                        && s.remaining() > 8 // not worth moving a nearly-done seq
                })
                .map(|s| (s.req, s.current_len()))
                .collect();
            self.instances[i].engine.tighten_len_hint();
            for (req, len) in outgrown {
                // Misprediction recovery: a sequence that grew past its
                // *predicted* final outlived the stage the predictor
                // routed it to — the handover below is its re-route.
                // Counted once per request; under `oracle` current
                // length never exceeds the true final, so the gate is
                // never taken.
                if !self.predictor.is_oracle()
                    && len > self.predictor.predicted_final(&req)
                    && self.rerouted.insert(req.id)
                {
                    self.stats.predict_reroutes += 1;
                }
                let next_stage =
                    self.stage_for_len(len).max(stage + 1).min(self.stages.len() - 1);
                let candidates = self.stages[next_stage].clone();
                self.bid_ask_migrate(now, i, req.id, len, &candidates);
            }
        }

        // --- Intra-stage rebalance: am I an overloaded outlier?
        // Hysteresis: one outstanding offer per instance per cooldown
        // window, so a persistent imbalance migrates a few sequences,
        // not a stampede (§4.4's trigger is an *outlier* condition,
        // re-evaluated after the stage settles).
        const OFFER_COOLDOWN: Time = 0.5;
        if self.cfg.policy.balance == BalancePolicy::Full
            && now - self.instances[i].last_offer >= OFFER_COOLDOWN
        {
            let my_load = self.instances[i].norm_load();
            // Peer reports older than three gossip periods are stale —
            // an instance that went silent (dead, draining, or wedged)
            // must not keep winning outlier comparisons with its last
            // load figure.  Static fleets refresh every report each
            // gossip tick, so at the default interval this filter
            // admits exactly the reports the old fixed 1.0 s window
            // did (bit-identical); only silent peers age out earlier.
            if self.instances[i].tracker.is_overloaded(
                now,
                my_load,
                self.cfg.overload_threshold,
                3.0 * self.cfg.gossip_interval,
            ) {
                self.instances[i].last_offer = now;
                // Offer the most demanding decoding sequence to peers.
                let peers: Vec<InstanceId> =
                    self.stages[stage].iter().copied().filter(|&p| p != i).collect();
                if let Some((rid, len)) = self.instances[i]
                    .engine
                    .running()
                    .iter()
                    .filter(|s| {
                        s.phase == Phase::Decoding
                            && !self.migration.is_migrating(s.req.id)
                            && s.remaining() > 16
                    })
                    .max_by_key(|s| s.current_len())
                    .map(|s| (s.req.id, s.current_len()))
                {
                    self.bid_ask_migrate(now, i, rid, len, &peers);
                }
            }
        }
    }

    /// Run the bid-ask selection over `candidates` and start the KV
    /// transfer to the winner (§4.4 + §5).
    fn bid_ask_migrate(
        &mut self,
        now: Time,
        from: InstanceId,
        request: RequestId,
        seq_len: Tokens,
        candidates: &[InstanceId],
    ) {
        if candidates.is_empty() || self.in_flight.contains(&request) {
            return;
        }
        // Back off after a failed attempt (no dest slot / at the
        // concurrency cap) instead of retrying every iteration.
        if self.retry_after.get(&request).map(|&t| now < t).unwrap_or(false) {
            return;
        }
        if self.offers.contains_key(&request)
            || self.instances[from].scheduler.sender.is_open(request)
        {
            return; // negotiation already in flight
        }
        if self.cfg.policy.balance == BalancePolicy::RoundRobinIntra {
            // Ablation: skip the negotiation, rotate receivers.
            let to = candidates[self.router.next_rr() % candidates.len()];
            if to != from {
                self.start_transfer(now, request, from, to, seq_len);
            }
            return;
        }
        // --- Asking phase: notify every candidate receiver (§4.4).
        // Loads ride the protocol capacity-normalized so heterogeneous
        // receivers are compared on equal footing.
        let sender_load = self.instances[from].norm_load();
        // Only admitting instances are valid migration destinations;
        // under a churn-free fleet every candidate admits, so the
        // filter is a no-op.
        let targets: Vec<InstanceId> = candidates
            .iter()
            .copied()
            .filter(|&c| c != from && self.instances[c].admits())
            .collect();
        if targets.is_empty() {
            return;
        }
        self.instances[from].scheduler.sender.open(request, targets.len());
        self.offers.insert(request, (from, seq_len, sender_load));
        let ask = Ask { sender: from, request, seq_len, sender_load };
        for c in targets {
            let latency = self.topology.link_between(from, c).latency_s();
            self.events
                .schedule(now + latency, Event::AskDelivered { receiver: c, ask });
        }
    }

    /// Bidding phase: the receiver replies with its load and earliest
    /// transmission start (buffered length / measured throughput).
    fn on_ask(&mut self, now: Time, receiver: InstanceId, ask: Ask) {
        if !self.instances[receiver].admits() {
            // The receiver stopped admitting between ask send and
            // delivery.  Still reply — with an unbeatable-bad bid — so
            // the sender's book reaches its expected reply count and
            // the offer resolves instead of wedging open.
            let latency = self.topology.link_between(ask.sender, receiver).latency_s();
            let reply_at = now + latency;
            let bid = Bid {
                receiver,
                request: ask.request,
                load: f64::INFINITY,
                earliest_start: f64::INFINITY,
                reply_at,
            };
            self.events
                .schedule(reply_at, Event::BidDelivered { sender: ask.sender, bid });
            return;
        }
        let buffered = self.instances[receiver].scheduler.receiver.buffered_len()
            + self.inbound_tokens(receiver);
        // Receivers reply between engine iterations; model that
        // scheduling delay with a deterministic per-(request, receiver)
        // hash so first-reply selection doesn't degenerate into
        // always-lowest-id.
        let jitter = {
            let mut h = ask
                .request
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(receiver as u64);
            h ^= h >> 33;
            h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            (h >> 40) as f64 / (1u64 << 24) as f64 * 2.0e-3
        };
        let latency = self.topology.link_between(ask.sender, receiver).latency_s();
        let reply_at = now + latency + jitter;
        let bid = Bid {
            receiver,
            request: ask.request,
            // Capacity-normalized: a fast H100 carrying more raw
            // tokens than a saturating H20 still (correctly) outbids
            // it.  On homogeneous fleets capacity is exactly 1.0 and
            // this equals the raw token count.
            load: (self.instances[receiver].engine.token_load() + buffered) as f64
                / self.instances[receiver].capacity,
            earliest_start: now
                + buffered as f64 / self.instances[receiver].tracker.throughput().max(1.0),
            reply_at,
        };
        self.events.schedule(reply_at, Event::BidDelivered { sender: ask.sender, bid });
    }

    /// All bids in: run the §4.4 selection (drop high-load half, keep
    /// 3 earliest starts, first reply wins) and confirm the handover.
    fn on_bid(&mut self, now: Time, sender: InstanceId, bid: Bid) {
        let request = bid.request;
        let Some(chosen) = self.instances[sender].scheduler.sender.record(bid) else {
            return; // still collecting
        };
        let Some(&(from, seq_len, sender_load)) = self.offers.get(&request) else {
            return;
        };
        debug_assert_eq!(from, sender);
        let pull = PendingPull {
            sender,
            request,
            seq_len,
            priority: sender_load,
            failed_attempts: 0,
        };
        let latency = self.topology.link_between(sender, chosen).latency_s();
        self.events
            .schedule(now + latency, Event::ConfirmDelivered { receiver: chosen, pull });
    }

    /// Confirm: the receiver queues the pull by sender-load priority
    /// and drives its transfer queue.
    fn on_confirm(&mut self, now: Time, receiver: InstanceId, pull: PendingPull) {
        if !self.instances[receiver].admits() {
            // Chosen receiver left between confirm send and delivery:
            // resolve the offer so the sender can renegotiate later.
            self.offers.remove(&pull.request);
            self.retry_after.insert(pull.request, now + 0.25);
            return;
        }
        self.instances[receiver].scheduler.receiver.push(pull);
        self.events.schedule(now, Event::PullAttempt { receiver });
    }

    /// Receiver-side pull loop: dequeue the highest-priority request
    /// whose sender is not already transmitting; escalate starvation.
    fn on_pull(&mut self, now: Time, receiver: InstanceId) {
        if self.migration.at_capacity(receiver) {
            if !self.instances[receiver].scheduler.receiver.is_empty() {
                self.events.schedule(now + 0.05, Event::PullAttempt { receiver });
            }
            return;
        }
        let migration = &self.migration;
        let action = self.instances[receiver]
            .scheduler
            .receiver
            .next_action(|sndr| migration.sender_busy(sndr));
        match action {
            PullAction::Pull(p) => {
                self.try_pull(now, receiver, p);
                if !self.instances[receiver].scheduler.receiver.is_empty() {
                    self.events.schedule(now + 0.01, Event::PullAttempt { receiver });
                }
            }
            PullAction::Starved(p) => {
                // Notify the sender; the receiver waits for this pull
                // instead of skipping further (§4.4).
                let latency = self.topology.link_between(p.sender, receiver).latency_s();
                self.events.schedule(
                    now + latency,
                    Event::StarveNotice { sender: p.sender, pull: p, receiver },
                );
            }
            PullAction::Idle => {}
        }
    }

    /// Start the actual KV transfer for a granted pull.
    fn try_pull(&mut self, now: Time, receiver: InstanceId, p: PendingPull) {
        let request = p.request;
        if !self.instances[receiver].admits() {
            // Receiver drained/died while the pull sat queued.
            self.offers.remove(&request);
            self.retry_after.insert(request, now + 0.25);
            return;
        }
        // The sequence may have finished or moved since the offer.
        let live_len = self.instances[p.sender]
            .engine
            .running()
            .iter()
            .find(|s| s.req.id == request)
            .map(|s| s.current_len());
        let Some(len) = live_len else {
            self.offers.remove(&request);
            return;
        };
        if self.migration.is_migrating(request) || self.in_flight.contains(&request) {
            return;
        }
        self.start_transfer(now, request, p.sender, receiver, len);
    }

    /// Sender promised to transmit `pull` right after its current
    /// transfer; remember the promise.
    fn on_starve(
        &mut self,
        _now: Time,
        sender: InstanceId,
        pull: PendingPull,
        receiver: InstanceId,
    ) {
        self.promises.entry(sender).or_default().push((pull, receiver));
    }

    /// Common transfer start: §5 flow control (idle-slot check,
    /// concurrency cap) + live-migration scheduling.
    fn start_transfer(
        &mut self,
        now: Time,
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        seq_len: Tokens,
    ) {
        if !self.instances[to].admits() || !self.instances[from].serves() {
            // Endpoint membership changed under the negotiation; count
            // it like any other failed start so the offer resolves.
            self.stats.migrations_skipped += 1;
            self.offers.remove(&request);
            self.retry_after.insert(request, now + 0.25);
            return;
        }
        let link = self.topology.link_between(from, to);
        let decode_rate = self.instances[from].tracker.throughput()
            / self.instances[from].engine.n_running().max(1) as f64;
        let dest_free = self.instances[to].engine.kv().can_allocate(seq_len + 64);
        if let Some(t) = self
            .migration
            .try_start(now, request, from, to, seq_len, link, decode_rate, dest_free)
        {
            self.in_flight.insert(request);
            self.retry_after.remove(&request);
            self.offers.remove(&request);
            self.events
                .schedule(t.finish_at, Event::MigrationDone { request, from, to });
        } else {
            self.stats.migrations_skipped += 1;
            self.offers.remove(&request);
            self.retry_after.insert(request, now + 0.25);
        }
    }

    /// Tokens already inbound to instance `i` from active transfers —
    /// the receiver's "buffered length" in the bid. Counting in-flight
    /// arrivals prevents the herd effect where every sender picks the
    /// same momentarily-least-loaded receiver.  O(1) (running sum kept
    /// by the migration manager).
    fn inbound_tokens(&self, i: InstanceId) -> Tokens {
        self.migration.inbound_tokens(i)
    }

    fn on_migration_done(
        &mut self,
        now: Time,
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
    ) {
        if !self.cfg.churn.is_none() && !self.migration.matches(request, from, to, now) {
            // Stale completion: this transfer was aborted by a churn
            // event (and the request possibly re-admitted and migrating
            // again) — completing it now would corrupt the new state.
            return;
        }
        self.in_flight.remove(&request);
        let Some(t) = self.migration.finish(request) else { return };
        // The sequence kept decoding on the source during the transfer
        // (live migration). Move it now if it still exists.
        if let Some(seq) = self.instances[from].engine.extract(request) {
            if self.instances[to].admits() && self.instances[to].engine.inject(seq) {
                if self.pd.is_some() {
                    // PD: the transfer was a completed-prefill KV
                    // handoff, not a load-balance migration.
                    self.stats.pd_handoffs += 1;
                    self.stats.pd_handoff_tokens += t.tokens_moved;
                } else {
                    self.stats.migrations += 1;
                    self.stats.migration_tokens += t.tokens_moved;
                }
                // Single-step kicks: more driver work follows at this
                // same instant (the second kick, starvation promises),
                // and under micro-stepping it runs before any later
                // iteration of `to`/`from` — inline advancement here
                // would reorder it.  See `Cluster::kick_scheduled`.
                self.kick_scheduled(now, to);
            } else {
                // Destination filled up mid-flight: keep on source
                // (§5: requests exceeding the cap keep running there).
                let back = self.instances[from].engine.inject(seq);
                debug_assert!(back, "source must re-accept its own sequence");
                self.stats.migrations_skipped += 1;
            }
        }
        self.kick_scheduled(now, from);
        // Starvation promises: the sender transmits the starved pull
        // immediately after completing its current transfer (§4.4).
        if let Some(mut list) = self.promises.remove(&from) {
            if let Some((p, receiver)) = list.pop() {
                self.try_pull(now, receiver, p);
            }
            if !list.is_empty() {
                self.promises.insert(from, list);
            }
        }
    }

    /// Expose the fitted QoE model (for validation figures).
    pub fn qoe_model(&self) -> QoeModel {
        self.qoe
    }

    /// Per-stage live sequence lengths (testing / figures).
    pub fn stage_loads(&self) -> Vec<Vec<Tokens>> {
        self.stages
            .iter()
            .map(|members| {
                members
                    .iter()
                    .flat_map(|&i| {
                        self.instances[i].engine.running().iter().map(Sequence::current_len)
                    })
                    .collect()
            })
            .collect()
    }
}

/// Convenience: run one (scheduler, workload) experiment end to end.
pub fn run_experiment(cfg: ClusterConfig, requests: &[Request]) -> (Report, RunStats) {
    let cluster = Cluster::new(cfg, requests);
    cluster.run(requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LLAMA_3B;
    use crate::workload::{generate, ShareGptLike};

    fn small_cfg(scheduler: SchedulerKind) -> ClusterConfig {
        let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, scheduler);
        cfg.plan_sample = 500;
        cfg
    }

    fn workload(n: usize, rate: f64, seed: u64) -> Vec<Request> {
        generate(&ShareGptLike::default(), rate, n, seed)
    }

    #[test]
    fn all_requests_complete_cascade() {
        let reqs = workload(200, 20.0, 1);
        let (report, stats) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        assert_eq!(report.records.len(), 200);
        assert!(report.mean_ttft() > 0.0);
        assert!(report.throughput_tokens_per_s() > 0.0);
        assert!(!stats.stages.is_empty());
    }

    #[test]
    fn all_requests_complete_baselines() {
        let reqs = workload(150, 15.0, 2);
        for k in [
            SchedulerKind::RoundRobin,
            SchedulerKind::SgLangLike,
            SchedulerKind::LlumnixLike,
            SchedulerKind::Chain,
            SchedulerKind::NoPipeline,
        ] {
            let (report, _) = run_experiment(small_cfg(k), &reqs);
            assert_eq!(report.records.len(), 150, "{k:?} dropped requests");
        }
    }

    #[test]
    fn deterministic_runs() {
        let reqs = workload(100, 10.0, 3);
        let (r1, s1) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        let (r2, s2) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        assert_eq!(r1.records.len(), r2.records.len());
        assert_eq!(s1.migrations, s2.migrations);
        let t1: f64 = r1.records.iter().map(|r| r.completion).sum();
        let t2: f64 = r2.records.iter().map(|r| r.completion).sum();
        assert!((t1 - t2).abs() < 1e-9);
    }

    #[test]
    fn custom_policy_spec_runs_without_a_kind() {
        // An axis combination no legacy SchedulerKind expresses:
        // planned layout + memory refinement + round-robin intra.
        let spec =
            PolicySpec::resolve("custom:layout=planned,refine=memory,balance=rrintra").unwrap();
        let reqs = workload(150, 15.0, 22);
        let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, spec);
        cfg.plan_sample = 500;
        let (report, _) = run_experiment(cfg, &reqs);
        assert_eq!(report.records.len(), 150);
    }

    #[test]
    fn shortest_first_dispatch_completes_all_requests() {
        let spec = PolicySpec::resolve("sjf").unwrap();
        let reqs = workload(150, 15.0, 23);
        let mut cfg = ClusterConfig::new(GpuProfile::H20, LLAMA_3B, 4, spec);
        cfg.plan_sample = 500;
        let (report, stats) = run_experiment(cfg, &reqs);
        assert_eq!(report.records.len(), 150);
        assert_eq!(stats.migrations, 0);
    }

    #[test]
    fn cascade_pipeline_has_multiple_stages() {
        let reqs = workload(500, 10.0, 4);
        let cluster = Cluster::new(small_cfg(SchedulerKind::Cascade), &reqs);
        assert!(cluster.pipeline.stages.len() > 1, "{:?}", cluster.pipeline.stages);
        assert_eq!(cluster.pipeline.total_instances(), 4);
    }

    #[test]
    fn cascade_migrates_growing_sequences() {
        // Long outputs force sequences across stage boundaries.
        let mut reqs = workload(120, 12.0, 5);
        for r in reqs.iter_mut() {
            r.output_len = r.output_len.max(1500);
        }
        let (report, stats) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        assert_eq!(report.records.len(), 120);
        assert!(stats.migrations > 0, "expected inter-stage handovers: {stats:?}");
    }

    #[test]
    fn round_robin_never_migrates() {
        let reqs = workload(100, 10.0, 6);
        let (_, stats) = run_experiment(small_cfg(SchedulerKind::RoundRobin), &reqs);
        assert_eq!(stats.migrations, 0);
    }

    #[test]
    fn refinement_updates_boundaries() {
        let mut cfg = small_cfg(SchedulerKind::Cascade);
        cfg.refine_interval = 0.5;
        let reqs = workload(300, 30.0, 7);
        let cluster = Cluster::new(cfg.clone(), &reqs);
        let initial = cluster.pipeline.boundaries();
        let (_, stats) = run_experiment(cfg, &reqs);
        assert!(stats.refinements > 0);
        assert_eq!(stats.final_boundaries.len(), initial.len());
    }

    #[test]
    fn heavy_load_cascade_not_worse_than_round_robin() {
        // The headline comparison (Figs. 6-7) at miniature scale.
        let reqs = workload(400, 40.0, 8);
        let (cascade, _) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        let (rr, _) = run_experiment(small_cfg(SchedulerKind::RoundRobin), &reqs);
        assert_eq!(cascade.records.len(), rr.records.len());
        assert!(
            cascade.mean_tpot() < rr.mean_tpot() * 1.10,
            "cascade {} vs rr {}",
            cascade.mean_tpot(),
            rr.mean_tpot()
        );
    }

    #[test]
    fn fig1_snapshots_collected() {
        let reqs = workload(300, 25.0, 9);
        let (_, stats) = run_experiment(small_cfg(SchedulerKind::Cascade), &reqs);
        assert!(!stats.batch_snapshots.is_empty());
    }

    #[test]
    fn stage_ranges_are_monotone_throughout() {
        let mut cfg = small_cfg(SchedulerKind::Cascade);
        cfg.refine_interval = 0.3;
        let reqs = workload(250, 25.0, 10);
        let (_, stats) = run_experiment(cfg, &reqs);
        for w in stats.final_boundaries.windows(2) {
            assert!(w[0] < w[1], "boundaries must stay ordered: {:?}", stats.final_boundaries);
        }
    }

    #[test]
    fn cached_ranges_match_refiner_boundaries() {
        // The cached `ranges` table is the hot-path view of the refiner
        // boundaries; they must agree at construction.
        let reqs = workload(300, 10.0, 21);
        let cluster = Cluster::new(small_cfg(SchedulerKind::Cascade), &reqs);
        let ranges = cluster.stage_ranges();
        assert_eq!(ranges.len(), cluster.pipeline.stages.len());
        assert_eq!(ranges[0].0, 0);
        assert_eq!(ranges.last().unwrap().1, cluster.cfg.max_len);
        for (b, r) in cluster.refiners.iter().zip(ranges.iter()) {
            assert_eq!(b.boundary, r.1);
        }
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges must tile contiguously");
        }
    }
}
