//! Event loop — the "driver" layer of the cluster split.
//!
//! Owns the event alphabet ([`Event`]), the discrete-event clock
//! ([`crate::sim::EventQueue`]), dispatch, and the periodic timers
//! (gossip, refinement, re-planning, the Llumnix-style baseline
//! rebalancer).  Handlers here never rescan per-instance sequence
//! state except where the *semantics* require it (outgrown-sequence
//! scans, refinement unions); every load/occupancy probe is an O(1)
//! running aggregate maintained by [`super::state::InstanceState`].

use crate::coordinator::balance::{Ask, Bid, PendingPull};
use crate::coordinator::loadtracker::LoadReport;
use crate::coordinator::plan::PlanInstance;
use crate::coordinator::refine::{naive, RangeRefiner, RefineConfig};
use crate::engine::{MacroStop, Phase, Sequence};
use crate::metrics::{Report, RequestRecord, Slo};
use crate::workload::{LengthHistogram, Request};
use crate::{InstanceId, RequestId, Time, Tokens};

use super::elastic::{
    Membership, AUTOSCALE_ATTAIN_HIGH, AUTOSCALE_ATTAIN_LOW, AUTOSCALE_QUEUE_FACTOR,
    AUTOSCALE_SLO_TPOT, AUTOSCALE_SLO_TTFT, DEFAULT_DRAIN_DEADLINE, DRAIN_PUMP_INTERVAL,
    MAX_SPOT_RETRIES, READMIT_BACKOFF_BASE,
};
use super::policy::{BalancePolicy, Layout, RefinePolicy};
use super::{Cluster, RunStats};

/// The cluster's event alphabet.
#[derive(Debug, Clone)]
pub(super) enum Event {
    Arrival(Request),
    /// Instance finished one engine iteration.
    StepDone(InstanceId),
    /// Periodic load gossip.
    Gossip,
    /// Periodic stage-range refinement.
    Refine,
    /// Periodic full pipeline re-planning (§4.2).
    Replan,
    /// Periodic Llumnix-style rebalance check (baseline only).
    BaselineRebalance,
    /// KV transfer completed.
    MigrationDone { request: RequestId, from: InstanceId, to: InstanceId },
    /// §4.4 asking phase: an Ask reaches a candidate receiver.
    AskDelivered { receiver: InstanceId, ask: Ask },
    /// §4.4 bidding phase: a Bid reaches the asking sender.
    BidDelivered { sender: InstanceId, bid: Bid },
    /// §4.4 confirm: ownership handover reaches the chosen receiver.
    ConfirmDelivered { receiver: InstanceId, pull: PendingPull },
    /// Receiver drains its priority queue (starts actual transfers).
    PullAttempt { receiver: InstanceId },
    /// Starvation escalation reaches the sender (§4.4).
    StarveNotice { sender: InstanceId, pull: PendingPull, receiver: InstanceId },
    /// Elastic fleets: an `Absent` slot finished its weight load and
    /// goes live.
    InstanceJoin(InstanceId),
    /// Elastic fleets: graceful scale-in.  The first firing flips the
    /// instance to `Draining`; subsequent firings are the recurring
    /// drain pump (requeue/offer residue, check empty + deadline).
    DrainStart(InstanceId),
    /// Elastic fleets: spot preemption — the instance dies here.
    InstanceGone(InstanceId),
    /// Elastic fleets: periodic SLO-feedback autoscaler observation.
    AutoscaleTick,
    /// Elastic fleets: a preempted request re-enters admission after
    /// its backoff (capped attempts, then a counted rejection).
    Readmit(Request),
    /// PD layouts: the waiting window expired — drain the short/long
    /// prefill queues into similar-length batches.
    PdFlush,
    /// PD layouts: retry the handoff pump after a failed start (clears
    /// the retry gate; the post-dispatch pump does the work).
    PdPump,
    /// PD layouts: periodic dynamic P/D re-allocation check.
    PdRebalance,
}

impl Cluster {
    /// Run the full workload; returns the report and run stats.
    ///
    /// Arrivals ride the event queue's reserved *front-class* seq lane
    /// ([`crate::sim::EventQueue::schedule_front_class`]): scheduled
    /// here, before any timer or runtime event, they carried the
    /// globally smallest insertion seqs under the single-lane queue
    /// too, so the lane changes nothing — but it lets
    /// [`Cluster::run_stream`] schedule arrivals lazily with the exact
    /// same tie-break rank.
    pub fn run(mut self, requests: &[Request]) -> (Report, RunStats) {
        self.n_requests_total = requests.len();
        for r in requests {
            self.events.schedule_front_class(r.arrival, Event::Arrival(*r));
        }
        self.schedule_timers();

        let mut guard: u64 = 0;
        while let Some((now, ev)) = self.events.pop() {
            guard += 1;
            assert!(guard < 500_000_000, "cluster event loop runaway");
            self.dispatch(now, ev);
            // Stop once all requests completed (or were rejected at
            // admission) and only periodic timers remain in the queue.
            if self.all_done(self.n_requests_total) {
                break;
            }
        }
        self.finish()
    }

    /// Run a lazily generated workload: exactly one pending `Arrival`
    /// event exists at any time, so resident memory is O(instances +
    /// in-flight requests) instead of O(trace length).
    ///
    /// Bit-identity with [`Cluster::run`] on the same request sequence
    /// holds because (a) the next arrival is scheduled *before* the
    /// popped one dispatches, so every macro-stretch horizon
    /// ([`crate::sim::EventQueue::peek_time`]) and same-instant
    /// tie-break sees the earliest unpopped arrival exactly as the
    /// fully scheduled queue does (later arrivals can never be the
    /// minimum while an earlier one is pending), and (b) lazily
    /// scheduled arrivals draw the same front-class seqs 0,1,2,... they
    /// would have drawn up front.  This requires non-decreasing arrival
    /// times (asserted) — replay unsorted traces through the
    /// materialized path instead.
    ///
    /// `n_requests_total` is the full stream length (it anchors the
    /// Fig. 1 snapshot-mark progress fractions); pass the generator's
    /// request count or [`crate::workload::count_csv_rows`].
    pub fn run_stream<I>(mut self, mut arrivals: I, n_requests_total: usize) -> (Report, RunStats)
    where
        I: Iterator<Item = Request>,
    {
        self.n_requests_total = n_requests_total;
        let mut delivered: usize = 0;
        let mut last_arrival: Time = 0.0;
        if let Some(r) = arrivals.next() {
            last_arrival = r.arrival;
            self.events.schedule_front_class(r.arrival, Event::Arrival(r));
            delivered = 1;
        }
        let mut stream_done = delivered == 0;
        self.schedule_timers();

        let mut guard: u64 = 0;
        while let Some((now, ev)) = self.events.pop() {
            guard += 1;
            assert!(guard < 500_000_000, "cluster event loop runaway");
            // Pull the next arrival in *before* dispatching this one,
            // so the queue state the handler observes matches the
            // pre-scheduled path.
            if matches!(ev, Event::Arrival(_)) && !stream_done {
                match arrivals.next() {
                    Some(r) => {
                        assert!(
                            r.arrival >= last_arrival,
                            "run_stream requires non-decreasing arrival times \
                             (got {} after {last_arrival}); replay unsorted \
                             traces through Cluster::run",
                            r.arrival
                        );
                        last_arrival = r.arrival;
                        self.events.schedule_front_class(r.arrival, Event::Arrival(r));
                        delivered += 1;
                    }
                    None => stream_done = true,
                }
            }
            self.dispatch(now, ev);
            // Same break instant as the materialized loop: with the
            // stream exhausted, `delivered` is the full request count.
            if stream_done && self.all_done(delivered) {
                break;
            }
        }
        self.finish()
    }

    /// Schedule the periodic timers (gossip / refine / replan /
    /// baseline rebalance) — after the initial arrival scheduling, so
    /// their normal-lane seqs follow both driver entry points
    /// identically.
    fn schedule_timers(&mut self) {
        if self.cfg.gossip_interval > 0.0 && self.cfg.policy.gossip {
            self.events.schedule(self.cfg.gossip_interval, Event::Gossip);
        }
        if self.cfg.refine_interval > 0.0 && self.cfg.policy.refine != RefinePolicy::Off {
            self.events.schedule(self.cfg.refine_interval, Event::Refine);
        }
        if self.cfg.policy.balance == BalancePolicy::PeriodicLengthAgnostic {
            self.events.schedule(0.25, Event::BaselineRebalance);
        }
        if self.cfg.replan_interval > 0.0
            && self.cfg.policy.layout == Layout::Planned
            && self.cfg.forced_pipeline.is_none()
        {
            self.events.schedule(self.cfg.replan_interval, Event::Replan);
        }
        // Churn events ride the same calendar lane, scheduled last so
        // the legacy timers keep their normal-lane insertion seqs.  A
        // `ChurnSpec::none()` run schedules nothing here — the queue
        // state is bit-identical to before this block existed.
        for (at, ev) in std::mem::take(&mut self.churn_schedule) {
            self.events.schedule(at, ev);
        }
        if let Some(auto) = self.cfg.churn.autoscale {
            self.events.schedule(auto.period, Event::AutoscaleTick);
        }
        // PD dynamic re-allocation rides its own periodic timer;
        // `balance=off` pins the pools for the whole run.
        if self.pd.is_some() && self.cfg.policy.balance != BalancePolicy::Off {
            self.events.schedule(super::pd::PD_REBALANCE_INTERVAL, Event::PdRebalance);
        }
    }

    /// Route one popped event to its handler.
    fn dispatch(&mut self, now: Time, ev: Event) {
        match ev {
            Event::Arrival(req) => self.on_arrival(now, req),
            Event::StepDone(i) => self.on_step_done(now, i),
            Event::Gossip => self.on_gossip(now),
            Event::Refine => self.on_refine(now),
            Event::BaselineRebalance => self.on_baseline_rebalance(now),
            Event::Replan => self.on_replan(now),
            Event::MigrationDone { request, from, to } => {
                self.on_migration_done(now, request, from, to)
            }
            Event::AskDelivered { receiver, ask } => self.on_ask(now, receiver, ask),
            Event::BidDelivered { sender, bid } => self.on_bid(now, sender, bid),
            Event::ConfirmDelivered { receiver, pull } => self.on_confirm(now, receiver, pull),
            Event::PullAttempt { receiver } => self.on_pull(now, receiver),
            Event::StarveNotice { sender, pull, receiver } => {
                self.on_starve(now, sender, pull, receiver)
            }
            Event::InstanceJoin(i) => self.on_instance_join(now, i),
            Event::DrainStart(i) => self.on_drain_start(now, i),
            Event::InstanceGone(i) => self.on_instance_gone(now, i),
            Event::AutoscaleTick => self.on_autoscale_tick(now),
            Event::Readmit(req) => self.on_readmit(now, req),
            Event::PdFlush => self.on_pd_flush(now),
            Event::PdPump => self.on_pd_pump_timer(),
            Event::PdRebalance => self.on_pd_rebalance(now),
        }
        // PD handoff pump: engine progress only happens inside event
        // handlers, so running after every dispatch guarantees no
        // parked completed prefill is ever stranded.  Colocated
        // layouts (`pd.is_none()`) skip this entirely.
        if self.pd.is_some() {
            self.pd_pump(now);
        }
    }

    /// All `target` requests accounted for (completed or rejected),
    /// every engine drained, no KV transfer in flight.
    fn all_done(&self, target: usize) -> bool {
        self.records.len() + self.stats.rejected as usize >= target
            && !self.instances.iter().any(|ins| ins.engine.has_work())
            && self.in_flight.is_empty()
    }

    /// Final stats assembly shared by both driver entry points.
    fn finish(mut self) -> (Report, RunStats) {
        self.stats.final_boundaries = self.refiners.iter().map(|r| r.boundary).collect();
        self.stats.engine_iterations =
            self.instances.iter().map(|ins| ins.engine.total_iterations).sum();
        self.stats.arena_high_water = self.arena.high_water() as u64;
        if self.load_samples > 0 {
            let n = self.load_samples as f64;
            self.stats.mean_token_load =
                self.load_sample_sum.iter().map(|s| s / n).collect();
        }
        (Report::from_records(std::mem::take(&mut self.records)), self.stats)
    }

    /// Advance instance `i` if it is idle and has admittable work —
    /// the macro-step hot loop.
    ///
    /// Between "interesting" instants (arrivals, timers, protocol
    /// deliveries) the driver advances as many engine iterations as fit
    /// *inline*: an iteration whose end precedes every queued event
    /// would have had its `StepDone` popped next anyway, so its
    /// boundary work (snapshot marks, §4.4 post-step hooks) runs here
    /// without any queue traffic, preserving the exact micro-stepped
    /// event order — including FIFO tie-breaks, because a `StepDone`
    /// would carry a younger insertion seq than anything already queued
    /// and therefore loses timestamp ties.  Iterations that overrun the
    /// next queued event are committed and their completion scheduled
    /// as a real `StepDone`, exactly like the in-flight iteration of
    /// the micro-stepped loop.
    ///
    /// Policies with no per-iteration driver work (no bid-ask hooks)
    /// additionally batch whole stretches through
    /// [`crate::engine::Engine::run_until`] while no snapshot mark is
    /// near, skipping even the per-iteration driver dispatch.
    /// `cfg.micro_step` forces the historical one-event-per-iteration
    /// path for A/B verification.
    pub(super) fn kick(&mut self, now: Time, i: InstanceId) {
        let mut now = now;
        loop {
            if self.instances[i].busy || !self.instances[i].engine.has_work() {
                return;
            }
            let bid_ask = self.cfg.policy.balance.uses_bid_ask();
            if !self.cfg.micro_step && !bid_ask && !self.snapshot_mark_near() {
                // Engine-side macro stretch: no per-iteration driver
                // work can occur, so let the engine rip until the next
                // queued event, a completion (progress moves — the
                // snapshot check must rerun), or idleness.
                let horizon = self.events.peek_time().unwrap_or(f64::INFINITY);
                let ins = &mut self.instances[i];
                let engine = &mut ins.engine;
                let tracker = &mut ins.tracker;
                let mo = engine.run_until(now, horizon, |t, tokens| {
                    tracker.observe_tokens(t, tokens);
                });
                if mo.iterations == 0 {
                    return; // idle or memory-blocked, nothing committed
                }
                self.stats.preemptions += mo.preempted;
                self.stats.counters.add(i, mo.tokens_emitted);
                if self.instances[i].engine.prefill_only() {
                    // Single-token outputs completing *on* the prefill
                    // pool (no handoff needed); always 0 colocated.
                    self.stats.pd_local_completions += mo.completed.len() as u64;
                }
                for rec in mo.completed {
                    self.record_completion(rec);
                }
                match mo.stop {
                    MacroStop::Idle => return,
                    MacroStop::Event => {
                        self.instances[i].busy = true;
                        self.events.schedule(mo.end, Event::StepDone(i));
                        return;
                    }
                    MacroStop::Boundary => {
                        now = mo.end;
                        self.maybe_snapshot(i);
                        continue;
                    }
                }
            }

            // Per-iteration path: bid-ask policies (per-step §4.4
            // hooks), an active snapshot mark, or --micro-step.
            let Some(end) = self.step_once(now, i) else {
                // Queued-but-unadmittable work (e.g. memory full); it
                // will be re-kicked when something frees.
                return;
            };
            let inline = !self.cfg.micro_step
                && self.events.peek_time().map_or(true, |t| end < t);
            if !inline {
                self.instances[i].busy = true;
                self.events.schedule(end, Event::StepDone(i));
                return;
            }
            // Inline iteration boundary: nothing else pops before
            // `end`, so handle the StepDone right here.
            now = end;
            self.maybe_snapshot(i);
            if bid_ask {
                self.cascade_post_step(now, i);
            }
        }
    }

    /// Run exactly one engine iteration on `i` at `now`, committing
    /// its boundary accounting — records (with their exact
    /// end-of-iteration timestamps), preemption/token counters, and
    /// the per-instance throughput EMA.  Returns the iteration's end
    /// time, or `None` if nothing ran (idle or memory-blocked; the
    /// zero-duration outcome is discarded, the historical gate).
    /// Every per-iteration driver path (`kick`'s per-step loop and
    /// [`Cluster::kick_scheduled`]) shares this helper so their
    /// accounting can never drift apart — drift here is exactly the
    /// macro-vs-micro divergence the equivalence suite pins.
    fn step_once(&mut self, now: Time, i: InstanceId) -> Option<Time> {
        let outcome = self.instances[i].engine.step(now);
        if outcome.duration <= 0.0 {
            return None;
        }
        self.stats.preemptions += outcome.preempted;
        let end = now + outcome.duration;
        if self.instances[i].engine.prefill_only() {
            self.stats.pd_local_completions += outcome.completed.len() as u64;
        }
        for rec in outcome.completed {
            self.record_completion(rec);
        }
        self.stats.counters.add(i, outcome.tokens_emitted);
        self.instances[i].tracker.observe_tokens(end, outcome.tokens_emitted);
        Some(end)
    }

    /// Commit one completed request: the `(input, final)` sample the
    /// periodic re-plan consumes, the report record, and — under
    /// non-oracle predictors — the misprediction count (true final
    /// exceeded the predicted one).  Both completion paths (the engine
    /// macro stretch and [`Cluster::step_once`]'s per-iteration loop)
    /// share this helper so their accounting can never drift apart.
    fn record_completion(&mut self, rec: RequestRecord) {
        self.observed.push((rec.input_len, rec.input_len + rec.output_len));
        // Completion ends the request's arena lifetime; take the cached
        // prediction on the way out.  The cache is bit-identical to
        // recomputing (the predictor is a pure seeded hash), so the
        // recompute fallback only covers requests that never passed
        // admission (e.g. directly injected in tests).
        let cached = self.arena.predicted(rec.id);
        self.arena.release(rec.id);
        if !self.predictor.is_oracle() {
            let req = Request {
                id: rec.id,
                arrival: rec.arrival,
                input_len: rec.input_len,
                output_len: rec.output_len,
            };
            let predicted = cached.unwrap_or_else(|| self.predictor.predicted_final(&req));
            if req.final_len() > predicted {
                self.stats.mispredictions += 1;
            }
        }
        self.records.push(rec);
    }

    /// Start (at most) one iteration on `i`, parking its completion in
    /// the event queue — the historical single-step kick.
    ///
    /// Handlers that do more work after kicking (`on_migration_done`
    /// kicks two instances and then serves starvation promises) MUST
    /// use this variant: advancing `i` inline there would run
    /// iterations *before* driver work that, under micro-stepping,
    /// happens first at the same instant — reordering records and
    /// tracker updates.  The parked `StepDone` resumes macro-stepping
    /// through [`Cluster::kick`] when it pops.
    pub(super) fn kick_scheduled(&mut self, now: Time, i: InstanceId) {
        if self.instances[i].busy || !self.instances[i].engine.has_work() {
            return;
        }
        let Some(end) = self.step_once(now, i) else { return };
        self.instances[i].busy = true;
        self.events.schedule(end, Event::StepDone(i));
    }

    fn on_step_done(&mut self, now: Time, i: InstanceId) {
        self.instances[i].busy = false;
        // A `StepDone` parked before the instance was spot-killed can
        // pop after it; the engine was evacuated, so there is nothing
        // to snapshot, offer, or kick.  Unreachable churn-free.
        if !self.cfg.churn.is_none() && !self.instances[i].serves() {
            return;
        }
        // Fig. 1 batch snapshots. The old loop materialised the batch
        // composition on *every* step just in case; the snapshot check
        // is O(1) now and rows are only built when a mark actually hits.
        self.maybe_snapshot(i);

        if self.cfg.policy.balance.uses_bid_ask() {
            self.cascade_post_step(now, i);
        }
        self.kick(now, i);
    }

    /// Index of the snapshot mark whose window current run progress is
    /// inside, if any — THE firing predicate of the Fig. 1 sampling.
    /// [`Cluster::maybe_snapshot`] and the macro stretch gate in
    /// [`Cluster::kick`] both consult this single definition, so the
    /// window width and progress formula cannot drift apart between
    /// them (drift would make macro-stepping skip boundaries where
    /// micro-stepping records snapshots).
    fn snapshot_mark_pos(&self) -> Option<usize> {
        if self.n_requests_total == 0 || self.snapshot_marks.is_empty() {
            return None;
        }
        let progress = self.records.len() as f64 / self.n_requests_total as f64;
        self.snapshot_marks.iter().position(|&m| (progress - m).abs() < 0.01)
    }

    /// Is run progress currently inside a snapshot-mark window?
    /// Progress only moves on completions, so between completions this
    /// is constant and the engine-side macro stretch can skip the
    /// per-iteration check entirely.
    fn snapshot_mark_near(&self) -> bool {
        self.snapshot_mark_pos().is_some()
    }

    /// Record a Fig. 1 batch-length snapshot when run progress crosses
    /// one of the marks.
    fn maybe_snapshot(&mut self, i: InstanceId) {
        let Some(pos) = self.snapshot_mark_pos() else {
            return;
        };
        let lens: Vec<Tokens> = self.instances[i]
            .engine
            .running()
            .iter()
            .map(|s| s.current_len())
            .collect();
        if lens.is_empty() {
            return;
        }
        let mark = self.snapshot_marks[pos];
        self.stats.batch_snapshots.push((mark, lens));
        // Cap snapshots per mark so memory stays bounded.
        let at_mark = self.stats.batch_snapshots.iter().filter(|(m, _)| *m == mark).count();
        if at_mark >= 64 {
            self.snapshot_marks.remove(pos);
        }
    }

    fn on_gossip(&mut self, now: Time) {
        // Each instance reports to same-stage peers and to the previous
        // stage (its upstream feeders) — §3.2 steps 1-2.  Assembling a
        // report is O(1) per instance (running aggregates).
        let reports: Vec<LoadReport> =
            self.instances.iter().map(|ins| ins.load_report(now)).collect();
        // Steady-state load sampling for the per-instance report
        // (read-only instrumentation; policy never consults it).
        for (i, r) in reports.iter().enumerate() {
            self.load_sample_sum[i] += r.token_load as f64;
        }
        self.load_samples += 1;
        for i in 0..self.instances.len() {
            // Departed and not-yet-joined slots neither send nor
            // receive gossip; stage lists already exclude them, so
            // this skip only saves their (empty) inbound recording.
            if !self.cfg.churn.is_none() && !self.instances[i].serves() {
                continue;
            }
            let s = self.stage_of[i];
            for &peer in &self.stages[s] {
                if peer != i {
                    self.instances[i].tracker.record_peer(reports[peer]);
                }
            }
            if s + 1 < self.stages.len() {
                for &succ in &self.stages[s + 1] {
                    self.instances[i].tracker.record_successor(reports[succ]);
                }
            }
        }
        self.events.schedule(now + self.cfg.gossip_interval, Event::Gossip);
    }

    fn on_refine(&mut self, now: Time) {
        self.stats.refinements += 1;
        let policy = self.cfg.policy.refine;
        for b in 0..self.refiners.len() {
            // Boundary b separates stage b from stage b+1. The local
            // side enters the split as a *per-instance average* (S4.3
            // refines an instance's own boundary against the successor
            // average), so a 15-instance stage does not numerically
            // swamp a 1-instance successor.
            let local_union: Vec<(Tokens, Tokens)> = self.stages[b]
                .iter()
                .flat_map(|&i| self.instances[i].engine.running().iter())
                .map(|s| (s.req.input_len, s.current_len()))
                .collect();
            let local =
                RangeRefiner::divide_set(local_union.clone(), self.stages[b].len().max(1));
            let successors: Vec<Vec<(Tokens, Tokens)>> = self.stages[b + 1]
                .iter()
                .map(|&i| {
                    self.instances[i]
                        .engine
                        .running()
                        .iter()
                        .map(|s| (s.req.input_len, s.current_len()))
                        .collect()
                })
                .collect();
            match policy {
                RefinePolicy::Adaptive => {
                    // Instance-count-weighted variant: stage unions on
                    // both sides, QoE per Eq. (1) with the even set
                    // division over each stage's member count.
                    let succ_union: Vec<(Tokens, Tokens)> =
                        successors.iter().flatten().copied().collect();
                    let k_local = self.stages[b].len();
                    let k_succ = self.stages[b + 1].len();
                    self.refiners[b].refine_weighted(local_union, succ_union, k_local, k_succ);
                }
                RefinePolicy::Quantity | RefinePolicy::Memory => {
                    let mut merged: Vec<(Tokens, Tokens)> = local
                        .iter()
                        .copied()
                        .chain(successors.iter().flatten().copied())
                        .collect();
                    if merged.len() >= 5 {
                        merged.sort_by_key(|&(_, l)| l);
                        let nb = if policy == RefinePolicy::Quantity {
                            naive::quantity_boundary(&merged)
                        } else {
                            naive::memory_boundary(&merged)
                        };
                        if let Some(nb) = nb {
                            self.refiners[b].boundary = nb.max(1);
                        }
                    }
                }
                RefinePolicy::Off => {}
            }
            // Keep boundaries monotone across stages (`self.ranges`
            // still holds the pre-refinement ranges here).
            let lo = self.ranges[b].0;
            if self.refiners[b].boundary <= lo {
                self.refiners[b].boundary = lo + 1;
            }
        }
        for b in 1..self.refiners.len() {
            if self.refiners[b].boundary <= self.refiners[b - 1].boundary {
                self.refiners[b].boundary = self.refiners[b - 1].boundary + 1;
            }
        }
        self.rebuild_ranges();
        self.events.schedule(now + self.cfg.refine_interval, Event::Refine);
    }

    /// Periodic full pipeline re-planning (§4.2): rebuild the length
    /// histogram from the last window's completed requests, re-run the
    /// DP, and remap instance membership.  Live sequences stay where
    /// they are; anything now out of range migrates through the normal
    /// handover path, so replanning never disrupts ongoing decoding.
    fn on_replan(&mut self, now: Time) {
        // Elastic fleets re-plan over live membership only — the churn
        // remap owns stage assignment there (the legacy contiguous
        // `0..n` rebuild below would resurrect departed instances).
        if !self.cfg.churn.is_none() {
            self.replan_membership(now);
            self.events.schedule(now + self.cfg.replan_interval, Event::Replan);
            return;
        }
        // Need a meaningful sample (low-traffic freeze, like §4.3).
        // `total()` counts every completion ever, exactly what the old
        // unbounded log's `len()` was; the ring retains the newest
        // `REPLAN_WINDOW` samples, newest first — the only ones the old
        // `.iter().rev().take(REPLAN_WINDOW)` read.
        if self.observed.total() >= 64 {
            let mut hist =
                LengthHistogram::new(LengthHistogram::exponential_bounds(self.cfg.max_len));
            for &(i, f) in self.observed.iter_rev() {
                hist.push(i, f);
            }
            // Include live sequences so long-runners are represented —
            // at the length the *predictor* expects them to reach (a
            // live sequence's true final is unknowable mid-decode;
            // under `oracle` this is its current length, the exact
            // legacy statistic).  Completed requests above enter at
            // their true lengths: post-hoc observation is legitimate
            // even in a real system.
            for ins in &self.instances {
                for sq in ins.engine.running() {
                    hist.push(
                        sq.req.input_len,
                        self.predictor.replan_live_len(&sq.req, sq.current_len()),
                    );
                }
            }
            // Partition over the (possibly heterogeneous) per-instance
            // capacities — uniform fleets take the identical legacy
            // DP path; TP-sharded fleets re-plan through the TP-aware
            // DP with the same KV/collective inputs as construction.
            let pipe = match &self.plan_insts {
                Some(insts) => self.planner.plan_dp_instances(&hist, insts),
                None => self.planner.plan_dp_weighted(&hist, &self.caps),
            };
            if pipe.stages.len() != self.stages.len()
                || pipe
                    .stages
                    .iter()
                    .zip(self.pipeline.stages.iter())
                    .any(|(a, b)| a.n_instances != b.n_instances)
            {
                // Remap membership contiguously (keeps the §5 placement
                // property) and rebuild refiners from the new plan.
                let mut stage_of = Vec::with_capacity(self.cfg.n_instances);
                let mut stages: Vec<Vec<InstanceId>> = Vec::new();
                for spec in pipe.stages.iter() {
                    let mut members = Vec::new();
                    for _ in 0..spec.n_instances {
                        members.push(stage_of.len());
                        stage_of.push(stages.len());
                    }
                    stages.push(members);
                }
                self.refiners = pipe
                    .boundaries()
                    .iter()
                    .map(|&b| RangeRefiner::new(self.qoe, b, RefineConfig::default()))
                    .collect();
                self.stage_of = stage_of;
                self.stats.stages = stages.clone();
                self.stages = stages;
                self.pipeline = pipe;
                self.rebuild_ranges();
                self.replans += 1;
            }
        }
        self.events.schedule(now + self.cfg.replan_interval, Event::Replan);
    }

    /// Llumnix-like periodic rebalancing: move one sequence from the
    /// most- to the least-memory-loaded instance when the gap is big.
    /// Length-agnostic — exactly the §2.4 criticism.
    fn on_baseline_rebalance(&mut self, now: Time) {
        let (mut hi_i, mut hi_v) = (0, f64::MIN);
        let (mut lo_i, mut lo_v) = (0, f64::MAX);
        // `admitting` is exactly `0..n` on a churn-free run, so this
        // iteration is the legacy whole-fleet scan bit for bit; under
        // churn it keeps the rebalancer off departed/absent slots
        // (whose empty engines would always win the `lo` side).
        for &i in &self.admitting {
            let d = self.instances[i].engine.memory_demand();
            if d > hi_v {
                hi_v = d;
                hi_i = i;
            }
            if d < lo_v {
                lo_v = d;
                lo_i = i;
            }
        }
        if hi_v - lo_v > 0.2 && hi_i != lo_i {
            debug_assert!(self.instances[lo_i].admits());
            if let Some((rid, len)) = self.instances[hi_i]
                .engine
                .running()
                .iter()
                .filter(|s| s.phase == Phase::Decoding && !self.migration.is_migrating(s.req.id))
                .max_by_key(|s| s.req.id)
                .map(|s| (s.req.id, s.current_len()))
            {
                let link = self.topology.link_between(hi_i, lo_i);
                let decode_rate = self.instances[hi_i].tracker.throughput()
                    / self.instances[hi_i].engine.n_running().max(1) as f64;
                let dest_free = self.instances[lo_i].engine.kv().can_allocate(len + 64);
                if let Some(t) = self
                    .migration
                    .try_start(now, rid, hi_i, lo_i, len, link, decode_rate, dest_free)
                {
                    self.in_flight.insert(rid);
                    self.events.schedule(
                        t.finish_at,
                        Event::MigrationDone { request: rid, from: hi_i, to: lo_i },
                    );
                }
            }
        }
        self.events.schedule(now + 0.25, Event::BaselineRebalance);
    }
}

/// Elastic-fleet handlers: joins, drains, spot kills, readmission, and
/// the SLO-feedback autoscaler.  Every method here is reachable only
/// when `cfg.churn` is non-empty (the events that invoke them are
/// never scheduled otherwise), so a churn-free run executes none of
/// this code.
impl Cluster {
    /// Recompute the cached admitting-id list after a membership
    /// transition.
    fn rebuild_admitting(&mut self) {
        self.admitting =
            (0..self.instances.len()).filter(|&i| self.instances[i].admits()).collect();
    }

    /// An `Absent` slot finished its weight load: go live and fold it
    /// into the stage layout.
    fn on_instance_join(&mut self, now: Time, i: InstanceId) {
        if self.instances[i].membership != Membership::Absent {
            return;
        }
        self.instances[i].membership = Membership::Live;
        self.booting.remove(&i);
        self.pending_joins = self.pending_joins.saturating_sub(1);
        self.stats.joins += 1;
        self.rebuild_admitting();
        self.replan_membership(now);
    }

    /// Spot preemption: the instance dies right now.
    fn on_instance_gone(&mut self, now: Time, i: InstanceId) {
        if !self.instances[i].serves() {
            return; // already gone (double spot / spot after drain-out)
        }
        self.stats.spot_kills += 1;
        self.kill_instance(now, i);
    }

    /// Hard-kill `i`: cancel its transfers, drop its protocol state,
    /// evacuate every resident sequence into the capped re-admission
    /// path, and expunge its gossip.  Shared by spot preemption and
    /// the drain-deadline forced fallback.
    fn kill_instance(&mut self, now: Time, i: InstanceId) {
        self.instances[i].membership = Membership::Dead;
        self.instances[i].drain_deadline = f64::INFINITY;
        self.instances[i].busy = false;
        self.rebuild_admitting();
        // Cancel in-flight transfers touching the dead instance
        // (deterministic ascending-request order).  Source-dead: the
        // sequence — still decoding on the source under live migration
        // — rides the evacuation below.  Dest-dead: it simply keeps
        // decoding on its source.
        for t in self.migration.transfers_touching(i) {
            self.migration.abort(t.request);
            self.in_flight.remove(&t.request);
            self.offers.remove(&t.request);
            self.retry_after.remove(&t.request);
        }
        // Negotiations the dead instance was driving or promised into.
        // A dropped promise whose receiver died would leave the (live)
        // sender's offer open forever — re-offers early-return on an
        // open book — so resolve those offers for renegotiation.
        self.offers.retain(|_, v| v.0 != i);
        self.promises.remove(&i);
        let mut orphaned: Vec<RequestId> = Vec::new();
        for list in self.promises.values_mut() {
            list.retain(|(p, recv)| {
                if *recv == i {
                    orphaned.push(p.request);
                    false
                } else {
                    true
                }
            });
        }
        for r in orphaned {
            self.offers.remove(&r);
            self.retry_after.insert(r, now + 0.25);
        }
        // Evacuate every resident sequence and re-admit it as a
        // re-prefill (prompt + generated prefix; decode picks up where
        // it left off, only the KV is recomputed).
        for seq in self.instances[i].engine.evacuate() {
            self.stats.preempted_requests += 1;
            self.stats.lost_tokens += seq.kv_len;
            self.arena.release(seq.req.id);
            self.retry_after.remove(&seq.req.id);
            let req = Request {
                id: seq.req.id,
                arrival: seq.req.arrival,
                input_len: seq.logical_len(),
                output_len: seq.remaining().max(1),
            };
            self.schedule_readmit(now, req);
        }
        // Its last gossip must not linger as a stale bid anywhere.
        for j in 0..self.instances.len() {
            if j != i {
                self.instances[j].tracker.forget_instance(i);
            }
        }
        self.replan_membership(now);
    }

    /// First firing: flip to `Draining` and leave the admitting set.
    /// Every firing (the recurring pump): requeue/offer residue and
    /// check the empty / deadline exit conditions.
    fn on_drain_start(&mut self, now: Time, i: InstanceId) {
        match self.instances[i].membership {
            Membership::Live => {
                let dur =
                    self.drain_spec.get(&i).copied().unwrap_or(DEFAULT_DRAIN_DEADLINE);
                self.instances[i].membership = Membership::Draining;
                self.instances[i].drain_deadline = now + dur;
                self.stats.drains_started += 1;
                self.rebuild_admitting();
                self.replan_membership(now);
            }
            Membership::Draining => {}
            Membership::Absent | Membership::Dead => return,
        }
        self.pump_drain(now, i);
    }

    fn pump_drain(&mut self, now: Time, i: InstanceId) {
        if !self.instances[i].engine.has_work()
            && self.migration.transfers_touching(i).is_empty()
        {
            // Fully evacuated: leave gracefully.
            self.instances[i].membership = Membership::Dead;
            self.instances[i].drain_deadline = f64::INFINITY;
            self.stats.drains_completed += 1;
            for j in 0..self.instances.len() {
                if j != i {
                    self.instances[j].tracker.forget_instance(i);
                }
            }
            return;
        }
        if now >= self.instances[i].drain_deadline {
            // Deadline passed with work still resident: forced kill,
            // recovery through the spot path.
            self.stats.drains_forced += 1;
            self.kill_instance(now, i);
            return;
        }
        if !self.admitting.is_empty() {
            // Queued requests hold no KV here — reroute them through
            // normal dispatch on the live fleet.
            let queued: Vec<RequestId> =
                self.instances[i].engine.queued().map(|s| s.req.id).collect();
            for rid in queued {
                if let Some(seq) = self.instances[i].engine.extract(rid) {
                    self.redispatch(now, seq);
                }
            }
            // Decoding sequences leave via the §4.4 bid-ask handover,
            // offered to the admitting members of their length's stage
            // (falling back to the whole live fleet when that stage is
            // momentarily empty).
            let running: Vec<(RequestId, Tokens)> = self.instances[i]
                .engine
                .running()
                .iter()
                .filter(|s| !self.migration.is_migrating(s.req.id))
                .map(|s| (s.req.id, s.current_len()))
                .collect();
            for (rid, len) in running {
                let s = super::router::stage_for_len(&self.ranges, len);
                let mut candidates: Vec<InstanceId> = self.stages[s]
                    .iter()
                    .copied()
                    .filter(|&c| c != i && self.instances[c].admits())
                    .collect();
                if candidates.is_empty() {
                    candidates = self.admitting.clone();
                }
                self.bid_ask_migrate(now, i, rid, len, &candidates);
            }
        }
        self.events.schedule(now + DRAIN_PUMP_INTERVAL, Event::DrainStart(i));
    }

    /// Re-inject a still-queued sequence (drain requeue) through
    /// normal dispatch; its arena entry survives the move.
    fn redispatch(&mut self, now: Time, seq: Sequence) {
        let req = seq.req;
        let target = self.router.route(
            &self.cfg.policy,
            &req,
            &self.stages,
            &self.ranges,
            &self.instances,
            &self.admitting,
            &self.migration,
            &self.predictor,
            &self.arena,
        );
        if self.instances[target].engine.can_ever_hold(self.predictor.admit_len(&req)) {
            let ok = self.instances[target].engine.inject(seq);
            debug_assert!(ok, "queued sequences always inject");
            self.kick(now, target);
        } else {
            // The routed instance can never hold it: back through the
            // capped re-admission path (converges to a counted
            // rejection instead of wedging the drain).
            self.arena.release(req.id);
            let req = Request {
                id: req.id,
                arrival: req.arrival,
                input_len: seq.logical_len(),
                output_len: seq.remaining().max(1),
            };
            self.schedule_readmit(now, req);
        }
    }

    /// Queue `req` for re-admission after an exponential backoff, or
    /// — past [`MAX_SPOT_RETRIES`] attempts — count it rejected.  The
    /// request holds no arena entry between preemption and
    /// re-admission.
    pub(super) fn schedule_readmit(&mut self, now: Time, req: Request) {
        let attempts = {
            let e = self.spot_attempts.entry(req.id).or_insert(0);
            *e += 1;
            *e
        };
        if attempts > MAX_SPOT_RETRIES {
            self.spot_attempts.remove(&req.id);
            self.stats.rejected += 1;
            return;
        }
        let delay = READMIT_BACKOFF_BASE * (1u64 << (attempts - 1)) as f64;
        self.events.schedule(now + delay, Event::Readmit(req));
    }

    /// A preempted request's backoff expired: try admission again.
    fn on_readmit(&mut self, now: Time, req: Request) {
        if self.admitting.is_empty() {
            // Still no admitting instance; burn an attempt and back
            // off again (converges to a counted rejection).
            self.schedule_readmit(now, req);
            return;
        }
        let before = self.stats.rejected;
        self.on_arrival(now, req);
        self.spot_attempts.remove(&req.id);
        if self.stats.rejected == before {
            self.stats.recovered += 1;
        }
    }

    /// Periodic SLO-feedback controller: scale out when windowed SLO
    /// attainment sags (or queues pile up), scale in when attainment
    /// is comfortable and queues are empty — within `min..=max`.
    fn on_autoscale_tick(&mut self, now: Time) {
        let Some(spec) = self.cfg.churn.autoscale else { return };
        self.stats.autoscale_ticks += 1;
        let slo = Slo { ttft: AUTOSCALE_SLO_TTFT, tpot: AUTOSCALE_SLO_TPOT };
        let window = &self.records[self.autoscale_watermark..];
        let attainment = if window.is_empty() {
            1.0
        } else {
            window.iter().filter(|r| r.ttft() <= slo.ttft && r.tpot() <= slo.tpot).count()
                as f64
                / window.len() as f64
        };
        let queued: usize = self
            .admitting
            .iter()
            .map(|&i| self.instances[i].engine.queued().count())
            .sum();
        let n_live = self.admitting.len() + self.pending_joins;
        let pressed = attainment < AUTOSCALE_ATTAIN_LOW
            || queued > AUTOSCALE_QUEUE_FACTOR * self.admitting.len().max(1);
        if pressed && n_live < spec.max {
            // Lowest-id absent slot boots (weight-load latency priced
            // from its model slice over the inter-node link).
            if let Some(slot) = (0..self.instances.len()).find(|&j| {
                self.instances[j].membership == Membership::Absent
                    && !self.booting.contains(&j)
            }) {
                self.booting.insert(slot);
                self.pending_joins += 1;
                self.stats.scale_outs += 1;
                self.events.schedule(now + self.boot_latency[slot], Event::InstanceJoin(slot));
            }
        } else if attainment >= AUTOSCALE_ATTAIN_HIGH
            && queued == 0
            && self.pending_joins == 0
            && n_live > spec.min
            && self.admitting.len() > 1
        {
            // Highest-id live instance drains away gracefully.
            if let Some(&victim) = self.admitting.last() {
                self.stats.scale_ins += 1;
                self.drain_spec.insert(victim, DEFAULT_DRAIN_DEADLINE);
                self.events.schedule(now, Event::DrainStart(victim));
            }
        }
        self.autoscale_watermark = self.records.len();
        self.events.schedule(now + spec.period, Event::AutoscaleTick);
    }

    /// Rebuild stage membership over the live fleet after a
    /// join/leave.  Planned layouts re-run the §4.2 DP over the
    /// admitting instances' capacities (once enough completions
    /// exist); forced/Flat/Chain layouts — and the early-run planned
    /// case — prune departed members in place and hand joiners to the
    /// thinnest stage.
    fn replan_membership(&mut self, _now: Time) {
        if self.admitting.is_empty() {
            // Admission-less interregnum: keep the old shape; arrivals
            // park on the backoff path until a join lands.
            return;
        }
        let planned = self.cfg.forced_pipeline.is_none()
            && self.cfg.policy.layout == Layout::Planned;
        if planned && self.observed.total() >= 64 {
            self.replan_planned_membership();
            return;
        }
        // Structural fallback: keep the stage count, prune departures,
        // append joiners to the thinnest stage (lowest index on ties).
        {
            let instances = &self.instances;
            for members in self.stages.iter_mut() {
                members.retain(|&m| instances[m].admits());
            }
        }
        let joiners: Vec<InstanceId> = self
            .admitting
            .iter()
            .copied()
            .filter(|&i| !self.stages[self.stage_of[i]].contains(&i))
            .collect();
        for i in joiners {
            let s = (0..self.stages.len())
                .min_by_key(|&s| (self.stages[s].len(), s))
                .expect("pipeline has stages");
            self.stages[s].push(i);
            self.stages[s].sort_unstable();
            self.stage_of[i] = s;
        }
        // No stage may sit empty while spare members exist elsewhere
        // (routing indexes stage members): steal the highest id from
        // the largest stage, deterministically.
        loop {
            let Some(empty) = (0..self.stages.len()).find(|&s| self.stages[s].is_empty())
            else {
                break;
            };
            let Some(donor) = (0..self.stages.len())
                .filter(|&s| self.stages[s].len() > 1)
                .max_by_key(|&s| (self.stages[s].len(), s))
            else {
                break;
            };
            let m = self.stages[donor].pop().expect("donor has members");
            self.stages[empty].push(m);
            self.stage_of[m] = empty;
        }
        self.stats.stages = self.stages.clone();
    }

    /// The §4.2 DP over live membership: histogram from recent
    /// completions + live sequences, capacities subset to admitting
    /// ids, contiguous assignment in ascending live order.
    fn replan_planned_membership(&mut self) {
        let mut hist =
            LengthHistogram::new(LengthHistogram::exponential_bounds(self.cfg.max_len));
        for &(i, f) in self.observed.iter_rev() {
            hist.push(i, f);
        }
        for ins in &self.instances {
            if !ins.serves() {
                continue;
            }
            for sq in ins.engine.running() {
                hist.push(
                    sq.req.input_len,
                    self.predictor.replan_live_len(&sq.req, sq.current_len()),
                );
            }
        }
        let live = self.admitting.clone();
        let pipe = match &self.plan_insts {
            Some(insts) => {
                let sub: Vec<PlanInstance> = live.iter().map(|&i| insts[i]).collect();
                self.planner.plan_dp_instances(&hist, &sub)
            }
            None => {
                let sub: Vec<f64> = live.iter().map(|&i| self.caps[i]).collect();
                self.planner.plan_dp_weighted(&hist, &sub)
            }
        };
        let mut stages: Vec<Vec<InstanceId>> = Vec::new();
        let mut k = 0usize;
        for spec in pipe.stages.iter() {
            stages.push(live[k..k + spec.n_instances].to_vec());
            k += spec.n_instances;
        }
        debug_assert_eq!(k, live.len(), "plan must place every live instance");
        for (s, members) in stages.iter().enumerate() {
            for &m in members {
                self.stage_of[m] = s;
            }
        }
        self.refiners = pipe
            .boundaries()
            .iter()
            .map(|&b| RangeRefiner::new(self.qoe, b, RefineConfig::default()))
            .collect();
        self.stats.stages = stages.clone();
        self.stages = stages;
        self.pipeline = pipe;
        self.rebuild_ranges();
        self.replans += 1;
    }
}
