//! Per-instance bookkeeping — the "state" layer of the cluster split.
//!
//! [`InstanceState`] bundles everything the simulator tracks per
//! engine instance: the engine itself, its token-level load tracker,
//! the §4.4 bid-ask state machine, the busy flag of the event loop,
//! the intra-stage offer cooldown, and — since fleets may be
//! heterogeneous — the instance's GPU tag and its *relative capacity*
//! (normalized to the fleet maximum; exactly 1.0 on homogeneous
//! fleets).  All load-shaped queries the coordination protocol makes
//! against an instance (token load, memory demand, gossip report)
//! resolve to running aggregates maintained by the engine/tracker, so
//! touching an instance on the hot path is O(1) instead of an
//! O(batch) rescan of its sequences.

use crate::cluster::elastic::Membership;
use crate::coordinator::balance::BidAskScheduler;
use crate::coordinator::loadtracker::LoadReport;
use crate::coordinator::LoadTracker;
use crate::engine::Engine;
use crate::{InstanceId, Time};

use super::ScaledBackend;

/// Everything the cluster tracks for one engine instance.
#[derive(Debug, Clone)]
pub struct InstanceState {
    pub id: InstanceId,
    pub engine: Engine<ScaledBackend>,
    pub tracker: LoadTracker,
    /// §4.4 sender book + receiver priority queue.
    pub scheduler: BidAskScheduler,
    /// GPU profile name backing this instance (report tag).
    pub gpu: &'static str,
    /// Relative serving capacity in (0, 1], normalized to the fleet
    /// maximum.  Every cross-instance load comparison divides by this,
    /// so a homogeneous fleet (capacity exactly 1.0) reduces
    /// bit-identically to raw token-load comparisons.
    pub capacity: f64,
    /// True while a `StepDone` event for this instance is in flight.
    /// Under macro-stepping this is rarer than "an iteration is
    /// running": iterations whose end precedes every queued event are
    /// advanced inline by the driver without ever setting it — only an
    /// iteration that overruns the next interesting instant parks its
    /// completion in the queue.
    pub busy: bool,
    /// Last intra-stage offer time (rebalance hysteresis).
    pub last_offer: Time,
    /// Elastic-fleet lifecycle.  `Live` for every instance of a
    /// churn-free run (the legacy fixed fleet); pre-allocated join /
    /// autoscale slots start `Absent`.
    pub membership: Membership,
    /// Absolute forced-kill instant of an in-progress drain
    /// (`INFINITY` when not draining).
    pub drain_deadline: Time,
}

impl InstanceState {
    pub fn new(
        id: InstanceId,
        engine: Engine<ScaledBackend>,
        tracker: LoadTracker,
        scheduler: BidAskScheduler,
        gpu: &'static str,
        capacity: f64,
    ) -> Self {
        Self {
            id,
            engine,
            tracker,
            scheduler,
            gpu,
            capacity,
            busy: false,
            last_offer: f64::NEG_INFINITY,
            membership: Membership::Live,
            drain_deadline: f64::INFINITY,
        }
    }

    /// True when this instance accepts *new* admissions (router
    /// dispatch, migration destinations).
    pub fn admits(&self) -> bool {
        self.membership == Membership::Live
    }

    /// True when this instance still executes work it already holds
    /// (live or draining) — the set gossip and bid-ask protocols run
    /// over.
    pub fn serves(&self) -> bool {
        matches!(self.membership, Membership::Live | Membership::Draining)
    }

    /// This instance's capacity-normalized token load — the value all
    /// cross-instance comparisons use.
    pub fn norm_load(&self) -> f64 {
        self.engine.token_load() as f64 / self.capacity
    }

    /// The gossip report this instance broadcasts (§3.2). All inputs
    /// are running aggregates — assembling a report is O(1).
    pub fn load_report(&self, now: Time) -> LoadReport {
        let token_load = self.engine.token_load();
        LoadReport {
            instance: self.id,
            at: now,
            token_load,
            norm_load: token_load as f64 / self.capacity,
            n_seqs: self.engine.n_running(),
            memory_demand: self.engine.memory_demand(),
            throughput: self.tracker.throughput(),
        }
    }
}
