//! Per-instance bookkeeping — the "state" layer of the cluster split.
//!
//! [`InstanceState`] bundles everything the simulator tracks per
//! engine instance: the engine itself, its token-level load tracker,
//! the §4.4 bid-ask state machine, the busy flag of the event loop,
//! and the intra-stage offer cooldown.  All load-shaped queries the
//! coordination protocol makes against an instance (token load, memory
//! demand, gossip report) resolve to running aggregates maintained by
//! the engine/tracker, so touching an instance on the hot path is O(1)
//! instead of an O(batch) rescan of its sequences.

use crate::coordinator::balance::BidAskScheduler;
use crate::coordinator::loadtracker::LoadReport;
use crate::coordinator::LoadTracker;
use crate::engine::Engine;
use crate::{InstanceId, Time};

use super::ScaledBackend;

/// Everything the cluster tracks for one engine instance.
#[derive(Debug, Clone)]
pub struct InstanceState {
    pub id: InstanceId,
    pub engine: Engine<ScaledBackend>,
    pub tracker: LoadTracker,
    /// §4.4 sender book + receiver priority queue.
    pub scheduler: BidAskScheduler,
    /// True while a StepDone event for this instance is in flight.
    pub busy: bool,
    /// Last intra-stage offer time (rebalance hysteresis).
    pub last_offer: Time,
}

impl InstanceState {
    pub fn new(
        id: InstanceId,
        engine: Engine<ScaledBackend>,
        tracker: LoadTracker,
        scheduler: BidAskScheduler,
    ) -> Self {
        Self { id, engine, tracker, scheduler, busy: false, last_offer: f64::NEG_INFINITY }
    }

    /// The gossip report this instance broadcasts (§3.2). All inputs
    /// are running aggregates — assembling a report is O(1).
    pub fn load_report(&self, now: Time) -> LoadReport {
        LoadReport {
            instance: self.id,
            at: now,
            token_load: self.engine.token_load(),
            n_seqs: self.engine.n_running(),
            memory_demand: self.engine.memory_demand(),
            throughput: self.tracker.throughput(),
        }
    }
}
