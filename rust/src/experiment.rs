//! The experiment builder — the single construction path for every
//! simulated run.
//!
//! Before this module, each consumer (the `sim` CLI, the figure
//! benches, the examples, the integration tests) hand-assembled its
//! own `(ClusterConfig, Vec<Request>)` pair, each with its own name
//! resolution, defaults, and engine-speed conventions.  The builder
//! unifies them:
//!
//! ```no_run
//! use cascade_infer::experiment::Experiment;
//! use cascade_infer::workload::WorkloadSpec;
//!
//! let (report, stats) = Experiment::builder()
//!     .model("Llama-3.2-3B")
//!     .gpu("H20")
//!     .instances(8)
//!     .scheduler("cascade")           // registry name or custom:...
//!     .workload(WorkloadSpec::HeavyTail)
//!     .rate(16.0)
//!     .requests(500)
//!     .seed(42)
//!     .build()
//!     .unwrap()
//!     .run();
//! println!("mean TTFT {:.4}s, {} migrations", report.mean_ttft(), stats.migrations);
//! ```
//!
//! Everything is resolved at [`ExperimentBuilder::build`]: model/GPU
//! names become profiles (unknown names are hard errors listing the
//! valid choices — never a silent fallback), scheduler strings go
//! through the [`PolicySpec`] registry (so `custom:` axis combinations
//! work anywhere a name does), and the [`WorkloadSpec`] materialises
//! the request trace.  The resulting [`Experiment`] is a plain
//! `(ClusterConfig, Vec<Request>)` bundle; [`Experiment::run`] feeds
//! it to [`crate::cluster::run_experiment`].
//!
//! Construction from a parsed config file goes through
//! [`Experiment::from_config`]; CLI flags then override individual
//! fields before `build()`.

use crate::cluster::{run_experiment, Cluster, ClusterConfig, PolicySpec};
use crate::config::ExperimentConfig;
use crate::coordinator::plan::Pipeline;
use crate::fleet::FleetSpec;
use crate::gpu::{GpuProfile, Topology};
use crate::metrics::Report;
use crate::models::{self, ModelProfile};
use crate::predict::PredictorSpec;
use crate::workload::{count_csv_rows, Request, WorkloadSpec};
use crate::{Time, Tokens};

use std::fmt;

/// Error building an experiment.  Every variant carries a
/// human-readable message that lists the valid choices.
#[derive(Debug, Clone, PartialEq)]
pub enum ExperimentError {
    UnknownModel(String),
    UnknownGpu(String),
    Policy(String),
    Workload(String),
    /// Malformed `--fleet` spec (bad grammar or unknown GPU).
    Fleet(String),
    Invalid(String),
}

impl fmt::Display for ExperimentError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExperimentError::UnknownModel(m) => write!(f, "{m}"),
            ExperimentError::UnknownGpu(m) => write!(f, "{m}"),
            ExperimentError::Policy(m) => write!(f, "{m}"),
            ExperimentError::Workload(m) => write!(f, "{m}"),
            ExperimentError::Fleet(m) => write!(f, "{m}"),
            ExperimentError::Invalid(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ExperimentError {}

/// Resolve a model name, with an error listing the zoo (shared by the
/// builder and the `plan`/`fit` subcommands so the message never
/// drifts between the two).
pub fn resolve_model(name: &str) -> Result<ModelProfile, ExperimentError> {
    models::by_name(name).ok_or_else(|| {
        let names: Vec<&str> = models::paper_zoo().iter().map(|m| m.name).collect();
        ExperimentError::UnknownModel(format!(
            "unknown model `{name}`; valid: {} (or Llama-70B-TP2/TP4)",
            names.join(", ")
        ))
    })
}

/// Resolve a GPU name, with an error listing the profiles.
pub fn resolve_gpu(name: &str) -> Result<GpuProfile, ExperimentError> {
    GpuProfile::by_name(name).ok_or_else(|| {
        ExperimentError::UnknownGpu(format!(
            "unknown gpu `{name}`; valid: {}",
            GpuProfile::NAMES.join("|")
        ))
    })
}

/// A fully-resolved experiment: cluster configuration + request trace.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub cfg: ClusterConfig,
    pub requests: Vec<Request>,
}

impl Experiment {
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }

    /// Seed a builder from a parsed `[experiment]` config section.
    /// Individual setters (CLI flags) can still override before
    /// `build()`.
    pub fn from_config(cfg: &ExperimentConfig) -> ExperimentBuilder {
        let mut b = Experiment::builder()
            .model(&cfg.model)
            .gpu(&cfg.gpu)
            .instances(cfg.n_instances)
            .rate(cfg.rate)
            .requests(cfg.n_requests)
            .seed(cfg.seed)
            .scheduler(&cfg.scheduler)
            .workload_name(&cfg.workload);
        if let Some(f) = &cfg.fleet {
            b = b.fleet(f);
        }
        if let Some(p) = &cfg.predictor {
            b = b.predictor(p);
        }
        if let Some(l) = &cfg.layout {
            b = b.layout(l);
        }
        if let Some(c) = &cfg.churn {
            b = b.churn(c);
        }
        b
    }

    /// Run the experiment end to end.
    pub fn run(self) -> (Report, crate::cluster::RunStats) {
        run_experiment(self.cfg, &self.requests)
    }
}

/// Builder for [`Experiment`].  All fields optional; defaults mirror
/// the historical `sim` subcommand (Llama-3.2-3B on H20, 16 instances,
/// 8 req/s, 2000 requests, seed 42, ShareGPT workload, CascadeInfer).
#[derive(Debug, Clone)]
pub struct ExperimentBuilder {
    model_name: String,
    model_profile: Option<ModelProfile>,
    gpu_name: String,
    gpu_profile: Option<GpuProfile>,
    instances: usize,
    scheduler_name: String,
    policy: Option<PolicySpec>,
    predictor_name: Option<String>,
    layout_name: Option<String>,
    rate: f64,
    requests: usize,
    seed: u64,
    workload_name: Option<String>,
    workload: Option<WorkloadSpec>,
    trace: Option<Vec<Request>>,
    fleet_name: Option<String>,
    fleet_spec: Option<FleetSpec>,
    topology: Option<Topology>,
    engine_speed: Option<f64>,
    kv_capacity: Option<Tokens>,
    plan_sample: Option<usize>,
    refine_interval: Option<Time>,
    replan_interval: Option<Time>,
    forced_pipeline: Option<Pipeline>,
    micro_step: bool,
    churn_name: Option<String>,
}

impl Default for ExperimentBuilder {
    fn default() -> Self {
        Self {
            model_name: "Llama-3.2-3B".into(),
            model_profile: None,
            gpu_name: "H20".into(),
            gpu_profile: None,
            instances: 16,
            scheduler_name: "cascade".into(),
            policy: None,
            predictor_name: None,
            layout_name: None,
            rate: 8.0,
            requests: 2000,
            seed: 42,
            workload_name: None,
            workload: None,
            trace: None,
            fleet_name: None,
            fleet_spec: None,
            topology: None,
            engine_speed: None,
            kv_capacity: None,
            plan_sample: None,
            refine_interval: None,
            replan_interval: None,
            forced_pipeline: None,
            micro_step: false,
            churn_name: None,
        }
    }
}

impl ExperimentBuilder {
    /// Model by zoo name (resolved at `build`).
    pub fn model(mut self, name: &str) -> Self {
        self.model_name = name.to_string();
        self.model_profile = None;
        self
    }

    /// Model by explicit profile (skips name resolution).
    pub fn model_profile(mut self, m: ModelProfile) -> Self {
        self.model_profile = Some(m);
        self
    }

    /// GPU by name (`H20`/`L40`/`H100`, resolved at `build`).
    pub fn gpu(mut self, name: &str) -> Self {
        self.gpu_name = name.to_string();
        self.gpu_profile = None;
        self
    }

    /// GPU by explicit profile.
    pub fn gpu_profile(mut self, g: GpuProfile) -> Self {
        self.gpu_profile = Some(g);
        self
    }

    pub fn instances(mut self, n: usize) -> Self {
        self.instances = n;
        self
    }

    /// Scheduler by registry name or `custom:` axis string (resolved
    /// at `build`).
    pub fn scheduler(mut self, name: &str) -> Self {
        self.scheduler_name = name.to_string();
        self.policy = None;
        self
    }

    /// Scheduler by explicit spec.
    pub fn policy(mut self, spec: PolicySpec) -> Self {
        self.policy = Some(spec);
        self
    }

    /// Length predictor (`oracle`, `noisy:CV`, `bucket:ACC`,
    /// `ltr:PACC` — see [`crate::predict`]); overrides whatever the
    /// scheduler spec carries.  Resolved at `build`.
    pub fn predictor(mut self, name: &str) -> Self {
        self.predictor_name = Some(name.to_string());
        self
    }

    /// Stage layout (`planned`, `chain`, `flat`, or
    /// `pd[:P/D[:BOUNDARY[:WINDOW_US]]]` — see
    /// [`crate::cluster::pd::PdSpec`]); overrides whatever the
    /// scheduler spec carries.  Resolved at `build`.
    pub fn layout(mut self, name: &str) -> Self {
        self.layout_name = Some(name.to_string());
        self
    }

    pub fn rate(mut self, r: f64) -> Self {
        self.rate = r;
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Workload by spec.
    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = Some(w);
        self.workload_name = None;
        self
    }

    /// Workload by CLI/config name (`sharegpt`, `heavytail`,
    /// `uniformshort`, `mix`, `bursty`, `trace:FILE`).
    pub fn workload_name(mut self, name: &str) -> Self {
        self.workload_name = Some(name.to_string());
        self.workload = None;
        self
    }

    /// Explicit request trace (bypasses workload generation — used by
    /// tests and benches that share one trace across systems).
    pub fn trace(mut self, reqs: Vec<Request>) -> Self {
        self.trace = Some(reqs);
        self
    }

    /// Heterogeneous fleet by CLI string
    /// (`h20:6,h100:2[,speed=F][,tp=N]`, parsed at `build`).
    /// Overrides `instances` and `gpu`: the instance count is the
    /// fleet size, and each instance carries its own GPU profile,
    /// engine speed, and tensor-parallel degree.
    pub fn fleet(mut self, spec: &str) -> Self {
        self.fleet_name = Some(spec.to_string());
        self.fleet_spec = None;
        self
    }

    /// Heterogeneous fleet by explicit spec (skips parsing).
    pub fn fleet_spec(mut self, f: FleetSpec) -> Self {
        self.fleet_spec = Some(f);
        self.fleet_name = None;
        self
    }

    /// Physical node topology (default: 8-GPU NVLink nodes, sequential
    /// fill — the paper's H20 testbed shape).  Drives the migration
    /// cost model's link bandwidth.
    pub fn topology(mut self, t: Topology) -> Self {
        self.topology = Some(t);
        self
    }

    /// Override the policy's engine speed (e.g. benches modelling a
    /// faster runtime).
    pub fn engine_speed(mut self, s: f64) -> Self {
        self.engine_speed = Some(s);
        self
    }

    /// Explicit per-instance KV capacity in tokens (default: derived
    /// from the GPU memory budget).
    pub fn kv_capacity(mut self, tokens: Tokens) -> Self {
        self.kv_capacity = Some(tokens);
        self
    }

    /// How many head-of-trace requests feed the offline planner.
    pub fn plan_sample(mut self, n: usize) -> Self {
        self.plan_sample = Some(n);
        self
    }

    /// Boundary-refinement interval in seconds (0 disables).
    pub fn refine_interval(mut self, t: Time) -> Self {
        self.refine_interval = Some(t);
        self
    }

    /// Full re-planning interval in seconds (0 disables).
    pub fn replan_interval(mut self, t: Time) -> Self {
        self.replan_interval = Some(t);
        self
    }

    /// Bypass planning with an explicit layout (ablation experiments).
    pub fn forced_pipeline(mut self, p: Pipeline) -> Self {
        self.forced_pipeline = Some(p);
        self
    }

    /// Drive every engine iteration through its own queue event (the
    /// pre-macro-step debug path; bit-identical reports, much slower).
    /// CLI: `sim --micro-step`.
    pub fn micro_step(mut self, on: bool) -> Self {
        self.micro_step = on;
        self
    }

    /// Fault-injection / elasticity spec by CLI string
    /// (`spot:T@I`, `drain:T@I[:DEADLINE]`, `join:T[@GPU]`,
    /// `auto:PERIOD:MIN..MAX`, comma-separated; `none` disables —
    /// see [`crate::cluster::ChurnSpec::parse`]).  Resolved at `build`.
    pub fn churn(mut self, spec: &str) -> Self {
        self.churn_name = Some(spec.to_string());
        self
    }

    /// Resolve every name, materialise the trace, and assemble the
    /// cluster configuration.
    pub fn build(self) -> Result<Experiment, ExperimentError> {
        let r = self.resolve()?;
        let requests = match r.workload {
            ResolvedWorkload::Trace(t) => t,
            ResolvedWorkload::Spec(spec) => {
                spec.generate(r.rate, r.n_requests, r.seed).map_err(|e| {
                    ExperimentError::Workload(format!("workload generation failed: {e}"))
                })?
            }
        };
        if requests.is_empty() {
            return Err(ExperimentError::Invalid("experiment has zero requests".into()));
        }
        Ok(Experiment { cfg: r.cfg, requests })
    }

    /// Resolve every name but keep the trace *lazy*: the run pulls
    /// arrivals from a fresh [`crate::workload::WorkloadStream`], so
    /// resident memory is O(instances + in-flight) instead of
    /// O(requests).  The offline planner still sees the same head
    /// prefix the materialized path would slice, so reports are
    /// bit-identical to [`Experiment::run`] over the same spec.
    ///
    /// Explicit `.trace(..)` builders are already materialized and are
    /// rejected here; CSV replays stream straight off disk (their
    /// request total comes from a counting pre-pass).
    pub fn build_streaming(self) -> Result<StreamingExperiment, ExperimentError> {
        let r = self.resolve()?;
        let spec = match r.workload {
            ResolvedWorkload::Spec(s) => s,
            ResolvedWorkload::Trace(_) => {
                return Err(ExperimentError::Invalid(
                    "an explicit .trace(..) is already materialized; use build()".into(),
                ))
            }
        };
        let total = match &spec {
            WorkloadSpec::CsvTrace(path) => count_csv_rows(path).map_err(|e| {
                ExperimentError::Workload(format!("cannot count rows of trace `{path}`: {e}"))
            })?,
            _ => r.n_requests,
        };
        if total == 0 {
            return Err(ExperimentError::Invalid("experiment has zero requests".into()));
        }
        // Plan prefix: exactly the slice the materialized path hands
        // the planner (`&requests[..min(plan_sample, len)]`), pulled
        // from a fresh stream — streams and materialized traces are
        // identical by construction, so planning is bit-identical too.
        let k = total.min(r.cfg.plan_sample);
        let mut plan_prefix = Vec::with_capacity(k);
        let head = spec.stream(r.rate, r.n_requests, r.seed).map_err(|e| {
            ExperimentError::Workload(format!("cannot open workload stream: {e}"))
        })?;
        for item in head.take(k) {
            plan_prefix.push(item.map_err(|e| {
                ExperimentError::Workload(format!("workload generation failed: {e}"))
            })?);
        }
        Ok(StreamingExperiment {
            cfg: r.cfg,
            spec,
            rate: r.rate,
            n_requests: r.n_requests,
            seed: r.seed,
            total,
            plan_prefix,
        })
    }

    /// Shared resolution behind [`build`](Self::build) and
    /// [`build_streaming`](Self::build_streaming): every name becomes a
    /// profile/spec and the cluster config is assembled; only the
    /// trace's materialisation strategy differs between the callers.
    fn resolve(self) -> Result<ResolvedExperiment, ExperimentError> {
        // The fleet axis, when present, defines the instance count and
        // per-instance GPUs; otherwise `instances` copies of `gpu`.
        let fleet_from_name = self.fleet_spec.is_none() && self.fleet_name.is_some();
        let fleet = match (self.fleet_spec, &self.fleet_name) {
            (Some(f), _) => Some(f),
            (None, Some(name)) => {
                Some(FleetSpec::parse(name).map_err(ExperimentError::Fleet)?)
            }
            (None, None) => None,
        };
        let n_instances = fleet.as_ref().map(FleetSpec::len).unwrap_or(self.instances);
        if n_instances == 0 {
            return Err(ExperimentError::Invalid("instances must be >= 1".into()));
        }
        let model = match self.model_profile {
            Some(m) => m,
            None => resolve_model(&self.model_name)?,
        };
        let gpu = match self.gpu_profile {
            Some(g) => g,
            None => resolve_gpu(&self.gpu_name)?,
        };
        let mut policy = match self.policy {
            Some(p) => p,
            None => PolicySpec::resolve(&self.scheduler_name)
                .map_err(|e| ExperimentError::Policy(e.to_string()))?,
        };
        if let Some(p) = &self.predictor_name {
            policy.predictor = PredictorSpec::parse(p).map_err(ExperimentError::Policy)?;
        }
        if let Some(l) = &self.layout_name {
            policy.layout = crate::cluster::parse_layout(l).map_err(ExperimentError::Policy)?;
        }
        let workload = match self.trace {
            Some(t) => ResolvedWorkload::Trace(t),
            None => {
                let spec = match (&self.workload, &self.workload_name) {
                    (Some(w), _) => w.clone(),
                    (None, Some(name)) => {
                        WorkloadSpec::parse(name).map_err(ExperimentError::Workload)?
                    }
                    (None, None) => WorkloadSpec::default(),
                };
                // CSV traces carry their own arrivals; everything else
                // draws Poisson gaps and needs a positive rate (a
                // non-positive rate would otherwise panic deep inside
                // the generator instead of surfacing as a CLI error).
                if !matches!(spec, WorkloadSpec::CsvTrace(_))
                    && (self.rate.is_nan() || self.rate <= 0.0)
                {
                    return Err(ExperimentError::Invalid(format!(
                        "rate must be > 0 (got {})",
                        self.rate
                    )));
                }
                ResolvedWorkload::Spec(spec)
            }
        };

        let mut cfg = ClusterConfig::new(gpu, model, n_instances, policy);
        cfg.seed = self.seed;
        if let Some(s) = self.engine_speed {
            cfg.engine_speed = s;
        }
        if let Some(kv) = self.kv_capacity {
            cfg.engine.kv_capacity_tokens = Some(kv);
        }
        if let Some(n) = self.plan_sample {
            cfg.plan_sample = n;
        }
        if let Some(t) = self.refine_interval {
            cfg.refine_interval = t;
        }
        if let Some(t) = self.replan_interval {
            cfg.replan_interval = t;
        }
        if let Some(p) = self.forced_pipeline {
            cfg.forced_pipeline = Some(p);
        }
        cfg.micro_step = self.micro_step;
        if let Some(c) = &self.churn_name {
            cfg.churn = crate::cluster::ChurnSpec::parse(c)
                .map_err(|e| ExperimentError::Invalid(format!("bad --churn spec: {e}")))?;
        }
        if let Some(mut f) = fleet {
            if fleet_from_name {
                // A parsed fleet string cannot express engine knobs:
                // builder-level engine settings (KV capacity etc.)
                // apply fleet-wide.  A `None` KV capacity still
                // derives from each instance's own GPU in the cluster.
                for spec in &mut f.instances {
                    spec.engine = cfg.engine;
                }
            } else if let Some(kv) = self.kv_capacity {
                // An explicit FleetSpec keeps its per-instance engine
                // configs; only the builder's explicit KV override is
                // applied on top.
                for spec in &mut f.instances {
                    spec.engine.kv_capacity_tokens = Some(kv);
                }
            }
            cfg.gpu = f.reference().gpu;
            cfg.fleet = Some(f);
        }
        if let Some(t) = self.topology {
            cfg.topology = Some(t);
        }
        Ok(ResolvedExperiment {
            cfg,
            workload,
            rate: self.rate,
            n_requests: self.requests,
            seed: self.seed,
        })
    }
}

/// Output of [`ExperimentBuilder::resolve`]: assembled config plus the
/// workload in whichever form the builder was given it.
struct ResolvedExperiment {
    cfg: ClusterConfig,
    workload: ResolvedWorkload,
    rate: f64,
    n_requests: usize,
    seed: u64,
}

/// The workload half of a resolved builder: an explicit, already
/// materialized trace, or a spec a streaming run can re-open lazily.
enum ResolvedWorkload {
    Trace(Vec<Request>),
    Spec(WorkloadSpec),
}

/// A fully-resolved experiment whose trace is never materialized.
///
/// Built by [`ExperimentBuilder::build_streaming`].  Holds the
/// cluster configuration, the workload spec (re-opened as a fresh
/// [`crate::workload::WorkloadStream`] at [`run`](Self::run) time), and
/// the bounded plan prefix — never the full request vector, so a
/// billion-request replay is O(instances + in-flight) resident.
#[derive(Debug, Clone)]
pub struct StreamingExperiment {
    pub cfg: ClusterConfig,
    spec: WorkloadSpec,
    rate: f64,
    n_requests: usize,
    seed: u64,
    /// Arrivals the stream will deliver (generator `n`, or the CSV
    /// trace's counted row total).
    total: usize,
    /// Head of the stream fed to the offline planner — identical to
    /// the slice the materialized path hands [`Cluster::new`].
    plan_prefix: Vec<Request>,
}

impl StreamingExperiment {
    /// Total number of requests the run will deliver.
    pub fn total_requests(&self) -> usize {
        self.total
    }

    /// Run end to end, pulling arrivals lazily.  Bit-identical to the
    /// materialized [`Experiment::run`] over the same spec — see the
    /// equivalence argument on [`Cluster::run_stream`].
    pub fn run(self) -> Result<(Report, crate::cluster::RunStats), ExperimentError> {
        let stream = self.spec.stream(self.rate, self.n_requests, self.seed).map_err(|e| {
            ExperimentError::Workload(format!("cannot open workload stream: {e}"))
        })?;
        let cluster = Cluster::new(self.cfg, &self.plan_prefix);
        // A CSV replay can fail mid-stream (truncated file, bad row).
        // Latch the error and end the stream: the driver winds down
        // in-flight work normally and the error surfaces afterwards,
        // instead of panicking inside the event loop.
        let io_err = std::cell::RefCell::new(None);
        let arrivals = stream.map_while(|item| match item {
            Ok(r) => Some(r),
            Err(e) => {
                *io_err.borrow_mut() = Some(e);
                None
            }
        });
        let out = cluster.run_stream(arrivals, self.total);
        if let Some(e) = io_err.into_inner() {
            return Err(ExperimentError::Workload(format!("trace replay failed: {e}")));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BalancePolicy, DispatchPolicy, Layout, RefinePolicy};

    #[test]
    fn defaults_build() {
        let exp = Experiment::builder().requests(10).build().unwrap();
        assert_eq!(exp.cfg.n_instances, 16);
        assert_eq!(exp.cfg.policy.name, "cascade");
        assert_eq!(exp.requests.len(), 10);
    }

    #[test]
    fn unknown_names_are_hard_errors_listing_choices() {
        let e = Experiment::builder().model("GPT-9000").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::UnknownModel(_)));
        assert!(e.to_string().contains("Llama-3.2-3B"), "{e}");

        let e = Experiment::builder().gpu("A100").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::UnknownGpu(_)));
        assert!(e.to_string().contains("H20|L40|H100"), "{e}");

        let e = Experiment::builder().scheduler("fifo").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Policy(_)));
        assert!(e.to_string().contains("cascade"), "{e}");

        let e = Experiment::builder().workload_name("poisson2").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Workload(_)));
        assert!(e.to_string().contains("sharegpt"), "{e}");

        let e = Experiment::builder().instances(0).requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Invalid(_)));

        // A non-positive rate must surface as a build error, not a
        // panic inside the Poisson generator.
        let e = Experiment::builder().rate(0.0).requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Invalid(_)));
        assert!(e.to_string().contains("rate"), "{e}");
        let e = Experiment::builder().rate(-3.0).requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Invalid(_)));
    }

    #[test]
    fn custom_axis_spec_builds() {
        let exp = Experiment::builder()
            .scheduler("custom:layout=planned,refine=memory,balance=rrintra,dispatch=stagerouted")
            .instances(4)
            .requests(20)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.policy.layout, Layout::Planned);
        assert_eq!(exp.cfg.policy.refine, RefinePolicy::Memory);
        assert_eq!(exp.cfg.policy.balance, BalancePolicy::RoundRobinIntra);
        assert_eq!(exp.cfg.policy.dispatch, DispatchPolicy::StageRouted);
    }

    #[test]
    fn config_file_values_feed_builder_and_flags_override() {
        let cfg = crate::config::Config::parse(
            "[experiment]\nmodel = \"Llama-3.2-3B\"\ninstances = 4\nrate = 2.5\n\
             requests = 30\nseed = 7\nscheduler = \"llumnix\"\nworkload = \"heavytail\"\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_config(&cfg);
        let exp = Experiment::from_config(&ec).build().unwrap();
        assert_eq!(exp.cfg.n_instances, 4);
        assert_eq!(exp.cfg.policy.name, "llumnix");
        assert_eq!(exp.cfg.engine_speed, 1.25, "registry llumnix carries its engine speed");
        assert_eq!(exp.requests.len(), 30);
        // A later setter (the CLI flag path) overrides the file value.
        let exp = Experiment::from_config(&ec).scheduler("cascade").instances(2).build().unwrap();
        assert_eq!(exp.cfg.policy.name, "cascade");
        assert_eq!(exp.cfg.n_instances, 2);
    }

    #[test]
    fn explicit_kv_capacity_is_honoured_even_at_the_old_default() {
        // The old sentinel ("value == default => derive from GPU")
        // made an explicit 1M indistinguishable from unset; the
        // Option-based config keeps it.
        let exp = Experiment::builder().requests(5).kv_capacity(1_000_000).build().unwrap();
        assert_eq!(exp.cfg.engine.kv_capacity_tokens, Some(1_000_000));
        let exp = Experiment::builder().requests(5).build().unwrap();
        assert_eq!(exp.cfg.engine.kv_capacity_tokens, None);
    }

    #[test]
    fn fleet_string_defines_instances_and_gpus() {
        let exp = Experiment::builder()
            .fleet("h20:2,h100:2")
            .requests(10)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.n_instances, 4);
        let fleet = exp.cfg.fleet.as_ref().expect("fleet set");
        assert_eq!(fleet.gpu_names(), vec!["H20", "H20", "H100", "H100"]);
        // Majority GPU becomes the config-level reference.
        assert_eq!(exp.cfg.gpu.name, "H20");
    }

    #[test]
    fn malformed_fleet_is_a_hard_error_listing_choices() {
        let e = Experiment::builder().fleet("a100:4").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Fleet(_)));
        assert!(e.to_string().contains("H20|L40|H100"), "{e}");
        let e = Experiment::builder().fleet("h20:zero").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Fleet(_)));
        // Malformed / unknown fleet options surface through the
        // builder with the valid keys named.
        let e = Experiment::builder().fleet("h20:2,tp=0").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Fleet(_)), "{e}");
        let e = Experiment::builder().fleet("h20:2,turbo=on").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Fleet(_)));
        assert!(e.to_string().contains("speed") && e.to_string().contains("tp"), "{e}");
    }

    #[test]
    fn tp_fleet_string_reaches_cluster_config() {
        let exp = Experiment::builder()
            .fleet("h20:2,h20:2,tp=4")
            .requests(10)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.n_instances, 4);
        let fleet = exp.cfg.fleet.as_ref().expect("fleet set");
        assert_eq!(fleet.tp_degrees(), vec![1, 1, 4, 4]);
        assert!(fleet.has_tensor_parallel());
        // Builder-level engine knobs stamp fleet-wide without
        // clobbering the parsed TP degrees.
        let exp = Experiment::builder()
            .fleet("h20:1,h20:1,tp=2")
            .kv_capacity(500_000)
            .requests(5)
            .build()
            .unwrap();
        let fleet = exp.cfg.fleet.as_ref().unwrap();
        assert_eq!(fleet.tp_degrees(), vec![1, 2]);
        assert!(fleet
            .instances
            .iter()
            .all(|s| s.engine.kv_capacity_tokens == Some(500_000)));
    }

    #[test]
    fn builder_kv_capacity_applies_fleet_wide() {
        let exp = Experiment::builder()
            .fleet("h20:1,h100:1")
            .kv_capacity(500_000)
            .requests(5)
            .build()
            .unwrap();
        let fleet = exp.cfg.fleet.as_ref().unwrap();
        assert!(fleet
            .instances
            .iter()
            .all(|s| s.engine.kv_capacity_tokens == Some(500_000)));
    }

    #[test]
    fn predictor_flag_reaches_the_policy_and_overrides_the_spec() {
        use crate::predict::PredictorSpec;
        let exp = Experiment::builder()
            .predictor("noisy:0.3")
            .requests(5)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.policy.predictor, PredictorSpec::Noisy { cv: 0.3 });
        // The flag wins over the predictor carried by a custom: spec.
        let exp = Experiment::builder()
            .scheduler("custom:layout=flat,predictor=bucket:0.7")
            .predictor("ltr:0.9")
            .requests(5)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.policy.predictor, PredictorSpec::Ltr { pacc: 0.9 });
        // Unknown predictors are hard errors listing the grammar.
        let e = Experiment::builder().predictor("psychic").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Policy(_)));
        assert!(e.to_string().contains("noisy"), "{e}");
    }

    #[test]
    fn layout_flag_reaches_the_policy_and_overrides_the_spec() {
        use crate::cluster::{Layout, PdSpec};
        let exp = Experiment::builder()
            .layout("pd:1/1")
            .instances(2)
            .requests(5)
            .build()
            .unwrap();
        match exp.cfg.policy.layout {
            Layout::Disaggregated(pd) => assert_eq!((pd.prefill, pd.decode), (1, 1)),
            other => panic!("expected a PD layout, got {other:?}"),
        }
        // The flag wins over the layout carried by a custom: spec.
        let exp = Experiment::builder()
            .scheduler("custom:layout=chain")
            .layout("flat")
            .requests(5)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.policy.layout, Layout::Flat);
        // Unknown layouts are hard errors quoting the PD grammar.
        let e = Experiment::builder().layout("pancake").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Policy(_)));
        assert!(e.to_string().contains(PdSpec::GRAMMAR), "{e}");
    }

    #[test]
    fn config_file_layout_feeds_builder() {
        let cfg = crate::config::Config::parse(
            "[experiment]\ninstances = 4\nrequests = 10\nrate = 5.0\n\
             layout = \"pd:2/2\"\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_config(&cfg);
        assert_eq!(ec.layout.as_deref(), Some("pd:2/2"));
        let exp = Experiment::from_config(&ec).build().unwrap();
        match exp.cfg.policy.layout {
            crate::cluster::Layout::Disaggregated(pd) => {
                assert_eq!((pd.prefill, pd.decode), (2, 2))
            }
            other => panic!("expected a PD layout, got {other:?}"),
        }
    }

    #[test]
    fn config_file_predictor_feeds_builder() {
        let cfg = crate::config::Config::parse(
            "[experiment]\ninstances = 2\nrequests = 10\nrate = 5.0\n\
             predictor = \"noisy:0.5\"\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_config(&cfg);
        assert_eq!(ec.predictor.as_deref(), Some("noisy:0.5"));
        let exp = Experiment::from_config(&ec).build().unwrap();
        assert_eq!(
            exp.cfg.policy.predictor,
            crate::predict::PredictorSpec::Noisy { cv: 0.5 }
        );
    }

    #[test]
    fn config_file_fleet_feeds_builder() {
        let cfg = crate::config::Config::parse(
            "[experiment]\nfleet = \"h20:1,h100:1\"\nrequests = 10\nrate = 5.0\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_config(&cfg);
        assert_eq!(ec.fleet.as_deref(), Some("h20:1,h100:1"));
        let exp = Experiment::from_config(&ec).build().unwrap();
        assert_eq!(exp.cfg.n_instances, 2);
        assert!(exp.cfg.fleet.is_some());
    }

    #[test]
    fn churn_spec_reaches_cluster_config() {
        let exp = Experiment::builder()
            .instances(4)
            .churn("spot:2.0@1,join:6.0")
            .requests(10)
            .build()
            .unwrap();
        assert_eq!(exp.cfg.churn.events.len(), 2);
        assert_eq!(exp.cfg.churn.scheduled_joins(), 1);
        // `none` is the explicit no-op spelling.
        let exp = Experiment::builder().churn("none").requests(5).build().unwrap();
        assert!(exp.cfg.churn.is_none());
        // Malformed specs are hard errors naming the flag.
        let e = Experiment::builder().churn("spot:oops").requests(1).build().unwrap_err();
        assert!(matches!(e, ExperimentError::Invalid(_)));
        assert!(e.to_string().contains("churn"), "{e}");
    }

    #[test]
    fn config_file_churn_feeds_builder() {
        let cfg = crate::config::Config::parse(
            "[experiment]\ninstances = 2\nrequests = 10\nrate = 5.0\n\
             churn = \"auto:1.0:2..4\"\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_config(&cfg);
        assert_eq!(ec.churn.as_deref(), Some("auto:1.0:2..4"));
        let exp = Experiment::from_config(&ec).build().unwrap();
        let auto = exp.cfg.churn.autoscale.expect("autoscale parsed");
        assert_eq!((auto.min, auto.max), (2, 4));
    }

    #[test]
    fn streaming_build_matches_materialized_fingerprint() {
        let builder = || {
            Experiment::builder()
                .instances(4)
                .scheduler("cascade")
                .workload_name("heavytail")
                .rate(12.0)
                .requests(80)
                .plan_sample(40)
                .seed(7)
        };
        let (rep_m, stats_m) = builder().build().unwrap().run();
        let streaming = builder().build_streaming().unwrap();
        assert_eq!(streaming.total_requests(), 80);
        let (rep_s, stats_s) = streaming.run().unwrap();
        assert_eq!(rep_m.fingerprint(), rep_s.fingerprint());
        assert_eq!(rep_m.records.len(), rep_s.records.len());
        assert_eq!(stats_m.migrations, stats_s.migrations);
        assert_eq!(stats_m.engine_iterations, stats_s.engine_iterations);
    }

    #[test]
    fn explicit_trace_refuses_streaming_build() {
        let reqs = crate::workload::generate(&crate::workload::ShareGptLike::default(), 8.0, 5, 1);
        let e = Experiment::builder().trace(reqs).build_streaming().unwrap_err();
        assert!(matches!(e, ExperimentError::Invalid(_)));
        assert!(e.to_string().contains("materialized"), "{e}");
    }

    #[test]
    fn small_experiment_runs_end_to_end() {
        let (report, stats) = Experiment::builder()
            .instances(4)
            .scheduler("sjf")
            .rate(10.0)
            .requests(60)
            .plan_sample(200)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.records.len(), 60);
        assert_eq!(stats.migrations, 0, "sjf has no bid-ask migration");
    }
}
