//! Serving metrics — TTFT, TPOT, throughput, SLO attainment (§6.1).
//!
//! Per-request lifecycle timestamps are recorded by the engines and
//! folded here into the exact statistics the paper's figures report:
//! mean/p95 TTFT (Fig. 6), mean/p95 TPOT (Fig. 7), token throughput
//! (Figs. 10–11), normalized latency (Fig. 9), SLO attainment
//! (Fig. 12), and per-instance output-token CV (Fig. 16).

use crate::{RequestId, Time, Tokens};
use std::collections::HashMap;

/// Lifecycle record of one completed request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: RequestId,
    pub arrival: Time,
    /// First output token emitted (end of prefill).
    pub first_token: Time,
    /// Last output token emitted.
    pub completion: Time,
    pub input_len: Tokens,
    pub output_len: Tokens,
}

impl RequestRecord {
    /// Time to First Token.
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Time per Output Token (averaged over the decode phase).
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            0.0
        } else {
            (self.completion - self.first_token) / (self.output_len - 1) as f64
        }
    }

    /// End-to-end latency.
    pub fn e2e(&self) -> f64 {
        self.completion - self.arrival
    }

    /// Normalized latency: end-to-end delay per output token (the
    /// Fig. 9 metric, and the Q of the QoE fit).
    pub fn normalized_latency(&self) -> f64 {
        self.e2e() / self.output_len.max(1) as f64
    }
}

/// Percentile over a copy of the data (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp: a single NaN sample must not panic percentile
    // reporting (NaNs sort to the end and cannot poison low/mid ranks).
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0 * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Aggregated run report.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub records: Vec<RequestRecord>,
    /// Wall-clock span of the run (for throughput).
    pub duration: Time,
}

impl Report {
    pub fn from_records(records: Vec<RequestRecord>) -> Self {
        let duration = records
            .iter()
            .map(|r| r.completion)
            .fold(0.0f64, f64::max);
        Self { records, duration }
    }

    /// Stable FNV-style fingerprint over every record's exact bit
    /// patterns.  Two reports are bit-identical iff their fingerprints
    /// match — the golden-seed and builder-compat regressions key on
    /// this, and it is order-sensitive by construction.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for rec in &self.records {
            mix(rec.id);
            mix(rec.arrival.to_bits());
            mix(rec.first_token.to_bits());
            mix(rec.completion.to_bits());
            mix(rec.input_len);
            mix(rec.output_len);
        }
        h
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.ttft()).collect()
    }

    pub fn tpots(&self) -> Vec<f64> {
        self.records.iter().filter(|r| r.output_len > 1).map(|r| r.tpot()).collect()
    }

    pub fn normalized_latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.normalized_latency()).collect()
    }

    pub fn mean_ttft(&self) -> f64 {
        mean(&self.ttfts())
    }

    pub fn p50_ttft(&self) -> f64 {
        percentile(&self.ttfts(), 50.0)
    }

    pub fn p95_ttft(&self) -> f64 {
        percentile(&self.ttfts(), 95.0)
    }

    pub fn p99_ttft(&self) -> f64 {
        percentile(&self.ttfts(), 99.0)
    }

    pub fn mean_tpot(&self) -> f64 {
        mean(&self.tpots())
    }

    pub fn p95_tpot(&self) -> f64 {
        percentile(&self.tpots(), 95.0)
    }

    pub fn mean_normalized_latency(&self) -> f64 {
        mean(&self.normalized_latencies())
    }

    /// Output tokens per second over the run (Figs. 10–11).
    pub fn throughput_tokens_per_s(&self) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        let toks: u64 = self.records.iter().map(|r| r.output_len).sum();
        toks as f64 / self.duration
    }

    /// Output tokens per second emitted before `t` — the paper's
    /// fixed-duration throughput (§6.1: "each test point runs for the
    /// same duration"). Tokens of a request are attributed uniformly
    /// between its first token and its completion.
    pub fn throughput_until(&self, t: Time) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let mut toks = 0.0;
        for r in &self.records {
            if r.first_token > t {
                continue;
            }
            let span = (r.completion - r.first_token).max(1e-9);
            let frac = ((t - r.first_token) / span).clamp(0.0, 1.0);
            toks += r.output_len as f64 * frac;
        }
        toks / t
    }

    /// Completed requests per second.
    pub fn throughput_requests_per_s(&self) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / self.duration
    }

    /// Fraction of requests meeting `ttft <= slo.ttft && tpot <= slo.tpot`
    /// (Fig. 12).
    pub fn slo_attainment(&self, slo: Slo) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self
            .records
            .iter()
            .filter(|r| r.ttft() <= slo.ttft && r.tpot() <= slo.tpot)
            .count();
        ok as f64 / self.records.len() as f64
    }
}

/// An SLO: worst-case bounds on TTFT and TPOT (§6.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    pub ttft: f64,
    pub tpot: f64,
}

impl Slo {
    /// The paper's baseline SLO: metrics under minimum load (a single
    /// request on an idle system), scaled by N.
    pub fn scaled(base_ttft: f64, base_tpot: f64, n: f64) -> Self {
        Slo { ttft: base_ttft * n, tpot: base_tpot * n }
    }
}

/// Per-instance counters for load-balance statistics (Fig. 16).
#[derive(Debug, Clone, Default)]
pub struct InstanceCounters {
    /// Output tokens generated per instance.
    pub output_tokens: HashMap<usize, u64>,
}

impl InstanceCounters {
    pub fn add(&mut self, instance: usize, tokens: u64) {
        *self.output_tokens.entry(instance).or_insert(0) += tokens;
    }

    /// Coefficient of variation of output tokens across the given
    /// instances (lower = more balanced; the Fig. 16 metric).
    pub fn cv(&self, instances: &[usize]) -> f64 {
        if instances.is_empty() {
            return 0.0;
        }
        let xs: Vec<f64> = instances
            .iter()
            .map(|i| *self.output_tokens.get(i).unwrap_or(&0) as f64)
            .collect();
        let m = mean(&xs);
        if m.abs() < 1e-12 {
            return 0.0;
        }
        let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
        var.sqrt() / m
    }
}

/// Machine-readable perf output (`BENCH_hotpath.json`): a flat map of
/// metric name to finite number.  The repo is dependency-free, so this
/// is a tiny hand-rolled emitter/reader pair covering exactly the
/// format the perf harness writes and the CI regression gate reads —
/// not a general JSON implementation.
#[derive(Debug, Clone, Default)]
pub struct BenchReport {
    pub entries: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn push(&mut self, key: &str, value: f64) {
        debug_assert!(value.is_finite(), "{key}: {value} is not JSON-representable");
        self.entries.push((key.to_string(), value));
    }

    /// Serialize to a JSON object (insertion order preserved).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.entries.iter().enumerate() {
            s.push_str("  \"");
            s.push_str(k);
            s.push_str("\": ");
            s.push_str(&format!("{v}"));
            if i + 1 < self.entries.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("}\n");
        s
    }

    /// Read one numeric value back out of a flat JSON object (accepts
    /// this emitter's output and hand-edited baselines with the same
    /// `"key": number` shape).  Returns `None` for missing keys or
    /// non-numeric values.
    pub fn parse_value(json: &str, key: &str) -> Option<f64> {
        let needle = format!("\"{key}\"");
        let pos = json.find(&needle)?;
        let rest = json[pos + needle.len()..].trim_start();
        let rest = rest.strip_prefix(':')?.trim_start();
        let end = rest
            .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, first: f64, done: f64, out: u64) -> RequestRecord {
        RequestRecord {
            id: 0,
            arrival,
            first_token: first,
            completion: done,
            input_len: 10,
            output_len: out,
        }
    }

    #[test]
    fn ttft_tpot_e2e() {
        let r = rec(1.0, 1.5, 2.5, 11);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
        assert!((r.e2e() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn tpot_single_token_is_zero() {
        assert_eq!(rec(0.0, 1.0, 1.0, 1).tpot(), 0.0);
    }

    #[test]
    fn normalized_latency_divides_by_output() {
        let r = rec(0.0, 1.0, 5.0, 10);
        assert!((r.normalized_latency() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ttft_percentile_family_is_ordered() {
        let report = Report::from_records(
            (0..100).map(|i| rec(0.0, 0.1 + i as f64 * 0.01, 1.0, 5)).collect(),
        );
        assert!(report.p50_ttft() <= report.p95_ttft());
        assert!(report.p95_ttft() <= report.p99_ttft());
        assert!((report.p50_ttft() - 0.6).abs() < 1e-9);
        assert!((report.p99_ttft() - 1.08).abs() < 1e-9);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn throughput_counts_output_tokens() {
        let report = Report::from_records(vec![rec(0.0, 1.0, 10.0, 100), rec(0.0, 2.0, 8.0, 50)]);
        assert!((report.duration - 10.0).abs() < 1e-12);
        assert!((report.throughput_tokens_per_s() - 15.0).abs() < 1e-12);
        assert!((report.throughput_requests_per_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn throughput_until_interpolates() {
        let report = Report::from_records(vec![rec(0.0, 0.0, 10.0, 100)]);
        // Halfway through emission: 50 tokens over 5 seconds.
        assert!((report.throughput_until(5.0) - 10.0).abs() < 1e-9);
        // Past completion: all 100 tokens over 20 seconds.
        assert!((report.throughput_until(20.0) - 5.0).abs() < 1e-9);
        assert_eq!(report.throughput_until(0.0), 0.0);
    }

    #[test]
    fn slo_attainment_fraction() {
        let report = Report::from_records(vec![
            rec(0.0, 0.1, 1.0, 10),  // ttft 0.1, tpot 0.1
            rec(0.0, 2.0, 20.0, 10), // ttft 2.0, tpot 2.0
        ]);
        let slo = Slo { ttft: 0.5, tpot: 0.5 };
        assert!((report.slo_attainment(slo) - 0.5).abs() < 1e-12);
        let loose = Slo::scaled(0.1, 0.1, 100.0);
        assert!((report.slo_attainment(loose) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn instance_cv_balanced_is_zero() {
        let mut c = InstanceCounters::default();
        for i in 0..4 {
            c.add(i, 1000);
        }
        assert!(c.cv(&[0, 1, 2, 3]) < 1e-12);
        c.add(0, 1000);
        assert!(c.cv(&[0, 1, 2, 3]) > 0.1);
    }

    #[test]
    fn empty_report_is_finite() {
        let r = Report::default();
        assert_eq!(r.mean_ttft(), 0.0);
        assert_eq!(r.throughput_tokens_per_s(), 0.0);
        assert_eq!(r.slo_attainment(Slo { ttft: 1.0, tpot: 1.0 }), 0.0);
    }

    #[test]
    fn bench_report_round_trips() {
        let mut b = BenchReport::default();
        b.push("cluster_iters_per_s", 12345.5);
        b.push("ops", 2.0);
        let json = b.to_json();
        assert_eq!(BenchReport::parse_value(&json, "cluster_iters_per_s"), Some(12345.5));
        assert_eq!(BenchReport::parse_value(&json, "ops"), Some(2.0));
        assert_eq!(BenchReport::parse_value(&json, "missing"), None);
        // Hand-edited baselines (extra whitespace, string notes) parse.
        let hand = "{\n  \"note\": \"text\",\n  \"placeholder\": 1,\n  \"x\" : 3.5\n}\n";
        assert_eq!(BenchReport::parse_value(hand, "placeholder"), Some(1.0));
        assert_eq!(BenchReport::parse_value(hand, "x"), Some(3.5));
        assert_eq!(BenchReport::parse_value(hand, "note"), None);
    }
}
