//! Attention-backend cost model — the hardware behaviour of §2.3.
//!
//! The paper's central empirical claim is that modern attention kernels
//! (FlashAttention / FlashDecoding / Triton) are *sensitive to sequence-
//! length heterogeneity within a batch*: mixing short and long rows
//! inflates decode-kernel latency 1.1–2.1x at constant total tokens
//! (Fig. 2), because of
//!
//! 1. **Inter-SM imbalance** — a decode kernel assigns one CTA per
//!    (row, kv-head) when batch occupancy suffices; a 50K-token row
//!    then streams its whole KV through one CTA while the CTAs that
//!    served short rows sit idle — the long row is a *straggler* on the
//!    kernel's critical path.
//! 2. **Partitioning inefficiency** — when the kernel does split rows
//!    (FlashDecoding-style split-k), one split policy must serve the
//!    whole batch: small splits bloat the long rows' partial-result
//!    aggregation, large splits leave short rows' CTAs under-occupied
//!    (floor effects).
//!
//! The model prices a decode layer from: a hardware bandwidth floor,
//! per-CTA streaming rates with an occupancy cap, an issue-order
//! straggler term, a per-CTA minimum runtime, and serialized partial
//! aggregation.  The split policy mirrors flash_attn's real heuristic —
//! split **only** when `rows*kv_heads < 2*SMs` (occupancy starved),
//! never as a latency oracle — which is exactly why heterogeneous
//! batches get hurt on real kernels.
//!
//! Constants are physical where possible (datasheet bandwidths/FLOPs);
//! the four kernel-shape constants below are calibrated once so the
//! §2.2 attention-share numbers and the Fig. 2 penalty band reproduce
//! (see DESIGN.md §Calibration).

use crate::gpu::{GpuProfile, LinkKind};
use crate::models::ModelProfile;

/// Candidate split sizes (tokens) for the fixed-split ablation sweep —
/// mirrors FlashDecoding's split-k choices.
pub const BLOCK_CANDIDATES: [u32; 6] = [64, 128, 256, 512, 1024, 2048];

/// Per-partial-result aggregation cost, seconds (combine kernel's
/// serialized pass over one row's split partials).
const T_AGG_PER_PARTIAL: f64 = 1.0e-6;

/// Minimum CTA runtime regardless of tokens covered (warp scheduling +
/// DRAM burst granularity).
const T_BLOCK_MIN: f64 = 3.0e-6;

/// Single-CTA KV streaming rate, bytes/s. One CTA cannot saturate HBM;
/// ~12 GB/s is typical for a paged-KV gather loop on Hopper-class SMs.
const TB_BW: f64 = 12.0e9;

/// Fraction of peak HBM bandwidth the kernel sustains at full occupancy.
const ATTN_BW_EFF: f64 = 0.75;

/// Resident CTAs per SM (occupancy).
const CTA_PER_SM: u64 = 4;

/// Below `2*SM` row-head programs the kernel switches to split-k.
const SPLIT_OCCUPANCY_FACTOR: u64 = 2;

/// Minimum tokens per split program.
const SPLIT_TOKEN_MIN: u64 = 256;

/// One row of a decode batch: its current KV length in tokens.
pub type RowLen = u64;

/// The attention cost model bound to one (GPU, model) pair.
///
/// When the model is tensor-parallel (`model.tp > 1`) every forward
/// pass additionally pays per-layer collective costs: TP shards the
/// attention output projection and the MLP down projection, so each
/// transformer layer runs **two all-reduces** over the activations
/// (`tokens x d_model` at FP16) across the `tp` ranks.  The collective
/// is priced as a bandwidth-optimal ring over the configured TP link
/// ([`AttentionModel::with_tp_link`]; NVLink by default — TP groups
/// are intra-node), which is exactly why a TP4 slice does not decode
/// 4x faster than a TP1 replica even though its per-GPU weight and KV
/// traffic shrink 4x.
#[derive(Debug, Clone, Copy)]
pub struct AttentionModel {
    pub gpu: GpuProfile,
    pub model: ModelProfile,
    /// Bandwidth of the link TP collectives ride (bytes/s).
    pub tp_link_bytes_per_s: f64,
    /// Per-collective launch/synchronization latency (seconds).
    pub tp_link_latency_s: f64,
}

impl AttentionModel {
    pub fn new(gpu: GpuProfile, model: ModelProfile) -> Self {
        Self {
            gpu,
            model,
            tp_link_bytes_per_s: LinkKind::NvLink.bytes_per_s(),
            tp_link_latency_s: LinkKind::NvLink.latency_s(),
        }
    }

    /// Price TP collectives over `link` instead of the NVLink default
    /// (the cluster passes its topology's intra-node link here).
    pub fn with_tp_link(mut self, link: LinkKind) -> Self {
        self.tp_link_bytes_per_s = link.bytes_per_s();
        self.tp_link_latency_s = link.latency_s();
        self
    }

    /// Zero the collective term exactly (infinite link bandwidth, no
    /// latency) — the TP-aware planner prices a slice's compute/memory
    /// capacity with this and charges the collectives as a separate
    /// additive term, so the premium is never counted twice.
    pub fn without_tp_collectives(mut self) -> Self {
        self.tp_link_bytes_per_s = f64::INFINITY;
        self.tp_link_latency_s = 0.0;
        self
    }

    /// One ring all-reduce over `tokens` activation rows of `d_model`
    /// FP16 values: `2(tp-1)/tp` of the payload crosses the link
    /// (reduce-scatter + all-gather), plus one launch latency (the
    /// ring pipelines the per-hop latencies away for these sizes).
    fn allreduce_latency(&self, tokens: u64) -> f64 {
        let tp = self.model.tp as f64;
        let bytes = tokens as f64 * self.model.d_model as f64 * 2.0;
        2.0 * (tp - 1.0) / tp * bytes / self.tp_link_bytes_per_s + self.tp_link_latency_s
    }

    /// Tensor-parallel collective time of one full forward pass over
    /// `tokens` (a decode iteration's batch rows, or a prefill chunk's
    /// token count): two all-reduces per layer.  Exactly 0.0 when the
    /// model is not sharded — TP1 configurations stay bit-identical.
    pub fn tp_comm_latency(&self, tokens: u64) -> f64 {
        if self.model.tp <= 1 || tokens == 0 {
            return 0.0;
        }
        2.0 * self.model.n_layers as f64 * self.allreduce_latency(tokens)
    }

    /// KV bytes per token per layer per kv-head.
    #[inline]
    fn bytes_per_token_head(&self) -> f64 {
        self.model.kv_bytes_per_token() as f64
            / self.model.n_layers as f64
            / self.model.n_kv_heads as f64
    }

    /// Decode-attention latency of one layer.
    ///
    /// `split_tokens`: `None` = the kernel's own occupancy heuristic;
    /// `Some(s)` = force split-k at `s` tokens per split (ablation).
    pub fn decode_layer_latency(&self, lens: &[RowLen], split_tokens: Option<u64>) -> f64 {
        if lens.is_empty() {
            return 0.0;
        }
        let sm = self.gpu.sm_count as u64;
        let conc = CTA_PER_SM * sm; // concurrently resident CTAs
        let heads = self.model.n_kv_heads as u64;
        let row_heads = lens.len() as u64 * heads;
        let bph = self.bytes_per_token_head();

        // Split policy (the real kernels' heuristic, not an oracle):
        // enough (row, head) programs -> no split; occupancy-starved ->
        // split the longest row into ~conc/row_heads pieces.  The max
        // scan only runs in the starved branch: at full occupancy (the
        // common case for every per-decode-iteration call) the slice is
        // priced in a single pass.
        let split = match split_tokens {
            Some(s) => s.max(1),
            None => {
                if row_heads >= SPLIT_OCCUPANCY_FACTOR * sm {
                    u64::MAX // no split
                } else {
                    let len_max = lens.iter().copied().max().unwrap_or(1).max(1);
                    let target = (conc / row_heads.max(1)).max(1);
                    (len_max.div_ceil(target)).max(SPLIT_TOKEN_MIN)
                }
            }
        };

        let prog_dur = |tokens: u64| -> f64 { T_BLOCK_MIN.max(tokens as f64 * bph / TB_BW) };

        let mut work = 0.0f64; // total CTA-seconds
        let mut straggler = 0.0f64; // longest single program
        let mut n_progs = 0u64;
        let mut max_splits = 0u64;
        let mut total_tokens = 0u64;
        for &len in lens {
            let len = len.max(1);
            total_tokens += len;
            let splits = if split == u64::MAX { 1 } else { len.div_ceil(split) };
            let full = if split == u64::MAX { 0 } else { len / split };
            let rem = if split == u64::MAX { len } else { len - full * split };
            let mut row_work = full as f64 * prog_dur(split.min(len));
            let mut row_straggle = if full > 0 { prog_dur(split.min(len)) } else { 0.0 };
            if rem > 0 || full == 0 {
                let d = prog_dur(rem.max(1));
                row_work += d;
                row_straggle = row_straggle.max(d);
            }
            work += row_work * heads as f64;
            straggler = straggler.max(row_straggle);
            n_progs += splits * heads;
            max_splits = max_splits.max(splits);
        }

        // Issue-order list scheduling on `conc` workers: the makespan is
        // the work-conserving bound plus (when programs queue) the
        // expected straggler tail — on average half a straggler lands
        // in the final wave under issue-order (non-LPT) scheduling.
        let tb_time = if n_progs > conc {
            work / conc as f64 + 0.5 * straggler
        } else {
            (work / conc as f64).max(straggler)
        };
        // Hardware bandwidth floor: all KV bytes must cross HBM once.
        let total_bytes = total_tokens as f64 * bph * heads as f64;
        let bw_bound = total_bytes / (self.gpu.hbm_bytes_per_s * ATTN_BW_EFF);

        let agg = if max_splits > 1 { max_splits as f64 * T_AGG_PER_PARTIAL } else { 0.0 };
        self.gpu.launch_overhead_s + tb_time.max(bw_bound) + agg
    }

    /// Decode attention for the full stack (kernel heuristic).
    pub fn decode_attention_latency(&self, lens: &[RowLen]) -> f64 {
        self.decode_layer_latency(lens, None) * self.model.n_layers as f64
    }

    /// Same, with split-k forced at `block` tokens — used by the Fig. 2
    /// bench to expose the block-size/block-count trade-off explicitly.
    pub fn decode_attention_latency_fixed_block(&self, lens: &[RowLen], block: u32) -> f64 {
        self.decode_layer_latency(lens, Some(block as u64)) * self.model.n_layers as f64
    }

    /// Weight-access time of one decode iteration: every parameter is
    /// read once per forward pass (memory-bound GEMV regime).
    pub fn weight_access_latency(&self) -> f64 {
        self.model.weight_bytes() as f64 / self.gpu.hbm_bytes_per_s
    }

    /// Linear-layer compute for `batch` tokens in one iteration.
    pub fn linear_compute_latency(&self, batch: usize) -> f64 {
        batch as f64 * self.model.flops_per_token() / self.gpu.effective_flops()
    }

    /// Full decode-iteration latency for a batch with per-row KV lens:
    /// `max(weights, linear) + attention + engine overhead + TP
    /// collectives` (weight streaming overlaps GEMV compute; attention
    /// is a separate pass; the collective term is 0.0 at TP1).
    pub fn decode_iteration_latency(&self, lens: &[RowLen]) -> f64 {
        if lens.is_empty() {
            return 0.0;
        }
        let dense = self.weight_access_latency().max(self.linear_compute_latency(lens.len()));
        // Per-token sampling/dispatch overhead of the serving engine.
        let engine = 1.5e-6 * lens.len() as f64 + 150.0e-6;
        dense
            + self.decode_attention_latency(lens)
            + engine
            + self.tp_comm_latency(lens.len() as u64)
    }

    /// Fraction of decode-iteration latency spent in attention — the
    /// §2.2 motivation statistic (81% at bs=250, len=1000 on H100/3B).
    pub fn attention_share(&self, lens: &[RowLen]) -> f64 {
        let attn = self.decode_attention_latency(lens);
        attn / self.decode_iteration_latency(lens)
    }

    /// Prefill latency for a prompt of `t` tokens (compute-bound,
    /// quadratic attention term; §2.1).  TP-sharded models pay the
    /// per-layer all-reduces over the whole chunk (0.0 at TP1).
    pub fn prefill_latency(&self, t: u64) -> f64 {
        let comm = self.tp_comm_latency(t);
        let t = t as f64;
        let dense = t * self.model.flops_per_token() / self.gpu.effective_flops();
        // Attention FLOPs: 2 * T^2 * d per layer (QK^T and PV).
        let attn_flops = self.model.n_layers as f64
            * t
            * t
            * (self.model.n_heads as f64 * self.model.head_dim as f64)
            * 2.0
            / self.model.tp as f64;
        let weights = self.weight_access_latency();
        self.gpu.launch_overhead_s
            + dense.max(weights)
            + attn_flops / self.gpu.effective_flops()
            + comm
    }

    /// The Fig. 2 statistic: latency of a mixed batch over the latency
    /// of a homogeneous batch with the same row count and total tokens.
    pub fn heterogeneity_penalty(&self, lens: &[RowLen]) -> f64 {
        if lens.is_empty() {
            return 1.0;
        }
        let total: u64 = lens.iter().sum();
        let homo = vec![(total / lens.len() as u64).max(1); lens.len()];
        self.decode_attention_latency(lens) / self.decode_attention_latency(&homo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuProfile;
    use crate::models::LLAMA_3B;

    fn h100_3b() -> AttentionModel {
        AttentionModel::new(GpuProfile::H100, LLAMA_3B)
    }

    fn h20_3b() -> AttentionModel {
        AttentionModel::new(GpuProfile::H20, LLAMA_3B)
    }

    /// A mixed batch: `n_long` rows at `long` tokens, rest at `short`.
    fn mix(n: usize, n_long: usize, long: u64, short: u64) -> Vec<u64> {
        let mut v = vec![long; n_long];
        v.extend(vec![short; n - n_long]);
        v
    }

    #[test]
    fn empty_batch_costs_nothing() {
        assert_eq!(h100_3b().decode_attention_latency(&[]), 0.0);
    }

    #[test]
    fn latency_monotone_in_length() {
        let m = h100_3b();
        let short = m.decode_attention_latency(&[1000; 32]);
        let long = m.decode_attention_latency(&[4000; 32]);
        assert!(long > short);
    }

    #[test]
    fn latency_monotone_in_batch() {
        let m = h100_3b();
        let a = m.decode_attention_latency(&[2000; 16]);
        let b = m.decode_attention_latency(&[2000; 64]);
        assert!(b > a);
    }

    #[test]
    fn paper_2_2_attention_share_bs250() {
        // §2.2: Llama-3.2-3B on H100, 1000-token rows: attention is
        // ~81% of iteration latency at bs=250, vs ~14% at bs=1.
        let m = h100_3b();
        let share_big = m.attention_share(&[1000; 250]);
        let share_one = m.attention_share(&[1000; 1]);
        assert!(share_big > 0.70, "bs=250 share {share_big}");
        assert!(share_one < 0.30, "bs=1 share {share_one}");
    }

    #[test]
    fn paper_2_2_attention_share_len200_bs500() {
        // §2.2: 200-token rows at bs=500 reach ~62%.
        let share = h100_3b().attention_share(&[200; 500]);
        assert!(share > 0.45 && share < 0.85, "share {share}");
    }

    #[test]
    fn fig2a_heterogeneity_penalty_band() {
        // Fig. 2a: 1000 vs 50000 tokens, bs=512, constant total tokens:
        // 1.1-2.1x inflation. The penalty peaks when the long rows are
        // a minority (stragglers over mostly-idle CTAs).
        let m = h20_3b();
        let mut peak: f64 = 1.0;
        for n_long in [10, 26, 51, 128] {
            let lens = mix(512, n_long, 50_000, 1000);
            let p = m.heterogeneity_penalty(&lens);
            assert!(p >= 0.99 && p < 2.5, "penalty {p} at n_long {n_long}");
            peak = peak.max(p);
        }
        assert!(peak > 1.1 && peak < 2.2, "peak penalty {peak} outside Fig.2 band");
    }

    #[test]
    fn fig2b_small_mix_band() {
        // Fig. 2b: 200 vs 10000 tokens, bs=512.
        let m = h20_3b();
        let p = m.heterogeneity_penalty(&mix(512, 32, 10_000, 200));
        assert!(p > 1.1 && p < 2.5, "penalty {p}");
    }

    #[test]
    fn homogeneous_penalty_is_one() {
        let m = h20_3b();
        let p = m.heterogeneity_penalty(&[3000; 64]);
        assert!((p - 1.0).abs() < 1e-9);
    }

    #[test]
    fn split_heuristic_beats_forced_extremes_when_starved() {
        // Low-occupancy mixed batch: the occupancy-driven split must
        // beat both no-split (huge straggler) and tiny splits (agg
        // blowup + floors).
        let m = h20_3b();
        let lens = mix(8, 4, 60_000, 500);
        let heuristic = m.decode_attention_latency(&lens);
        let tiny = m.decode_attention_latency_fixed_block(&lens, 64);
        let nosplit = m.decode_attention_latency_fixed_block(&lens, u32::MAX);
        assert!(heuristic < tiny, "heuristic {heuristic} vs tiny {tiny}");
        assert!(heuristic < nosplit, "heuristic {heuristic} vs nosplit {nosplit}");
    }

    #[test]
    fn forced_split_tradeoff_exists() {
        // The block-size/block-count trade-off (§2.3): across forced
        // split sizes, the best is strictly inside the candidate range
        // for a straggler-heavy batch.
        let m = h20_3b();
        let lens = mix(64, 8, 80_000, 400);
        let costs: Vec<f64> = BLOCK_CANDIDATES
            .iter()
            .map(|&b| m.decode_attention_latency_fixed_block(&lens, b))
            .collect();
        let best = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(costs[0] > best, "tiny split should lose: {costs:?}");
        let nosplit = m.decode_attention_latency_fixed_block(&lens, u32::MAX);
        assert!(nosplit > best, "no-split should lose: {nosplit} vs {best}");
    }

    #[test]
    fn prefill_quadratic_regime() {
        let m = h20_3b();
        let t1 = m.prefill_latency(8_000);
        let t2 = m.prefill_latency(16_000);
        // Superlinear growth (attention term kicks in).
        assert!(t2 > 2.0 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn decode_iteration_includes_weight_floor() {
        let m = h20_3b();
        let t = m.decode_iteration_latency(&[100]);
        assert!(t >= m.weight_access_latency());
    }

    #[test]
    fn tp_reduces_weight_latency() {
        use crate::models::llama_70b;
        let m2 = AttentionModel::new(GpuProfile::H20, llama_70b(2));
        let m4 = AttentionModel::new(GpuProfile::H20, llama_70b(4));
        assert!(m4.weight_access_latency() < m2.weight_access_latency());
    }

    #[test]
    fn tp1_pays_no_collectives() {
        let m = h20_3b();
        assert_eq!(m.tp_comm_latency(256), 0.0);
        // And the iteration/prefill sums are bit-identical to adding
        // a literal 0.0 — the TP1 legacy guarantee.
        let lens = vec![1000u64; 32];
        let base = m.weight_access_latency().max(m.linear_compute_latency(32))
            + m.decode_attention_latency(&lens)
            + (1.5e-6 * 32.0 + 150.0e-6);
        assert_eq!(m.decode_iteration_latency(&lens).to_bits(), base.to_bits());
    }

    #[test]
    fn tp_collective_grows_with_degree_and_slower_links() {
        use crate::models::llama_70b;
        let m2 = AttentionModel::new(GpuProfile::H20, llama_70b(2));
        let m4 = AttentionModel::new(GpuProfile::H20, llama_70b(4));
        let m8 = AttentionModel::new(GpuProfile::H20, llama_70b(8));
        let c2 = m2.tp_comm_latency(64);
        let c4 = m4.tp_comm_latency(64);
        let c8 = m8.tp_comm_latency(64);
        assert!(c2 > 0.0);
        // Ring factor 2(tp-1)/tp rises with the degree at fixed bytes.
        assert!(c2 < c4 && c4 < c8, "{c2} {c4} {c8}");
        // A PCIe TP group pays far more than the NVLink default.
        let pcie = m4.with_tp_link(LinkKind::Pcie);
        assert!(pcie.tp_comm_latency(64) > c4);
        assert!(
            pcie.decode_iteration_latency(&[4000; 64])
                > m4.decode_iteration_latency(&[4000; 64])
        );
    }

    #[test]
    fn tp4_70b_iteration_still_beats_tp1_despite_collectives() {
        // The whole point of sharding: per-GPU weight and KV traffic
        // shrink 4x, which on a 70B model dwarfs the all-reduce
        // premium — but the speedup is sublinear (< 4x).
        use crate::models::llama_70b;
        let m1 = AttentionModel::new(GpuProfile::H20, llama_70b(1));
        let m4 = AttentionModel::new(GpuProfile::H20, llama_70b(4));
        let lens = vec![1280u64; 64];
        let t1 = m1.decode_iteration_latency(&lens);
        let t4 = m4.decode_iteration_latency(&lens);
        assert!(t4 < t1, "tp4 {t4} vs tp1 {t1}");
        assert!(t4 > t1 / 4.0, "collectives must make the speedup sublinear");
        // Prefill pays the collectives too.
        assert!(m4.prefill_latency(2048) > 0.0);
        let m4_pcie = m4.with_tp_link(LinkKind::Pcie);
        assert!(m4_pcie.prefill_latency(2048) > m4.prefill_latency(2048));
    }

    #[test]
    fn bandwidth_floor_binds_at_full_occupancy() {
        // A big homogeneous batch must cost at least its HBM traffic.
        let m = h20_3b();
        let lens = vec![8000u64; 512];
        let total_bytes: f64 =
            lens.iter().map(|&l| l as f64).sum::<f64>() * m.model.kv_bytes_per_token() as f64;
        let floor = total_bytes / (m.gpu.hbm_bytes_per_s * 0.75);
        assert!(m.decode_attention_latency(&lens) >= floor * 0.99);
    }
}
