//! Length-aware stage partitioning — the §4.2 dynamic program.
//!
//! Given `E` instances and a request-length histogram, find the
//! pipeline (number of stages, instances per stage, length range per
//! stage) minimising total predicted QoE plus inter-stage migration
//! cost:
//!
//! ```text
//! f[s][e][l] = min over e' in [s-1, e), l' in [0, l)
//!              of f[s-1][e'][l'] + (e-e') * Q^{n_{l',l}/(e-e')} + c_{l'}
//! ```
//!
//! Three implementations, matching the paper's complexity discussion:
//!
//! * [`Planner::plan_exact_fine`] — the naive formulation over raw
//!   length cut points, `O(E^3 L^2)`; only used by the complexity
//!   bench (§6.5 reports 51 hours at L=128K without optimizations).
//! * [`Planner::plan_dp`] — exact DP over exponential length buckets,
//!   `O(E^3 log^2 L)` (the first optimization).
//! * [`Planner::plan_heuristic`] — the two-phase heuristic: a chain DP
//!   assigning one instance per stage, then greedy merging of adjacent
//!   stages by best positive merge gain, `O(E (log^2 L + log E))`.

use crate::qoe::{Features, QoeModel};
use crate::workload::LengthHistogram;
use crate::Tokens;

/// One pipeline stage: serves sequences with length in `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSpec {
    pub lo: Tokens,
    pub hi: Tokens,
    pub n_instances: usize,
}

/// A full pipeline plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Pipeline {
    pub stages: Vec<StageSpec>,
    /// Predicted quality (lower is better) under the planning model.
    pub predicted_quality: f64,
}

impl Pipeline {
    /// Index of the stage serving length `len` (clamps to the ends —
    /// §3.2 routes a request to the earliest stage covering it).
    /// Binary search over the ascending stage boundaries — this runs
    /// per arrival and per rebalance probe, and stages tile the length
    /// axis contiguously by construction.
    pub fn stage_for(&self, len: Tokens) -> usize {
        debug_assert!(
            self.stages.windows(2).all(|w| w[0].hi <= w[1].hi),
            "stages must have ascending upper bounds: {:?}",
            self.stages
        );
        // A stageless pipeline maps to stage 0 instead of underflowing
        // `len() - 1` on usize.
        if self.stages.is_empty() {
            return 0;
        }
        self.stages.partition_point(|s| s.hi <= len).min(self.stages.len() - 1)
    }

    pub fn total_instances(&self) -> usize {
        self.stages.iter().map(|s| s.n_instances).sum()
    }

    /// A single-stage pipeline using all instances (the "no-pipeline"
    /// ablation layout of §6.5).
    pub fn no_pipeline(e: usize, max_len: Tokens) -> Self {
        Pipeline {
            stages: vec![StageSpec { lo: 0, hi: max_len, n_instances: e }],
            predicted_quality: f64::INFINITY,
        }
    }

    /// Boundaries between consecutive stages (len = stages-1).
    pub fn boundaries(&self) -> Vec<Tokens> {
        self.stages.iter().take(self.stages.len().saturating_sub(1)).map(|s| s.hi).collect()
    }
}

/// Inter-stage migration cost model: the `c_{l'}` term.
///
/// Every request whose final length crosses a cut at `l'` must move its
/// KV cache (~`l' * kv_bytes_per_token` bytes) across the inter-stage
/// link once.  Amortised over the planning window, the delay charged to
/// the cut is `crossings * bytes / bandwidth`, scaled by `weight` to
/// express how much one second of migration traffic degrades QoE.
#[derive(Debug, Clone, Copy)]
pub struct MigrationCost {
    pub kv_bytes_per_token: f64,
    pub link_bytes_per_s: f64,
    /// QoE units charged per second of transfer time.
    pub weight: f64,
}

impl MigrationCost {
    pub fn new(kv_bytes_per_token: f64, link_bytes_per_s: f64) -> Self {
        Self { kv_bytes_per_token, link_bytes_per_s, weight: 1.0 }
    }

    /// Zero-cost model (for tests / ablations).
    pub fn free() -> Self {
        Self { kv_bytes_per_token: 0.0, link_bytes_per_s: 1.0, weight: 0.0 }
    }

    fn cut_cost(&self, cut_len: Tokens, crossings: f64) -> f64 {
        if self.weight == 0.0 {
            return 0.0;
        }
        let bytes = crossings * cut_len as f64 * self.kv_bytes_per_token;
        self.weight * bytes / self.link_bytes_per_s
    }
}

/// The pipeline planner.
#[derive(Debug, Clone, Copy)]
pub struct Planner {
    pub qoe: QoeModel,
    pub migration: MigrationCost,
}

/// One instance's planning view for the tensor-parallel-aware DP
/// ([`Planner::plan_dp_instances`]).
///
/// Beyond the relative capacity weight the heterogeneous DP already
/// partitions over, a TP-aware plan needs to know (a) how much KV each
/// instance can actually hold — a TP4 slice pools 4x the per-GPU
/// headroom, and a stage serving 128K-token sequences is useless on an
/// instance whose pool tops out at a few thousand tokens — and (b) the
/// per-token collective premium its sharding pays, so the DP can trade
/// all-reduce overhead against KV feasibility when it decides which
/// length ranges land on the sharded instances.
#[derive(Debug, Clone, Copy)]
pub struct PlanInstance {
    /// Relative capacity weight (TP-adjusted modeled throughput; same
    /// convention as [`Planner::plan_dp_weighted`]'s `caps`).
    pub cap: f64,
    /// KV pool of this instance, in tokens (shards pooled).
    pub kv_tokens: f64,
    /// Amortized tensor-parallel collective seconds per generated
    /// token (0.0 for TP1 instances).
    pub comm_s_per_token: f64,
}

impl PlanInstance {
    /// A TP-free instance: ample KV, no collective premium.  A fleet
    /// of these makes [`Planner::plan_dp_instances`] price every stage
    /// exactly like [`Planner::plan_dp_weighted`].
    pub fn uniform(cap: f64) -> Self {
        Self { cap, kv_tokens: f64::INFINITY, comm_s_per_token: 0.0 }
    }
}

/// Aggregate view of the requests in a bucket range, as QoE features.
#[derive(Debug, Clone, Copy)]
struct RangeAgg {
    n: f64,
    sum_i: f64,
    sum_i2: f64,
    sum_l: f64,
}

impl RangeAgg {
    fn features(&self) -> Features {
        Features([1.0, self.n, self.sum_i, self.sum_i2, self.sum_l])
    }
}

/// Shared stage/cut pricing for the TP-aware DP
/// ([`Planner::plan_dp_instances`]) and its exhaustive reference
/// ([`Planner::plan_exhaustive_instances`]): both sides price every
/// candidate with the exact same float expressions, so the property
/// suite can compare their optima directly.
struct TpPlanCtx<'a> {
    planner: &'a Planner,
    bounds: &'a [Tokens],
    pref: Vec<(f64, f64, f64, f64)>,
    total_n: f64,
    uniform: bool,
    fleet_mean: f64,
    /// Prefix sums of raw capacities (`sum(caps[ep..ee])` is one
    /// subtraction per candidate — same trick as the weighted DP).
    cap_pref: Vec<f64>,
    /// Prefix sums of `cap * comm_s_per_token`: the capacity-share-
    /// weighted mean collective premium of a subrange is one
    /// subtraction + division per candidate.
    capcomm_pref: Vec<f64>,
    /// `min(kv_tokens)` over `[ep, ee)`, flattened `(e+1)^2` table
    /// (range-min has no prefix trick; E is small, build it once).
    min_kv: Vec<f64>,
    e: usize,
}

impl<'a> TpPlanCtx<'a> {
    fn new(planner: &'a Planner, hist: &'a LengthHistogram, insts: &[PlanInstance]) -> Self {
        let e = insts.len();
        let uniform = insts.windows(2).all(|w| w[0].cap == w[1].cap);
        let fleet_mean = insts.iter().map(|i| i.cap).sum::<f64>() / e as f64;
        let mut cap_pref = Vec::with_capacity(e + 1);
        let mut capcomm_pref = Vec::with_capacity(e + 1);
        let (mut acc_cap, mut acc_comm) = (0.0f64, 0.0f64);
        cap_pref.push(acc_cap);
        capcomm_pref.push(acc_comm);
        for inst in insts {
            acc_cap += inst.cap;
            acc_comm += inst.cap * inst.comm_s_per_token;
            cap_pref.push(acc_cap);
            capcomm_pref.push(acc_comm);
        }
        let mut min_kv = vec![f64::INFINITY; (e + 1) * (e + 1)];
        for ep in 0..e {
            let mut m = f64::INFINITY;
            for ee in (ep + 1)..=e {
                m = m.min(insts[ee - 1].kv_tokens);
                min_kv[ep * (e + 1) + ee] = m;
            }
        }
        let pref = hist.prefix();
        let total_n = pref[hist.bounds.len()].0;
        Self {
            planner,
            bounds: &hist.bounds,
            pref,
            total_n,
            uniform,
            fleet_mean,
            cap_pref,
            capcomm_pref,
            min_kv,
            e,
        }
    }

    fn range(&self, a: usize, b: usize) -> RangeAgg {
        RangeAgg {
            n: self.pref[b].0 - self.pref[a].0,
            sum_i: self.pref[b].1 - self.pref[a].1,
            sum_i2: self.pref[b].2 - self.pref[a].2,
            sum_l: self.pref[b].3 - self.pref[a].3,
        }
    }

    /// Migration cost of the cut at bucket boundary `lp` (0.0 for the
    /// leading edge) — same formula as the weighted DP.
    fn cut(&self, lp: usize) -> f64 {
        if lp == 0 {
            0.0
        } else {
            self.planner
                .migration
                .cut_cost(self.bounds[lp - 1], self.total_n - self.pref[lp].0)
        }
    }

    /// Cost of serving buckets `[lp, ll)` on instances `[ep, ee)`:
    /// the capacity-weighted set-division cost, scaled by the KV
    /// feasibility pressure, plus the collective premium on the
    /// range's generated tokens.  Both TP terms are bit-transparent
    /// for TP-free members (`* 1.0` and `+ 0.0`).
    fn stage(&self, ep: usize, ee: usize, lp: usize, ll: usize) -> f64 {
        let agg = self.range(lp, ll);
        let k = ee - ep;
        let base = if self.uniform {
            self.planner.stage_cost(agg, k)
        } else {
            let sum_rel = (self.cap_pref[ee] - self.cap_pref[ep]) / self.fleet_mean;
            self.planner.stage_cost_weighted(agg, k, sum_rel)
        };
        // KV pressure: the stage's upper length bound over the
        // smallest member pool.  <= 1 means every member can hold the
        // longest resident sequence — no penalty.
        let hi = self.bounds[ll - 1] as f64;
        let pressure = (hi / self.min_kv[ep * (self.e + 1) + ee]).max(1.0);
        // Collective premium: generated tokens (final minus input
        // lengths) times the members' capacity-share-weighted mean
        // per-token all-reduce time.
        let cap_sum = self.cap_pref[ee] - self.cap_pref[ep];
        let comm_per_token = (self.capcomm_pref[ee] - self.capcomm_pref[ep]) / cap_sum;
        let out_tokens = (agg.sum_l - agg.sum_i).max(0.0);
        base * pressure + comm_per_token * out_tokens
    }
}

impl Planner {
    pub fn new(qoe: QoeModel, migration: MigrationCost) -> Self {
        Self { qoe, migration }
    }

    /// QoE of serving the aggregate `agg` on `k` instances, via the
    /// paper's even set division (§4.2 footnote 1).
    fn stage_cost(&self, agg: RangeAgg, k: usize) -> f64 {
        if agg.n == 0.0 {
            return 0.0;
        }
        self.qoe.split_batch_qoe(&agg.features(), k)
    }

    /// QoE of serving `agg` on a *heterogeneous* instance set of `k`
    /// members whose relative speeds (each capacity over the fleet
    /// mean) sum to `sum_rel`.
    ///
    /// Model: the runtime's capacity-normalized balancing assigns each
    /// member the share that *equalizes per-request quality* — on an
    /// instance with relative speed `s_i` a sub-batch's latency scales
    /// by `1/s_i`, and solving `(D0 + L*w_i)/s_i = q, sum w_i = 1` for
    /// the linear QoE gives stage cost `Q_even * k / sum(s_i)`: the
    /// paper's even set division, discounted by the set's mean relative
    /// speed.  Speeds are relative to the **fleet mean** (mean raw
    /// capacity), so a stage of above-average instances prices *below*
    /// the even-split cost and the DP steers heavy length ranges toward
    /// capacity-rich stages.  For a homogeneous fleet every cap equals
    /// the fleet mean and the factor is exactly 1.0 — callers
    /// additionally take the legacy `stage_cost` path there so
    /// bit-identity never rests on this arithmetic.  `sum_rel` arrives
    /// precomputed (a prefix-sum difference in the DP) because
    /// rescanning `caps[ep..ee]` per candidate made the heterogeneous
    /// DP an O(E) factor slower than it needs to be.
    fn stage_cost_weighted(&self, agg: RangeAgg, k: usize, sum_rel: f64) -> f64 {
        if agg.n == 0.0 {
            return 0.0;
        }
        self.stage_cost(agg, k) * (k as f64 / sum_rel)
    }

    /// Exact DP over the histogram's exponential buckets for `e`
    /// interchangeable instances.  Thin wrapper over
    /// [`Planner::plan_dp_weighted`] with uniform capacities.
    pub fn plan_dp(&self, hist: &LengthHistogram, e: usize) -> Pipeline {
        self.plan_dp_weighted(hist, &vec![1.0; e])
    }

    /// Exact DP over the histogram's exponential buckets, partitioning
    /// a (possibly heterogeneous) ordered instance list described by
    /// per-instance capacity weights.  Instances are assigned to stages
    /// contiguously in list order (the §5 placement property), so the
    /// DP state is an instance *prefix* rather than a count; stage
    /// quality is a function of the exact instance subrange assigned
    /// ([`Planner::stage_cost_weighted`]): a subrange whose mean
    /// capacity beats the fleet mean prices below the even-split cost,
    /// so heavy length ranges gravitate to capacity-rich stages.  With
    /// uniform capacities the recurrence, the float operations, and the
    /// tie-breaking are identical to the historical count-based DP.
    pub fn plan_dp_weighted(&self, hist: &LengthHistogram, caps: &[f64]) -> Pipeline {
        self.plan_dp_weighted_impl(hist, caps, true)
    }

    /// Direct-summation variant of the heterogeneous DP: recomputes
    /// each candidate's relative-speed sum by rescanning `caps[ep..ee]`
    /// (the historical inner loop).  Kept as the reference the
    /// prefix-sum optimization is regression-pinned against — see the
    /// `weighted_dp_prefix_sums_match_reference` test.
    #[doc(hidden)]
    pub fn plan_dp_weighted_reference(&self, hist: &LengthHistogram, caps: &[f64]) -> Pipeline {
        self.plan_dp_weighted_impl(hist, caps, false)
    }

    fn plan_dp_weighted_impl(
        &self,
        hist: &LengthHistogram,
        caps: &[f64],
        prefix_caps: bool,
    ) -> Pipeline {
        let e = caps.len();
        assert!(e >= 1);
        debug_assert!(caps.iter().all(|c| c.is_finite() && *c > 0.0), "{caps:?}");
        let uniform = caps.windows(2).all(|w| w[0] == w[1]);
        let fleet_mean = caps.iter().sum::<f64>() / e as f64;
        // Prefix sums over raw capacities: `sum(caps[ep..ee])` becomes
        // one subtraction per DP candidate instead of an O(E) rescan.
        let cap_pref: Vec<f64> = {
            let mut v = Vec::with_capacity(e + 1);
            let mut acc = 0.0;
            v.push(acc);
            for &c in caps {
                acc += c;
                v.push(acc);
            }
            v
        };
        let k = hist.bounds.len();
        // A histogram with no buckets (empty bounds) cannot seed the
        // DP; the only valid answer is one stage holding everything.
        if k == 0 {
            return Pipeline {
                stages: vec![StageSpec { lo: 0, hi: Tokens::MAX, n_instances: e }],
                predicted_quality: 0.0,
            };
        }
        let pref = hist.prefix();
        let range = |a: usize, b: usize| -> RangeAgg {
            RangeAgg {
                n: pref[b].0 - pref[a].0,
                sum_i: pref[b].1 - pref[a].1,
                sum_i2: pref[b].2 - pref[a].2,
                sum_l: pref[b].3 - pref[a].3,
            }
        };
        // Crossings at bucket boundary b: requests in buckets >= b.
        let total_n = pref[k].0;
        let crossings = |b: usize| total_n - pref[b].0;

        // f[s][e][l]: s stages (1-indexed), e instances, first l buckets.
        // Flatten: dims (e+1) x (k+1) per stage level; roll stages.
        const INF: f64 = f64::INFINITY;
        let idx = |ee: usize, ll: usize| ee * (k + 1) + ll;
        let mut prev = vec![INF; (e + 1) * (k + 1)];
        // Base: 0 stages serve 0 buckets with any instance count >= 0.
        for ee in 0..=e {
            prev[idx(ee, 0)] = 0.0;
        }
        let mut choice: Vec<Vec<(usize, usize)>> = Vec::new(); // per stage level: (e', l') at (e,l)
        let mut best: Option<(f64, usize, usize)> = None; // (quality, stages, level snapshot idx)
        let mut layers: Vec<Vec<f64>> = vec![prev.clone()];

        let max_stages = e.min(k);
        for s in 1..=max_stages {
            let mut cur = vec![INF; (e + 1) * (k + 1)];
            let mut ch = vec![(0usize, 0usize); (e + 1) * (k + 1)];
            for ee in s..=e {
                for ll in s..=k {
                    let mut bv = INF;
                    let mut barg = (0usize, 0usize);
                    for ep in (s - 1)..ee {
                        for lp in (s - 1)..ll {
                            let base = prev[idx(ep, lp)];
                            if !base.is_finite() {
                                continue;
                            }
                            let agg = range(lp, ll);
                            // Stage quality over the instance subrange
                            // (ep..ee]: uniform fleets take the exact
                            // historical code path (bit-identical
                            // float ops), heterogeneous ones price the
                            // capacity-weighted set division.
                            let stage = if uniform {
                                self.stage_cost(agg, ee - ep)
                            } else {
                                let sum_rel = if prefix_caps {
                                    (cap_pref[ee] - cap_pref[ep]) / fleet_mean
                                } else {
                                    caps[ep..ee].iter().map(|c| c / fleet_mean).sum()
                                };
                                self.stage_cost_weighted(agg, ee - ep, sum_rel)
                            };
                            let cut = if lp == 0 {
                                0.0
                            } else {
                                self.migration.cut_cost(hist.bounds[lp - 1], crossings(lp))
                            };
                            let v = base + stage + cut;
                            if v < bv {
                                bv = v;
                                barg = (ep, lp);
                            }
                        }
                    }
                    cur[idx(ee, ll)] = bv;
                    ch[idx(ee, ll)] = barg;
                }
            }
            let q = cur[idx(e, k)];
            if q.is_finite() && best.map(|(b, _, _)| q < b).unwrap_or(true) {
                best = Some((q, s, layers.len()));
            }
            choice.push(ch);
            layers.push(cur.clone());
            prev = cur;
        }

        let (quality, n_stages, _) = best.expect("at least one feasible pipeline");
        // Reconstruct boundaries by walking the choice tables.
        let mut stages_rev: Vec<StageSpec> = Vec::new();
        let (mut ee, mut ll) = (e, k);
        for s in (1..=n_stages).rev() {
            let (ep, lp) = choice[s - 1][idx(ee, ll)];
            let lo = if lp == 0 { 0 } else { hist.bounds[lp - 1] };
            let hi = hist.bounds[ll - 1];
            stages_rev.push(StageSpec { lo, hi, n_instances: ee - ep });
            ee = ep;
            ll = lp;
        }
        stages_rev.reverse();
        // First stage starts at 0.
        if let Some(first) = stages_rev.first_mut() {
            first.lo = 0;
        }
        Pipeline { stages: stages_rev, predicted_quality: quality }
    }

    /// Tensor-parallel-aware exact DP: partition an ordered instance
    /// list described by [`PlanInstance`]s (capacity + KV pool +
    /// collective premium) over the histogram's buckets.
    ///
    /// Same recurrence and state space as
    /// [`Planner::plan_dp_weighted`], with two TP terms in the stage
    /// cost ([`TpPlanCtx::stage`]):
    ///
    /// * **KV feasibility pressure** — a stage must hold its longest
    ///   resident sequences, so its cost scales by
    ///   `max(1, hi / min member KV)`.  Length ranges that outgrow a
    ///   TP1 instance's pool are steeply penalized there and gravitate
    ///   to the TP-sharded stages that can actually hold their KV
    ///   (list the sharded instances *last*: stages are contiguous in
    ///   instance order and long ranges sit at the end).
    /// * **Collective premium** — the stage's generated tokens pay the
    ///   capacity-share-weighted mean `comm_s_per_token` of its
    ///   members, so the DP only concentrates load on sharded
    ///   instances when their KV/throughput advantage covers the
    ///   all-reduce cost.  The term is additive and linear in the comm
    ///   weights, so predicted quality degrades monotonically as TP
    ///   communication grows.
    ///
    /// With [`PlanInstance::uniform`] members (ample KV, zero comm)
    /// every stage prices exactly like `plan_dp_weighted` — the
    /// pressure multiplier is exactly 1.0 and the comm term exactly
    /// 0.0, both bit-transparent — and the cluster additionally gates
    /// TP-free fleets onto the legacy entry point so bit-identity
    /// never rests on this arithmetic.
    ///
    /// The DP skeleton deliberately *mirrors* `plan_dp_weighted_impl`
    /// instead of sharing it: the legacy float path must stay
    /// untouched, and the
    /// `dp_instances_with_trivial_extras_matches_plan_dp_weighted`
    /// test pins the two skeletons bit-equal so they cannot silently
    /// drift apart.
    pub fn plan_dp_instances(&self, hist: &LengthHistogram, insts: &[PlanInstance]) -> Pipeline {
        let e = insts.len();
        assert!(e >= 1);
        debug_assert!(
            insts.iter().all(|i| {
                i.cap.is_finite()
                    && i.cap > 0.0
                    && i.kv_tokens > 0.0
                    && i.comm_s_per_token >= 0.0
            }),
            "invalid plan instances: {insts:?}"
        );
        let k = hist.bounds.len();
        if k == 0 {
            return Pipeline {
                stages: vec![StageSpec { lo: 0, hi: Tokens::MAX, n_instances: e }],
                predicted_quality: 0.0,
            };
        }
        let ctx = TpPlanCtx::new(self, hist, insts);

        const INF: f64 = f64::INFINITY;
        let idx = |ee: usize, ll: usize| ee * (k + 1) + ll;
        let mut prev = vec![INF; (e + 1) * (k + 1)];
        // Base: 0 stages serve 0 buckets with any instance count >= 0
        // (same prefix-skip freedom as the weighted DP).
        for ee in 0..=e {
            prev[idx(ee, 0)] = 0.0;
        }
        let mut choice: Vec<Vec<(usize, usize)>> = Vec::new();
        let mut best: Option<(f64, usize)> = None;
        let max_stages = e.min(k);
        for s in 1..=max_stages {
            let mut cur = vec![INF; (e + 1) * (k + 1)];
            let mut ch = vec![(0usize, 0usize); (e + 1) * (k + 1)];
            for ee in s..=e {
                for ll in s..=k {
                    let mut bv = INF;
                    let mut barg = (0usize, 0usize);
                    for ep in (s - 1)..ee {
                        for lp in (s - 1)..ll {
                            let base = prev[idx(ep, lp)];
                            if !base.is_finite() {
                                continue;
                            }
                            let v = base + ctx.stage(ep, ee, lp, ll) + ctx.cut(lp);
                            if v < bv {
                                bv = v;
                                barg = (ep, lp);
                            }
                        }
                    }
                    cur[idx(ee, ll)] = bv;
                    ch[idx(ee, ll)] = barg;
                }
            }
            let q = cur[idx(e, k)];
            if q.is_finite() && best.map(|(b, _)| q < b).unwrap_or(true) {
                best = Some((q, s));
            }
            choice.push(ch);
            prev = cur;
        }

        let (quality, n_stages) = best.expect("at least one feasible pipeline");
        let mut stages_rev: Vec<StageSpec> = Vec::new();
        let (mut ee, mut ll) = (e, k);
        for s in (1..=n_stages).rev() {
            let (ep, lp) = choice[s - 1][idx(ee, ll)];
            let lo = if lp == 0 { 0 } else { hist.bounds[lp - 1] };
            let hi = hist.bounds[ll - 1];
            stages_rev.push(StageSpec { lo, hi, n_instances: ee - ep });
            ee = ep;
            ll = lp;
        }
        stages_rev.reverse();
        if let Some(first) = stages_rev.first_mut() {
            first.lo = 0;
        }
        // The base case allows an unused instance *prefix* (inherited
        // from the weighted DP, where extra instances never hurt a
        // stage).  Under KV pressure skipping can be genuinely optimal
        // — but a cluster needs every instance owned by some stage, so
        // fold any skipped prefix into the first (shortest-range)
        // stage, exactly where a low-KV instance is least harmful.
        let assigned: usize = stages_rev.iter().map(|s| s.n_instances).sum();
        if assigned < e {
            if let Some(first) = stages_rev.first_mut() {
                first.n_instances += e - assigned;
            }
        }
        Pipeline { stages: stages_rev, predicted_quality: quality }
    }

    /// Brute-force reference for [`Planner::plan_dp_instances`]:
    /// enumerate every contiguous (instance, bucket) partition —
    /// including the DP's prefix-skip freedom — and price each with
    /// the exact same [`TpPlanCtx`] arithmetic, accumulated in the
    /// same stage order.  Exponential; property-test sizes only.
    /// Tie-breaking between equal-quality layouts may differ from the
    /// DP, so compare `predicted_quality`, not stages.
    #[doc(hidden)]
    pub fn plan_exhaustive_instances(
        &self,
        hist: &LengthHistogram,
        insts: &[PlanInstance],
    ) -> Pipeline {
        let e = insts.len();
        assert!(e >= 1);
        let k = hist.bounds.len();
        if k == 0 {
            return Pipeline {
                stages: vec![StageSpec { lo: 0, hi: Tokens::MAX, n_instances: e }],
                predicted_quality: 0.0,
            };
        }
        let ctx = TpPlanCtx::new(self, hist, insts);
        let max_stages = e.min(k);

        #[allow(clippy::too_many_arguments)]
        fn go(
            ctx: &TpPlanCtx<'_>,
            e: usize,
            k: usize,
            max_stages: usize,
            ep: usize,
            lp: usize,
            acc: f64,
            n_stages: usize,
            trail: &mut Vec<(usize, usize, usize, usize)>,
            best: &mut Option<(f64, Vec<(usize, usize, usize, usize)>)>,
        ) {
            if ep == e && lp == k {
                if best.as_ref().map(|(b, _)| acc < *b).unwrap_or(true) {
                    *best = Some((acc, trail.clone()));
                }
                return;
            }
            if n_stages == max_stages || ep == e || lp == k {
                return;
            }
            for ee in (ep + 1)..=e {
                for ll in (lp + 1)..=k {
                    let v = acc + ctx.stage(ep, ee, lp, ll) + ctx.cut(lp);
                    trail.push((ep, ee, lp, ll));
                    go(ctx, e, k, max_stages, ee, ll, v, n_stages + 1, trail, best);
                    trail.pop();
                }
            }
        }

        let mut best: Option<(f64, Vec<(usize, usize, usize, usize)>)> = None;
        // The DP's base case allows any unused instance *prefix*;
        // mirror it so neither side can find a layout the other
        // cannot express.
        for ep0 in 0..e {
            let mut trail = Vec::new();
            go(&ctx, e, k, max_stages, ep0, 0, 0.0, 0, &mut trail, &mut best);
        }
        let (quality, trail) = best.expect("at least one feasible pipeline");
        let mut stages: Vec<StageSpec> = trail
            .iter()
            .map(|&(ep, ee, lp, ll)| StageSpec {
                lo: if lp == 0 { 0 } else { hist.bounds[lp - 1] },
                hi: hist.bounds[ll - 1],
                n_instances: ee - ep,
            })
            .collect();
        if let Some(first) = stages.first_mut() {
            first.lo = 0;
        }
        // Fold a skipped instance prefix into the first stage, like
        // the DP does (structural parity; quality is the raw optimum).
        let assigned: usize = stages.iter().map(|s| s.n_instances).sum();
        if assigned < e {
            if let Some(first) = stages.first_mut() {
                first.n_instances += e - assigned;
            }
        }
        Pipeline { stages, predicted_quality: quality }
    }

    /// The naive `O(E^3 L^2)` DP over raw cut points `0..=max_len` at
    /// `granularity`-token resolution. Exists to regenerate the §6.5
    /// complexity comparison — do not use at L=128K granularity 1.
    pub fn plan_exact_fine(
        &self,
        reqs: &[(Tokens, Tokens)], // (input_len, final_len)
        e: usize,
        max_len: Tokens,
        granularity: Tokens,
    ) -> Pipeline {
        // Build a fine-grained "histogram" with one bucket per
        // granularity step, then run the same DP.
        let g = granularity.max(1);
        let n_buckets = max_len.div_ceil(g) as usize;
        let bounds: Vec<Tokens> = (1..=n_buckets as Tokens).map(|i| (i * g).min(max_len)).collect();
        let mut hist = LengthHistogram::new(bounds);
        for &(i, f) in reqs {
            hist.push(i, f);
        }
        self.plan_dp(&hist, e)
    }

    /// Two-phase heuristic (§4.2 second optimization).
    ///
    /// Phase 1: chain DP with exactly one instance per stage over the
    /// bucket boundaries (E stages for E instances).  Phase 2: greedily
    /// merge the adjacent stage pair with the highest positive merge
    /// gain until no merge improves predicted quality.
    pub fn plan_heuristic(&self, hist: &LengthHistogram, e: usize) -> Pipeline {
        assert!(e >= 1);
        let k = hist.bounds.len();
        let pref = hist.prefix();
        let range = |a: usize, b: usize| -> RangeAgg {
            RangeAgg {
                n: pref[b].0 - pref[a].0,
                sum_i: pref[b].1 - pref[a].1,
                sum_i2: pref[b].2 - pref[a].2,
                sum_l: pref[b].3 - pref[a].3,
            }
        };
        let total_n = pref[k].0;
        let cut_cost = |b: usize| {
            if b == 0 || b >= k {
                0.0
            } else {
                self.migration.cut_cost(hist.bounds[b - 1], total_n - pref[b].0)
            }
        };

        // --- Phase 1: chain DP. g[s][l] = best cost of covering the
        // first l buckets with s single-instance stages.
        let s_max = e.min(k);
        const INF: f64 = f64::INFINITY;
        let mut g = vec![vec![INF; k + 1]; s_max + 1];
        let mut ch = vec![vec![0usize; k + 1]; s_max + 1];
        g[0][0] = 0.0;
        for s in 1..=s_max {
            for ll in s..=k {
                let mut bv = INF;
                let mut barg = 0;
                for lp in (s - 1)..ll {
                    let base = g[s - 1][lp];
                    if !base.is_finite() {
                        continue;
                    }
                    let v = base + self.stage_cost(range(lp, ll), 1) + cut_cost(lp);
                    if v < bv {
                        bv = v;
                        barg = lp;
                    }
                }
                g[s][ll] = bv;
                ch[s][ll] = barg;
            }
        }
        // Pick the best stage count for the chain (instances beyond the
        // chain length get distributed during merging below by giving
        // the chain exactly min(e, k) stages and then rebalancing).
        let chain_stages = (1..=s_max)
            .filter(|&s| g[s][k].is_finite())
            .min_by(|&a, &b| g[a][k].total_cmp(&g[b][k]))
            .expect("feasible chain");
        // Reconstruct cuts.
        let mut cuts_rev = Vec::new();
        let mut ll = k;
        for s in (1..=chain_stages).rev() {
            let lp = ch[s][ll];
            cuts_rev.push((lp, ll));
            ll = lp;
        }
        cuts_rev.reverse();
        // Distribute instances over the chain's ranges by greedy
        // marginal gain (optimal for the convex per-stage QoE curve).
        let distribute = |ranges: &[(usize, usize)], e: usize| -> Vec<usize> {
            let mut inst = vec![1usize; ranges.len()];
            for _ in ranges.len()..e {
                let (imax, _) = ranges
                    .iter()
                    .enumerate()
                    .map(|(i, &(a, b))| {
                        let agg = range(a, b);
                        let gain = self.stage_cost(agg, inst[i]) - self.stage_cost(agg, inst[i] + 1);
                        (i, gain)
                    })
                    .max_by(|x, y| x.1.total_cmp(&y.1))
                    .unwrap();
                inst[imax] += 1;
            }
            inst
        };
        let ranges: Vec<(usize, usize)> = cuts_rev.clone();
        let inst = distribute(&ranges, e);
        let mut stages: Vec<(usize, usize, usize)> = ranges
            .iter()
            .zip(inst.iter())
            .map(|(&(a, b), &i)| (a, b, i))
            .collect();

        // --- Phase 2: greedy merge by best positive gain, re-running
        // the instance distribution for every trial layout.
        let plan_cost = |ranges: &[(usize, usize)], inst: &[usize]| -> f64 {
            let mut c = 0.0;
            for (i, (&(a, b), &k)) in ranges.iter().zip(inst.iter()).enumerate() {
                c += self.stage_cost(range(a, b), k);
                if i > 0 {
                    c += cut_cost(a);
                }
            }
            c
        };
        let mut ranges: Vec<(usize, usize)> = stages.iter().map(|&(a, b, _)| (a, b)).collect();
        let mut inst: Vec<usize> = stages.iter().map(|&(_, _, i)| i).collect();
        let mut cost = plan_cost(&ranges, &inst);
        loop {
            if ranges.len() == 1 {
                break;
            }
            let mut best: Option<(f64, usize, Vec<(usize, usize)>, Vec<usize>)> = None;
            for i in 0..ranges.len() - 1 {
                let mut trial: Vec<(usize, usize)> = ranges.clone();
                trial[i] = (trial[i].0, trial[i + 1].1);
                trial.remove(i + 1);
                let trial_inst = distribute(&trial, e);
                let c = plan_cost(&trial, &trial_inst);
                let gain = cost - c;
                if gain > 0.0 && best.as_ref().map(|(g, _, _, _)| gain > *g).unwrap_or(true) {
                    best = Some((gain, i, trial, trial_inst));
                }
            }
            let Some((gain, _i, trial, trial_inst)) = best else { break };
            ranges = trial;
            inst = trial_inst;
            cost -= gain;
        }
        stages = ranges
            .iter()
            .zip(inst.iter())
            .map(|(&(a, b), &k)| (a, b, k))
            .collect();

        let specs: Vec<StageSpec> = stages
            .iter()
            .enumerate()
            .map(|(i, &(a, b, inst))| StageSpec {
                lo: if i == 0 { 0 } else { hist.bounds[a - 1] },
                hi: hist.bounds[b - 1],
                n_instances: inst,
            })
            .collect();
        Pipeline { stages: specs, predicted_quality: cost }
    }

    /// Predicted quality of an arbitrary pipeline under this planner's
    /// model (used by ablations to compare layouts on equal footing).
    pub fn pipeline_quality(&self, hist: &LengthHistogram, p: &Pipeline) -> f64 {
        let pref = hist.prefix();
        let k = hist.bounds.len();
        let total_n = pref[k].0;
        let bucket_at = |len: Tokens| -> usize {
            // First bucket index whose bound >= len (prefix cut point).
            hist.bounds.iter().position(|&b| b >= len).map(|i| i + 1).unwrap_or(k)
        };
        let mut cost = 0.0;
        for (i, s) in p.stages.iter().enumerate() {
            let a = if i == 0 { 0 } else { bucket_at(s.lo) };
            let b = bucket_at(s.hi);
            let agg = RangeAgg {
                n: pref[b].0 - pref[a].0,
                sum_i: pref[b].1 - pref[a].1,
                sum_i2: pref[b].2 - pref[a].2,
                sum_l: pref[b].3 - pref[a].3,
            };
            cost += self.stage_cost(agg, s.n_instances);
            if i > 0 {
                cost += self.migration.cut_cost(s.lo, total_n - pref[a].0);
            }
        }
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qoe::QoeModel;
    use crate::workload::{generate, LengthHistogram, ShareGptLike};

    /// A QoE model shaped like real fits: constant + per-batch terms.
    fn qoe() -> QoeModel {
        QoeModel::new([5e-3, 2e-4, 1e-6, 1e-11, 2e-6])
    }

    fn hist() -> LengthHistogram {
        let reqs = generate(&ShareGptLike::default(), 10.0, 5000, 77);
        LengthHistogram::from_requests(&reqs, 131_072)
    }

    #[test]
    fn dp_uses_all_instances_and_covers_range() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&hist(), 8);
        assert_eq!(pipe.total_instances(), 8);
        assert_eq!(pipe.stages.first().unwrap().lo, 0);
        assert_eq!(pipe.stages.last().unwrap().hi, 131_072);
        // Stages are contiguous and increasing.
        for w in pipe.stages.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
            assert!(w[0].lo < w[0].hi);
        }
    }

    #[test]
    fn dp_prefers_multi_stage_on_skewed_load() {
        // With a skewed distribution and a QoE model that charges for
        // length heterogeneity (F4 term), the optimum is > 1 stage.
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&hist(), 16);
        assert!(pipe.stages.len() > 1, "expected a pipeline, got {:?}", pipe.stages);
        assert!(pipe.stages.len() <= 16);
    }

    #[test]
    fn dp_beats_no_pipeline_quality() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let pipe = p.plan_dp(&h, 16);
        let flat = Pipeline::no_pipeline(16, 131_072);
        assert!(
            pipe.predicted_quality <= p.pipeline_quality(&h, &flat) + 1e-9,
            "DP {} vs flat {}",
            pipe.predicted_quality,
            p.pipeline_quality(&h, &flat)
        );
    }

    #[test]
    fn migration_cost_discourages_cuts() {
        let h = hist();
        let free = Planner::new(qoe(), MigrationCost::free()).plan_dp(&h, 16);
        let pricey = Planner::new(
            qoe(),
            MigrationCost { kv_bytes_per_token: 114_688.0, link_bytes_per_s: 25e9, weight: 1000.0 },
        )
        .plan_dp(&h, 16);
        assert!(pricey.stages.len() <= free.stages.len());
    }

    #[test]
    fn single_instance_is_single_stage() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&hist(), 1);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.total_instances(), 1);
    }

    #[test]
    fn heuristic_matches_dp_closely() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let exact = p.plan_dp(&h, 16);
        let heur = p.plan_heuristic(&h, 16);
        assert_eq!(heur.total_instances(), 16);
        // The heuristic is within 25% of the exact optimum's quality.
        let exact_q = exact.predicted_quality;
        let heur_q = p.pipeline_quality(&h, &heur);
        assert!(
            heur_q <= exact_q * 1.25 + 1e-9,
            "heuristic {heur_q} vs exact {exact_q}"
        );
    }

    #[test]
    fn heuristic_much_faster_than_exact_fine() {
        // Structural check of the complexity claim: the heuristic
        // touches O(E log^2 L) states vs the fine DP's O(E^3 (L/g)^2).
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let t0 = std::time::Instant::now(); // detlint: allow(D3) -- wall-clock bound on a test-only complexity check, not simulated time
        let _ = p.plan_heuristic(&h, 16);
        let heur_t = t0.elapsed();
        let reqs: Vec<(u64, u64)> = generate(&ShareGptLike::default(), 10.0, 500, 3)
            .iter()
            .map(|r| (r.input_len, r.final_len()))
            .collect();
        let t0 = std::time::Instant::now(); // detlint: allow(D3) -- wall-clock bound on a test-only complexity check, not simulated time
        let _ = p.plan_exact_fine(&reqs, 8, 16_384, 512); // 32 cut points
        let fine_t = t0.elapsed();
        // Both should run, heuristic comfortably under a second.
        assert!(heur_t.as_secs_f64() < 1.0, "heuristic took {heur_t:?}");
        assert!(fine_t.as_secs_f64() < 60.0);
    }

    #[test]
    fn stage_for_routes_by_length() {
        let pipe = Pipeline {
            stages: vec![
                StageSpec { lo: 0, hi: 1024, n_instances: 2 },
                StageSpec { lo: 1024, hi: 8192, n_instances: 2 },
                StageSpec { lo: 8192, hi: 131_072, n_instances: 1 },
            ],
            predicted_quality: 0.0,
        };
        assert_eq!(pipe.stage_for(0), 0);
        assert_eq!(pipe.stage_for(1023), 0);
        assert_eq!(pipe.stage_for(1024), 1);
        assert_eq!(pipe.stage_for(100_000), 2);
        assert_eq!(pipe.stage_for(999_999_999), 2);
        assert_eq!(pipe.boundaries(), vec![1024, 8192]);
    }

    #[test]
    fn empty_histogram_still_plans() {
        let h = LengthHistogram::new(LengthHistogram::exponential_bounds(131_072));
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&h, 4);
        assert_eq!(pipe.total_instances(), 4);
    }

    #[test]
    fn empty_histogram_plans_single_stage() {
        // No observed requests: the only defensible layout is one
        // stage holding every instance (no data to cut on).
        let h = LengthHistogram::new(LengthHistogram::exponential_bounds(131_072));
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&h, 4);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.stages[0].n_instances, 4);
        assert_eq!(pipe.stages[0].lo, 0);
    }

    #[test]
    fn no_bucket_histogram_plans_single_stage() {
        // Degenerate histogram with zero buckets: previously this fell
        // through to a "no feasible pipeline" panic.
        let h = LengthHistogram::new(Vec::new());
        let p = Planner::new(qoe(), MigrationCost::free());
        for e in [1, 4] {
            let pipe = p.plan_dp(&h, e);
            assert_eq!(pipe.stages.len(), 1);
            assert_eq!(pipe.total_instances(), e);
            assert_eq!(pipe.stages[0].lo, 0);
            assert!(pipe.boundaries().is_empty());
        }
    }

    #[test]
    fn single_bucket_histogram_plans_single_stage() {
        let mut h = LengthHistogram::new(vec![131_072]);
        h.push(100, 500);
        h.push(2000, 9000);
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp(&h, 8);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.stages[0], StageSpec { lo: 0, hi: 131_072, n_instances: 8 });
    }

    #[test]
    fn exact_fine_empty_requests_single_stage() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_exact_fine(&[], 4, 16_384, 512);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.total_instances(), 4);
        // Degenerate zero-length range collapses to zero buckets; still
        // a valid single-stage answer rather than a panic.
        let pipe = p.plan_exact_fine(&[], 2, 0, 512);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.total_instances(), 2);
    }

    #[test]
    fn weighted_dp_with_uniform_caps_matches_plan_dp() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let a = p.plan_dp(&h, 8);
        let b = p.plan_dp_weighted(&h, &[3.7; 8]);
        assert_eq!(a.stages, b.stages);
        assert_eq!(a.predicted_quality.to_bits(), b.predicted_quality.to_bits());
    }

    #[test]
    fn weighted_dp_heterogeneous_is_valid_and_contiguous() {
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        // 6 slow + 2 fast instances (an h20:6,h100:2-shaped fleet).
        let caps = [0.35, 0.35, 0.35, 0.35, 0.35, 0.35, 1.0, 1.0];
        let pipe = p.plan_dp_weighted(&h, &caps);
        assert_eq!(pipe.total_instances(), 8);
        assert_eq!(pipe.stages.first().unwrap().lo, 0);
        assert_eq!(pipe.stages.last().unwrap().hi, 131_072);
        for w in pipe.stages.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
            assert!(w[0].lo < w[0].hi);
        }
        assert!(pipe.predicted_quality.is_finite());
    }

    /// Sum of relative speeds the production DP derives from prefix
    /// sums; tests compute it directly.
    fn sum_rel(caps: &[f64], fleet_mean: f64) -> f64 {
        caps.iter().map(|c| c / fleet_mean).sum()
    }

    #[test]
    fn weighted_stage_cost_reduces_to_even_split_for_uniform_caps() {
        // At the fleet mean, the speed discount is exactly 1: the cost
        // is the paper's k * Q^{n/k} even set division, bit for bit.
        let p = Planner::new(qoe(), MigrationCost::free());
        let agg = RangeAgg { n: 64.0, sum_i: 12_000.0, sum_i2: 9.0e6, sum_l: 40_000.0 };
        let even = p.stage_cost(agg, 4);
        let weighted = p.stage_cost_weighted(agg, 4, sum_rel(&[2.0; 4], 2.0));
        assert_eq!(even.to_bits(), weighted.to_bits());
    }

    #[test]
    fn weighted_stage_cost_prefers_capacity_where_load_is() {
        // Against a fleet mean of 1.0: a pair with an above-average
        // member prices *below* the even-split cost (the DP is drawn to
        // put heavy ranges there), a below-average pair prices above
        // it.
        let p = Planner::new(qoe(), MigrationCost::free());
        let agg = RangeAgg { n: 128.0, sum_i: 64_000.0, sum_i2: 4.0e7, sum_l: 300_000.0 };
        let even = p.stage_cost(agg, 2);
        let fast_pair = p.stage_cost_weighted(agg, 2, sum_rel(&[1.0, 3.0], 1.0));
        let slow_pair = p.stage_cost_weighted(agg, 2, sum_rel(&[0.5, 0.5], 1.0));
        assert!(
            fast_pair < even && even < slow_pair,
            "fast {fast_pair} < even {even} < slow {slow_pair}"
        );
        // The discount is the set's mean relative speed: (1+3)/2 = 2x.
        assert!((fast_pair * 2.0 - even).abs() <= 1e-12 * even.abs());
    }

    #[test]
    fn weighted_dp_prefix_sums_match_reference() {
        // Pin the prefix-sum optimization to the direct-summation
        // reference on the seed histograms: identical pipelines (the
        // float-op reassociation must not flip any DP choice).
        let p = Planner::new(qoe(), MigrationCost::free());
        for seed in [77u64, 5, 42] {
            let reqs = generate(&ShareGptLike::default(), 10.0, 3000, seed);
            let h = LengthHistogram::from_requests(&reqs, 131_072);
            for caps in [
                vec![0.35, 0.35, 0.35, 0.35, 0.35, 0.35, 1.0, 1.0],
                vec![1.0, 0.5, 0.25, 1.0, 0.5, 0.25, 1.0, 0.5],
                vec![0.9; 8],
            ] {
                let fast = p.plan_dp_weighted(&h, &caps);
                let reference = p.plan_dp_weighted_reference(&h, &caps);
                assert_eq!(fast.stages, reference.stages, "seed {seed}, caps {caps:?}");
                // The TP-aware DP with trivial extras sits in the same
                // equivalence class (chains it to the pinned
                // direct-summation reference).
                let insts: Vec<PlanInstance> =
                    caps.iter().map(|&c| PlanInstance::uniform(c)).collect();
                let tp = p.plan_dp_instances(&h, &insts);
                assert_eq!(tp.stages, reference.stages, "seed {seed}, caps {caps:?}");
            }
        }
    }

    #[test]
    fn dp_instances_with_trivial_extras_matches_plan_dp_weighted() {
        // PlanInstance::uniform / ample-KV fleets must price every
        // stage exactly like the weighted DP: the pressure multiplier
        // is exactly 1.0 and the comm term exactly 0.0, both
        // bit-transparent in IEEE 754.
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        for caps in [vec![1.0; 8], vec![0.35, 0.35, 0.35, 0.35, 0.35, 0.35, 1.0, 1.0]] {
            let insts: Vec<PlanInstance> =
                caps.iter().map(|&c| PlanInstance::uniform(c)).collect();
            let weighted = p.plan_dp_weighted(&h, &caps);
            let tp = p.plan_dp_instances(&h, &insts);
            assert_eq!(weighted.stages, tp.stages, "caps {caps:?}");
            assert_eq!(
                weighted.predicted_quality.to_bits(),
                tp.predicted_quality.to_bits(),
                "caps {caps:?}"
            );
            // Finite (non-infinite) ample KV behaves identically as
            // long as it covers the top bound.
            let insts: Vec<PlanInstance> = caps
                .iter()
                .map(|&c| PlanInstance { cap: c, kv_tokens: 1e9, comm_s_per_token: 0.0 })
                .collect();
            let tp = p.plan_dp_instances(&h, &insts);
            assert_eq!(weighted.stages, tp.stages);
            assert_eq!(weighted.predicted_quality.to_bits(), tp.predicted_quality.to_bits());
        }
    }

    #[test]
    fn kv_pressure_steers_long_ranges_to_big_kv_instances() {
        // Two KV-starved instances (pools of 2000 tokens) followed by
        // two ample ones: every stage whose range tops out above the
        // small pool must sit entirely on the ample tail — the 70B
        // story, where only TP-sharded slices can hold long-context
        // KV.
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let insts = [
            PlanInstance { cap: 1.0, kv_tokens: 2_000.0, comm_s_per_token: 0.0 },
            PlanInstance { cap: 1.0, kv_tokens: 2_000.0, comm_s_per_token: 0.0 },
            PlanInstance::uniform(1.0),
            PlanInstance::uniform(1.0),
        ];
        let pipe = p.plan_dp_instances(&h, &insts);
        assert_eq!(pipe.total_instances(), 4);
        assert!(pipe.stages.len() > 1, "{:?}", pipe.stages);
        let mut start = 0usize;
        for s in &pipe.stages {
            // Stages whose upper bound exceeds the starved pool (with
            // slack for the adjacent exponential bucket) must start at
            // or after the ample suffix.
            if s.hi > 4096 {
                assert!(
                    start >= 2,
                    "stage {s:?} starting at instance {start} includes a KV-starved member: {:?}",
                    pipe.stages
                );
            }
            start += s.n_instances;
        }
    }

    #[test]
    fn dp_instances_quality_degrades_monotonically_in_comm_cost() {
        // The collective premium is additive and linear in the comm
        // weights, so the optimum over partitions is monotone in a
        // global comm scale.
        let p = Planner::new(qoe(), MigrationCost::free());
        let h = hist();
        let mut last = f64::NEG_INFINITY;
        for scale in [0.0, 1e-7, 1e-6, 1e-5, 1e-4] {
            let insts: Vec<PlanInstance> = (0..8)
                .map(|i| PlanInstance {
                    cap: if i >= 6 { 2.0 } else { 1.0 },
                    kv_tokens: f64::INFINITY,
                    comm_s_per_token: if i >= 6 { scale } else { 0.0 },
                })
                .collect();
            let q = p.plan_dp_instances(&h, &insts).predicted_quality;
            assert!(q.is_finite());
            assert!(
                q >= last - 1e-12,
                "quality must not improve as comm grows: {q} after {last} at {scale}"
            );
            last = q;
        }
    }

    #[test]
    fn dp_instances_no_bucket_histogram_plans_single_stage() {
        let h = LengthHistogram::new(Vec::new());
        let p = Planner::new(qoe(), MigrationCost::free());
        let pipe = p.plan_dp_instances(&h, &[PlanInstance::uniform(1.0); 4]);
        assert_eq!(pipe.stages.len(), 1);
        assert_eq!(pipe.total_instances(), 4);
    }

    #[test]
    fn stage_for_binary_search_matches_linear_reference() {
        use crate::sim::Rng;
        let mut rng = Rng::new(0x57A6E);
        for _ in 0..200 {
            // Random contiguous ascending stages.
            let n = 1 + rng.next_range(8) as usize;
            let mut lo = 0u64;
            let mut stages = Vec::new();
            for _ in 0..n {
                let hi = lo + 1 + rng.next_range(4000);
                stages.push(StageSpec { lo, hi, n_instances: 1 });
                lo = hi;
            }
            let pipe = Pipeline { stages, predicted_quality: 0.0 };
            for _ in 0..32 {
                let len = rng.next_range(lo + 100);
                // Linear reference: first stage with len < hi, else last.
                let want = pipe
                    .stages
                    .iter()
                    .position(|s| len < s.hi)
                    .unwrap_or(pipe.stages.len() - 1);
                assert_eq!(pipe.stage_for(len), want, "len {len} in {:?}", pipe.stages);
            }
        }
    }
}
