//! Adaptive range refinement — §4.3.
//!
//! Each instance periodically recomputes the boundary between its own
//! length range and its successors'.  The refinement:
//!
//! 1. averages the successor stage's workload (union of successor
//!    sequence lengths divided evenly by successor count, using the
//!    §4.2 set-division approximation),
//! 2. merges it with the local sequence lengths, sorts the union as a
//!    list `R`, and
//! 3. picks the split index minimising `Q^{R[:i]} + Q^{R[i:]}` under
//!    the QoE model (Eq. 1),
//!
//! with three stabilisers: initialisation from the offline plan, EMA
//! smoothing of boundary updates, and freezing under low traffic
//! (fewer than [`RefineConfig::min_requests`] samples).

use crate::qoe::{Features, QoeModel};
use crate::Tokens;

/// One sequence as (input_len, current_len).
pub type SeqLen = (Tokens, Tokens);

#[derive(Debug, Clone, Copy)]
pub struct RefineConfig {
    /// EMA smoothing factor for boundary updates in (0, 1]; 1 = jump.
    pub ema_alpha: f64,
    /// Freeze refinement below this many merged samples (§4.3: "fewer
    /// than five requests").
    pub min_requests: usize,
}

impl Default for RefineConfig {
    fn default() -> Self {
        Self { ema_alpha: 0.3, min_requests: 5 }
    }
}

/// Stateful per-boundary refiner.
#[derive(Debug, Clone)]
pub struct RangeRefiner {
    pub cfg: RefineConfig,
    pub qoe: QoeModel,
    /// Current smoothed boundary.
    pub boundary: Tokens,
}

impl RangeRefiner {
    /// Initialise from the offline pipeline-planning boundary (§4.3
    /// stabiliser #1).
    pub fn new(qoe: QoeModel, initial_boundary: Tokens, cfg: RefineConfig) -> Self {
        Self { cfg, qoe, boundary: initial_boundary }
    }

    /// The §4.2 set-division approximation: sort, start at the
    /// (n/2)-th element, take every n-th — yielding a representative
    /// 1/n-subset of the set.
    pub fn divide_set(mut lens: Vec<SeqLen>, n: usize) -> Vec<SeqLen> {
        if n <= 1 || lens.is_empty() {
            return lens;
        }
        lens.sort_by_key(|&(_, l)| l);
        lens.iter().skip(n / 2).step_by(n).copied().collect()
    }

    /// Optimal split of the sorted union `r` under the QoE model:
    /// returns (index, quality).  Index `i` means `r[..i]` stays local,
    /// `r[i..]` goes downstream.
    pub fn optimal_split(&self, r: &[SeqLen]) -> (usize, f64) {
        // Prefix features for O(1) range queries.
        let n = r.len();
        let mut pre = Vec::with_capacity(n + 1);
        let mut acc = (0.0f64, 0.0f64, 0.0f64); // sumI, sumI2, sumL
        pre.push(acc);
        for &(i, l) in r {
            acc.0 += i as f64;
            acc.1 += (i as f64) * (i as f64);
            acc.2 += l as f64;
            pre.push(acc);
        }
        let q_range = |a: usize, b: usize| -> f64 {
            if a == b {
                return 0.0;
            }
            let f = Features([
                1.0,
                (b - a) as f64,
                pre[b].0 - pre[a].0,
                pre[b].1 - pre[a].1,
                pre[b].2 - pre[a].2,
            ]);
            self.qoe.batch_qoe(&f)
        };
        let mut best = (0usize, f64::INFINITY);
        for i in 0..=n {
            let q = q_range(0, i) + q_range(i, n);
            if q < best.1 {
                best = (i, q);
            }
        }
        best
    }

    /// Instance-count-weighted split: evaluate `Q^{left/k_left} +
    /// Q^{right/k_right}` (Eq. 1 + the §4.2 even set division) so a
    /// 14-instance stage and a 1-instance stage are compared by
    /// *per-instance* quality. Returns (index, quality) over `r`.
    pub fn optimal_split_weighted(
        &self,
        r: &[SeqLen],
        k_left: usize,
        k_right: usize,
    ) -> (usize, f64) {
        let n = r.len();
        let mut pre = Vec::with_capacity(n + 1);
        let mut acc = (0.0f64, 0.0f64, 0.0f64);
        pre.push(acc);
        for &(i, l) in r {
            acc.0 += i as f64;
            acc.1 += (i as f64) * (i as f64);
            acc.2 += l as f64;
            pre.push(acc);
        }
        let q_range = |a: usize, b: usize, k: usize| -> f64 {
            if a == b {
                return 0.0;
            }
            let f = Features([
                1.0,
                (b - a) as f64,
                pre[b].0 - pre[a].0,
                pre[b].1 - pre[a].1,
                pre[b].2 - pre[a].2,
            ]);
            self.qoe.split_batch_qoe(&f, k)
        };
        let mut best = (0usize, f64::INFINITY);
        for i in 0..=n {
            let q = q_range(0, i, k_left) + q_range(i, n, k_right);
            if q < best.1 {
                best = (i, q);
            }
        }
        best
    }

    /// Refinement over full stage unions with explicit instance counts
    /// (the multi-instance-stage generalisation of `refine`).
    pub fn refine_weighted(
        &mut self,
        local_union: Vec<SeqLen>,
        succ_union: Vec<SeqLen>,
        k_local: usize,
        k_succ: usize,
    ) -> Tokens {
        let mut merged: Vec<SeqLen> =
            local_union.into_iter().chain(succ_union).collect();
        if merged.len() < self.cfg.min_requests {
            return self.boundary;
        }
        merged.sort_by_key(|&(_, l)| l);
        let (split, _q) =
            self.optimal_split_weighted(&merged, k_local.max(1), k_succ.max(1));
        let raw_boundary = if split >= merged.len() {
            merged.last().map(|&(_, l)| l + 1).unwrap_or(self.boundary)
        } else {
            merged[split].1
        };
        let a = self.cfg.ema_alpha;
        let smoothed = (1.0 - a) * self.boundary as f64 + a * raw_boundary as f64;
        self.boundary = smoothed.round().max(1.0) as Tokens;
        self.boundary
    }

    /// Run one refinement round.
    ///
    /// * `local` — this instance's live sequence lengths.
    /// * `successors` — each successor instance's live lengths.
    ///
    /// Returns the new (smoothed) boundary; `self.boundary` updates.
    pub fn refine(&mut self, local: &[SeqLen], successors: &[Vec<SeqLen>]) -> Tokens {
        // Average successor workload: union ÷ successor count.
        let succ_union: Vec<SeqLen> = successors.iter().flatten().copied().collect();
        let succ_avg = Self::divide_set(succ_union, successors.len().max(1));

        let mut merged: Vec<SeqLen> = local.iter().copied().chain(succ_avg).collect();
        if merged.len() < self.cfg.min_requests {
            // Low-traffic freeze (§4.3 stabiliser #3).
            return self.boundary;
        }
        merged.sort_by_key(|&(_, l)| l);
        let (split, _q) = self.optimal_split(&merged);

        // Boundary = length at the optimal split point. A split at the
        // very end means "keep everything local": push the boundary to
        // the largest observed length + 1.
        let raw_boundary = if split >= merged.len() {
            merged.last().map(|&(_, l)| l + 1).unwrap_or(self.boundary)
        } else {
            merged[split].1
        };

        // EMA smoothing (§4.3 stabiliser #2).
        let a = self.cfg.ema_alpha;
        let smoothed = (1.0 - a) * self.boundary as f64 + a * raw_boundary as f64;
        self.boundary = smoothed.round().max(1.0) as Tokens;
        self.boundary
    }
}

/// Ablation policies of Fig. 15.
pub mod naive {
    use super::SeqLen;
    use crate::Tokens;

    /// Quantity-based refinement: split so both sides hold the same
    /// *number* of requests.
    pub fn quantity_boundary(merged_sorted: &[SeqLen]) -> Option<Tokens> {
        if merged_sorted.is_empty() {
            return None;
        }
        Some(merged_sorted[merged_sorted.len() / 2].1)
    }

    /// Memory-based refinement: split so both sides hold roughly the
    /// same total cached tokens (memory).
    pub fn memory_boundary(merged_sorted: &[SeqLen]) -> Option<Tokens> {
        if merged_sorted.is_empty() {
            return None;
        }
        let total: u64 = merged_sorted.iter().map(|&(_, l)| l).sum();
        let mut acc = 0u64;
        for &(_, l) in merged_sorted {
            acc += l;
            if acc * 2 >= total {
                return Some(l);
            }
        }
        merged_sorted.last().map(|&(_, l)| l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qoe::QoeModel;

    fn qoe() -> QoeModel {
        // Constant per-batch cost + per-token terms: favours splitting
        // long from short.
        QoeModel::new([1e-3, 1e-4, 1e-6, 1e-11, 5e-6])
    }

    fn lens(v: &[u64]) -> Vec<SeqLen> {
        v.iter().map(|&l| (l / 2, l)).collect()
    }

    #[test]
    fn divide_set_picks_every_nth_from_middle() {
        let set = lens(&[10, 20, 30, 40, 50, 60, 70, 80]);
        let sub = RangeRefiner::divide_set(set, 4);
        // skip(2).step_by(4) over sorted: indices 2, 6.
        assert_eq!(sub.iter().map(|&(_, l)| l).collect::<Vec<_>>(), vec![30, 70]);
    }

    #[test]
    fn divide_by_one_is_identity() {
        let set = lens(&[5, 1, 3]);
        let sub = RangeRefiner::divide_set(set.clone(), 1);
        assert_eq!(sub, set);
    }

    #[test]
    fn optimal_split_separates_bimodal_lengths() {
        let r = RangeRefiner::new(qoe(), 1000, RefineConfig::default());
        let mut data = lens(&[100, 110, 120, 130, 10_000, 11_000, 12_000]);
        data.sort_by_key(|&(_, l)| l);
        let (split, _) = r.optimal_split(&data);
        // The optimum lands at the cluster boundary (exactly where the
        // clusters separate, +/- one element depending on the linear
        // model's n-interaction terms).
        assert!((4..=5).contains(&split), "split {split} not at the cluster gap");
    }

    #[test]
    fn refine_moves_boundary_toward_data() {
        let mut r = RangeRefiner::new(qoe(), 50_000, RefineConfig { ema_alpha: 1.0, min_requests: 5 });
        let local = lens(&[100, 200, 300, 400, 500]);
        let succ = vec![lens(&[20_000, 30_000, 40_000])];
        let b = r.refine(&local, &succ);
        assert!(b < 50_000, "boundary should drop toward the short cluster, got {b}");
        assert!(b > 500, "but not below the local lengths, got {b}");
    }

    #[test]
    fn ema_dampens_jumps() {
        let mut fast = RangeRefiner::new(qoe(), 10_000, RefineConfig { ema_alpha: 1.0, min_requests: 1 });
        let mut slow = RangeRefiner::new(qoe(), 10_000, RefineConfig { ema_alpha: 0.1, min_requests: 1 });
        let local = lens(&[100, 150, 200]);
        let succ = vec![lens(&[50_000, 60_000, 70_000])];
        let bf = fast.refine(&local, &succ);
        let bs = slow.refine(&local, &succ);
        // Slow refiner stays near the old boundary.
        assert!((bs as i64 - 10_000i64).abs() < (bf as i64 - 10_000i64).abs());
    }

    #[test]
    fn low_traffic_freezes_boundary() {
        let mut r = RangeRefiner::new(qoe(), 5000, RefineConfig::default());
        let local = lens(&[100, 200]); // only 2 < min_requests=5
        let b = r.refine(&local, &[]);
        assert_eq!(b, 5000, "boundary frozen under low traffic");
    }

    #[test]
    fn naive_quantity_balances_counts() {
        let mut data = lens(&[1, 2, 3, 4, 100, 200]);
        data.sort_by_key(|&(_, l)| l);
        let b = naive::quantity_boundary(&data).unwrap();
        assert_eq!(b, 4); // index 3 of 6
    }

    #[test]
    fn naive_memory_balances_tokens() {
        let mut data = lens(&[10, 10, 10, 1000]);
        data.sort_by_key(|&(_, l)| l);
        let b = naive::memory_boundary(&data).unwrap();
        assert_eq!(b, 1000, "one huge request dominates memory");
    }

    #[test]
    fn empty_inputs_survive() {
        let mut r = RangeRefiner::new(qoe(), 123, RefineConfig::default());
        assert_eq!(r.refine(&[], &[]), 123);
        assert_eq!(naive::quantity_boundary(&[]), None);
        assert_eq!(naive::memory_boundary(&[]), None);
    }
}
