//! Decentralized bid-ask load (re)balancing — §4.4.
//!
//! Senders (overloaded or handing-over instances) and receivers
//! negotiate pairwise, like transaction matching in a specialist
//! market:
//!
//! * **Ask** — the sender announces one request migration to all
//!   candidate receivers, piggybacking its own load (total length of
//!   its buffered requests).
//! * **Bid** — each receiver replies with its current load and its
//!   earliest transmission start time (buffered length ÷ measured
//!   throughput).
//! * **Selection** — the sender filters out the half of receivers with
//!   higher load, keeps the three earliest start times, and picks the
//!   one whose bid arrived first.
//! * **Confirm** — ownership transfers; the receiver enqueues the
//!   request in a priority queue ordered by *sender load* and drives
//!   the actual migration ([`crate::coordinator::migrate`]).
//!
//! Starvation guard: a receiver counts failed pull attempts per
//! request (sender busy transmitting another); past a threshold it
//! notifies the sender, which promotes the request to
//! send-immediately-after-current.
//!
//! Heterogeneous fleets: every load carried by the protocol messages
//! (`Ask::sender_load`, `Bid::load`, `PendingPull::priority`) is
//! **capacity-normalized** — raw token load divided by the instance's
//! relative capacity — so a fast H100 at 60% of its (larger) capacity
//! correctly outbids a saturating H20 at the same raw token count.  On
//! homogeneous fleets every capacity is exactly 1.0 and the normalized
//! values equal the raw token loads bit-for-bit.

use crate::{InstanceId, RequestId, Time, Tokens};
use std::collections::{BinaryHeap, HashMap};

/// Ask message: sender offers one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ask {
    pub sender: InstanceId,
    pub request: RequestId,
    pub seq_len: Tokens,
    /// Total length of all requests buffered at the sender, normalized
    /// by the sender's relative capacity.
    pub sender_load: f64,
}

/// Bid message: receiver's counter-offer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bid {
    pub receiver: InstanceId,
    pub request: RequestId,
    /// Receiver's current load (cached tokens + buffered migrations),
    /// normalized by the receiver's relative capacity.
    pub load: f64,
    /// Earliest time the receiver could start this transfer.
    pub earliest_start: Time,
    /// When the bid reached the sender (for first-reply tie-breaking).
    pub reply_at: Time,
}

/// The §4.4 selection rule. Returns the chosen receiver, or `None` if
/// there are no bids.
pub fn select_receiver(bids: &[Bid]) -> Option<InstanceId> {
    if bids.is_empty() {
        return None;
    }
    // 1. Filter out the half with higher (capacity-normalized) load —
    // keep ceil(n/2) lowest.  total_cmp: a NaN load sorts last instead
    // of panicking.
    let mut by_load: Vec<&Bid> = bids.iter().collect();
    by_load.sort_by(|a, b| {
        a.load
            .total_cmp(&b.load)
            .then(a.receiver.cmp(&b.receiver))
    });
    let keep = by_load.len().div_ceil(2);
    let low_half = &by_load[..keep];
    // 2. Keep the three earliest transmission start times.  total_cmp:
    // a receiver whose throughput estimate is still NaN/garbage at
    // startup must not panic selection — NaN sorts last and is simply
    // never picked ahead of a finite bid.
    let mut by_start: Vec<&&Bid> = low_half.iter().collect();
    by_start.sort_by(|a, b| {
        a.earliest_start
            .total_cmp(&b.earliest_start)
            .then(a.receiver.cmp(&b.receiver))
    });
    let top3 = &by_start[..by_start.len().min(3)];
    // 3. Of those, the first reply wins.
    top3.iter()
        .min_by(|a, b| {
            a.reply_at
                .total_cmp(&b.reply_at)
                .then(a.receiver.cmp(&b.receiver))
        })
        .map(|b| b.receiver)
}

/// A confirmed migration waiting in a receiver's priority queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PendingPull {
    pub sender: InstanceId,
    pub request: RequestId,
    pub seq_len: Tokens,
    /// Priority = sender's capacity-normalized load at confirm time
    /// (§4.4).
    pub priority: f64,
    pub failed_attempts: u32,
}

impl Eq for PendingPull {}

impl Ord for PendingPull {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap on priority (total_cmp: NaN-safe, total order);
        // deterministic tie-break on request id.
        self.priority
            .total_cmp(&other.priority)
            .then(other.request.cmp(&self.request))
    }
}

impl PartialOrd for PendingPull {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Receiver-side queue + starvation accounting.
#[derive(Debug, Clone)]
pub struct ReceiverQueue {
    heap: BinaryHeap<PendingPull>,
    /// Running sum of queued `seq_len`s, so [`Self::buffered_len`] is
    /// O(1) on the bid hot path instead of an O(queue) rescan.
    buffered: Tokens,
    /// Attempts threshold before the starvation escalation (§4.4).
    pub starvation_threshold: u32,
}

impl ReceiverQueue {
    pub fn new(starvation_threshold: u32) -> Self {
        Self { heap: BinaryHeap::new(), buffered: 0, starvation_threshold }
    }

    pub fn push(&mut self, pull: PendingPull) {
        self.buffered += pull.seq_len;
        self.heap.push(pull);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total buffered length (the "earliest start" numerator).
    /// Maintained incrementally; O(1).
    pub fn buffered_len(&self) -> Tokens {
        debug_assert_eq!(
            self.buffered,
            self.heap.iter().map(|p| p.seq_len).sum::<Tokens>()
        );
        self.buffered
    }

    /// Try to start the next migration.  `sender_busy(sender)` reports
    /// whether that sender is currently transmitting another request.
    ///
    /// Returns:
    /// * `Pull(p)` — start migrating `p` now,
    /// * `Starved(p)` — `p` exceeded the attempt threshold; the caller
    ///   must notify the sender and then wait (no further skipping),
    /// * `Idle` — nothing startable.
    pub fn next_action(&mut self, mut sender_busy: impl FnMut(InstanceId) -> bool) -> PullAction {
        let mut skipped: Vec<PendingPull> = Vec::new();
        let mut result = PullAction::Idle;
        while let Some(mut head) = self.heap.pop() {
            if !sender_busy(head.sender) {
                // Leaves the queue: hand to the caller for transfer.
                self.buffered -= head.seq_len;
                result = PullAction::Pull(head);
                break;
            }
            head.failed_attempts += 1;
            if head.failed_attempts >= self.starvation_threshold {
                self.buffered -= head.seq_len;
                result = PullAction::Starved(head);
                break;
            }
            skipped.push(head);
        }
        // Skipped pulls return to the queue; their buffered share never
        // left the running sum.
        for s in skipped {
            self.heap.push(s);
        }
        result
    }

    /// Re-insert a starved request while it waits for the sender's
    /// immediate-send promise.
    pub fn requeue(&mut self, pull: PendingPull) {
        self.push(pull);
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PullAction {
    Pull(PendingPull),
    Starved(PendingPull),
    Idle,
}

/// Sender-side offer bookkeeping: outstanding asks and collected bids.
#[derive(Debug, Clone, Default)]
pub struct SenderBook {
    /// request -> bids received so far.
    pending: HashMap<RequestId, Vec<Bid>>,
    /// request -> number of receivers asked.
    expected: HashMap<RequestId, usize>,
}

impl SenderBook {
    pub fn open(&mut self, request: RequestId, n_receivers: usize) {
        self.pending.insert(request, Vec::new());
        self.expected.insert(request, n_receivers);
    }

    /// Record a bid; returns `Some(receiver)` once all expected bids
    /// arrived and selection can run.
    pub fn record(&mut self, bid: Bid) -> Option<InstanceId> {
        let bids = self.pending.get_mut(&bid.request)?;
        bids.push(bid);
        if bids.len() >= *self.expected.get(&bid.request)? {
            let chosen = select_receiver(bids);
            self.pending.remove(&bid.request);
            self.expected.remove(&bid.request);
            chosen
        } else {
            None
        }
    }

    /// Force selection with whatever bids arrived (timeout path).
    pub fn close(&mut self, request: RequestId) -> Option<InstanceId> {
        let bids = self.pending.remove(&request)?;
        self.expected.remove(&request);
        select_receiver(&bids)
    }

    pub fn is_open(&self, request: RequestId) -> bool {
        self.pending.contains_key(&request)
    }
}

/// Snapshot of one instance's balance-relevant state, used by the
/// cluster to originate asks/bids without borrowing the engines.
#[derive(Debug, Clone, Copy)]
pub struct BidAskSnapshot {
    pub instance: InstanceId,
    pub token_load: Tokens,
    pub buffered_len: Tokens,
    pub throughput: f64,
}

impl BidAskSnapshot {
    /// The receiver's earliest transmission start (§4.4: buffered
    /// length over measured throughput).
    pub fn earliest_start(&self, now: Time) -> Time {
        now + self.buffered_len as f64 / self.throughput.max(1.0)
    }
}

/// Combined sender+receiver state machine (one per instance).
#[derive(Debug, Clone)]
pub struct BidAskScheduler {
    pub instance: InstanceId,
    pub sender: SenderBook,
    pub receiver: ReceiverQueue,
    /// Requests this instance promised to send immediately after its
    /// current transmission (starvation escalations).
    pub promised: Vec<RequestId>,
}

impl BidAskScheduler {
    pub fn new(instance: InstanceId, starvation_threshold: u32) -> Self {
        Self {
            instance,
            sender: SenderBook::default(),
            receiver: ReceiverQueue::new(starvation_threshold),
            promised: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bid(receiver: usize, load: f64, start: f64, reply: f64) -> Bid {
        Bid { receiver, request: 1, load, earliest_start: start, reply_at: reply }
    }

    #[test]
    fn selection_filters_high_load_half() {
        // Receivers 3,4 have much higher load and must be filtered even
        // though they reply first and start earliest.
        let bids = vec![
            bid(1, 100.0, 5.0, 5.0),
            bid(2, 120.0, 4.0, 4.0),
            bid(3, 900.0, 0.0, 0.0),
            bid(4, 950.0, 0.0, 0.0),
        ];
        let chosen = select_receiver(&bids).unwrap();
        assert!(chosen == 1 || chosen == 2);
        // Among the low half, earliest start then first reply: 2.
        assert_eq!(chosen, 2);
    }

    #[test]
    fn selection_top3_then_first_reply() {
        // 6 low-load receivers; keep 3 earliest starts {a,b,c}; first
        // reply among them wins.
        let bids = vec![
            bid(1, 10.0, 1.0, 9.0),
            bid(2, 10.0, 2.0, 1.0),
            bid(3, 10.0, 3.0, 2.0),
            bid(4, 10.0, 4.0, 0.1), // 4th earliest start — excluded
            bid(5, 11.0, 5.0, 0.1),
            bid(6, 11.0, 6.0, 0.1),
        ];
        assert_eq!(select_receiver(&bids), Some(2));
    }

    #[test]
    fn selection_single_bid() {
        assert_eq!(select_receiver(&[bid(7, 1.0, 0.0, 0.0)]), Some(7));
        assert_eq!(select_receiver(&[]), None);
    }

    #[test]
    fn selection_deterministic_on_ties() {
        let bids = vec![bid(2, 10.0, 1.0, 1.0), bid(1, 10.0, 1.0, 1.0)];
        // Ties broken by receiver id — stable across orderings.
        let a = select_receiver(&bids);
        let rev: Vec<Bid> = bids.into_iter().rev().collect();
        assert_eq!(a, select_receiver(&rev));
    }

    #[test]
    fn sender_book_waits_for_all_bids() {
        let mut book = SenderBook::default();
        book.open(1, 3);
        assert_eq!(book.record(bid(1, 10.0, 0.0, 0.0)), None);
        assert_eq!(book.record(bid(2, 20.0, 0.0, 0.1)), None);
        let chosen = book.record(bid(3, 30.0, 0.0, 0.2));
        assert!(chosen.is_some());
        assert!(!book.is_open(1));
    }

    #[test]
    fn sender_book_timeout_close() {
        let mut book = SenderBook::default();
        book.open(1, 5);
        book.record(bid(1, 10.0, 0.0, 0.0));
        assert_eq!(book.close(1), Some(1));
        assert_eq!(book.close(1), None, "already closed");
    }

    #[test]
    fn receiver_queue_orders_by_sender_load() {
        let mut q = ReceiverQueue::new(3);
        let p = |sender: usize, request: u64, priority: f64| PendingPull {
            sender,
            request,
            seq_len: 10,
            priority,
            failed_attempts: 0,
        };
        q.push(p(1, 1, 100.0));
        q.push(p(2, 2, 900.0));
        q.push(p(3, 3, 500.0));
        match q.next_action(|_| false) {
            PullAction::Pull(p) => assert_eq!(p.request, 2, "highest sender load first"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn receiver_skips_busy_sender() {
        let mut q = ReceiverQueue::new(5);
        let p = |sender: usize, request: u64, priority: f64| PendingPull {
            sender,
            request,
            seq_len: 10,
            priority,
            failed_attempts: 0,
        };
        q.push(p(1, 1, 900.0));
        q.push(p(2, 2, 100.0));
        // Sender 1 busy: queue skips to request 2.
        match q.next_action(|s| s == 1) {
            PullAction::Pull(p) => assert_eq!(p.request, 2),
            other => panic!("unexpected {other:?}"),
        }
        // Request 1 still queued with one failed attempt.
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn starvation_escalates_after_threshold() {
        let mut q = ReceiverQueue::new(2);
        let pull =
            PendingPull { sender: 1, request: 1, seq_len: 10, priority: 900.0, failed_attempts: 0 };
        q.push(pull);
        // Attempt 1: skipped.
        assert!(matches!(q.next_action(|_| true), PullAction::Idle));
        // Attempt 2: hits the threshold -> starved.
        match q.next_action(|_| true) {
            PullAction::Starved(p) => assert_eq!(p.request, 1),
            other => panic!("unexpected {other:?}"),
        }
        assert!(q.is_empty(), "starved pull handed to caller");
    }

    #[test]
    fn buffered_len_sums_queued() {
        let mut q = ReceiverQueue::new(3);
        let p = |request: u64, seq_len: u64, priority: f64| PendingPull {
            sender: 1,
            request,
            seq_len,
            priority,
            failed_attempts: 0,
        };
        q.push(p(1, 100, 1.0));
        q.push(p(2, 200, 2.0));
        assert_eq!(q.buffered_len(), 300);
    }

    #[test]
    fn earliest_start_uses_throughput() {
        let s = BidAskSnapshot { instance: 0, token_load: 0, buffered_len: 500, throughput: 100.0 };
        assert!((s.earliest_start(2.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn zero_throughput_earliest_start_is_finite() {
        // A receiver whose throughput EMA is still 0 at startup must
        // not produce an infinite/NaN earliest start: the divisor is
        // clamped to 1 token/s.
        let s = BidAskSnapshot { instance: 0, token_load: 0, buffered_len: 500, throughput: 0.0 };
        let t = s.earliest_start(1.0);
        assert!(t.is_finite());
        assert!((t - 501.0).abs() < 1e-9);
    }

    #[test]
    fn nan_bids_do_not_panic_and_never_beat_finite_bids() {
        // Pathological bids (NaN earliest_start / reply_at) must not
        // panic selection, and a finite bid of equal load must win.
        let nan_bid = |receiver: usize, load: f64| Bid {
            receiver,
            request: 1,
            load,
            earliest_start: f64::NAN,
            reply_at: f64::NAN,
        };
        let bids = vec![
            nan_bid(1, 10.0),
            bid(2, 10.0, 1.0, 1.0),
            bid(3, 900.0, 0.0, 0.0),
            bid(4, 900.0, 0.0, 0.0),
        ];
        assert_eq!(select_receiver(&bids), Some(2));
        // All-NaN still selects deterministically instead of panicking.
        let all_nan = vec![nan_bid(5, 1.0), nan_bid(6, 1.0)];
        assert!(select_receiver(&all_nan).is_some());
    }

    #[test]
    fn chosen_receiver_always_in_low_load_half() {
        // §4.4 invariant under random bids: whoever wins must belong to
        // the ceil(n/2) lowest-load subset.
        use crate::sim::Rng;
        use crate::testutil::for_all;
        for_all("bidask-low-half", 0xABBA, 128, |rng: &mut Rng| {
            let n = 1 + rng.next_range(8) as usize;
            let bids: Vec<Bid> = (0..n)
                .map(|i| Bid {
                    receiver: i,
                    request: 9,
                    load: rng.next_range(1000) as f64,
                    earliest_start: rng.next_f64(),
                    reply_at: rng.next_f64(),
                })
                .collect();
            let chosen = select_receiver(&bids).unwrap();
            let mut by_load: Vec<(f64, usize)> =
                bids.iter().map(|b| (b.load, b.receiver)).collect();
            by_load.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let keep = by_load.len().div_ceil(2);
            assert!(
                by_load[..keep].iter().any(|&(_, r)| r == chosen),
                "chosen {chosen} outside low half {by_load:?}"
            );
        });
    }

    #[test]
    fn buffered_len_incremental_tracks_push_pop_requeue() {
        let mut q = ReceiverQueue::new(2);
        let p = |request: u64, seq_len: u64, priority: f64| PendingPull {
            sender: 1,
            request,
            seq_len,
            priority,
            failed_attempts: 0,
        };
        q.push(p(1, 100, 5.0));
        q.push(p(2, 200, 9.0));
        assert_eq!(q.buffered_len(), 300);
        // Pull removes request 2 (highest priority): 200 leaves.
        match q.next_action(|_| false) {
            PullAction::Pull(got) => assert_eq!(got.request, 2),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(q.buffered_len(), 100);
        // Busy sender: skip leaves the sum unchanged.
        assert!(matches!(q.next_action(|_| true), PullAction::Idle));
        assert_eq!(q.buffered_len(), 100);
        // Second failed attempt hits the threshold: starved leaves.
        match q.next_action(|_| true) {
            PullAction::Starved(got) => {
                assert_eq!(got.request, 1);
                q.requeue(got);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(q.buffered_len(), 100, "requeue restores the sum");
    }
}
