//! The paper's L3 contribution: length-aware pipeline coordination.
//!
//! * [`plan`] — the §4.2 dynamic-programming stage partitioner (with
//!   the exponential-bucketing and two-phase-heuristic optimizations).
//! * [`refine`] — §4.3 adaptive range refinement with EMA smoothing
//!   and low-traffic freezing.
//! * [`balance`] — §4.4 decentralized bid-ask scheduling.
//! * [`migrate`] — §5 live KV migration with concurrency caps and
//!   starvation-aware backpressure.
//! * [`loadtracker`] — the per-instance token-level load monitor that
//!   feeds all of the above.

pub mod balance;
pub mod loadtracker;
pub mod migrate;
pub mod plan;
pub mod refine;

pub use balance::{BidAskScheduler, BidAskSnapshot};
pub use loadtracker::LoadTracker;
pub use migrate::{MigrationManager, Transfer};
pub use plan::{MigrationCost, Pipeline, Planner, StageSpec};
