//! LoadTracker — per-instance token-level workload monitor (§3.1).
//!
//! Each instance's LoadTracker records the token-level load of the
//! instance (cached tokens per live request), offers an optional
//! sliding-window reservoir of observed sequence lengths, and holds
//! the most recent load reports gossiped from peers (same stage) and
//! successors (next stage).  Staleness is explicit: every report
//! carries its timestamp, and consumers can discount or ignore reports
//! older than a threshold.
//!
//! Note: the cluster driver does NOT feed [`LoadTracker::observe_batch`]
//! on its hot path — boundary refinement reads live engine state
//! directly, and materialising the batch composition on every
//! `StepDone` was a measured O(batch) rescan for data nothing
//! consumed.  The reservoir stays available for offline tools and
//! diagnostics that want a length history.

use crate::{InstanceId, Time, Tokens};

/// A gossiped load report from one instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadReport {
    pub instance: InstanceId,
    pub at: Time,
    /// Total cached tokens across live sequences.
    pub token_load: Tokens,
    /// `token_load` divided by the instance's relative capacity — the
    /// value every cross-instance comparison (overload outliers, bid
    /// scoring) uses, so a fast instance is not declared overloaded for
    /// carrying its fair, larger share.  Equals `token_load as f64` on
    /// homogeneous fleets (capacity exactly 1.0).
    pub norm_load: f64,
    /// Live sequence count.
    pub n_seqs: usize,
    /// KV-pool utilization in [0,1].
    pub memory_demand: f64,
    /// Measured decode throughput, tokens/s (for bid earliest-start).
    pub throughput: f64,
}

/// Sliding-window sample of a sequence length observed on an instance.
#[derive(Debug, Clone, Copy)]
pub struct LengthSample {
    pub at: Time,
    pub input_len: Tokens,
    pub current_len: Tokens,
}

/// Bound on retained length samples: a reservoir this size is plenty
/// for boundary refinement while keeping `observe_batch` O(batch)
/// amortized (the unbounded version made sample GC the cluster
/// simulator's top hot spot — see EXPERIMENTS.md §Perf).
const MAX_SAMPLES: usize = 4096;

/// The per-instance tracker.
#[derive(Debug, Clone)]
pub struct LoadTracker {
    pub instance: InstanceId,
    /// Window length (seconds) for length samples.
    pub window: Time,
    samples: std::collections::VecDeque<LengthSample>,
    /// Freshest report per peer, kept sorted by instance id.  A sorted
    /// Vec (stage size ≤ instance count, typically ≤ 16) makes the
    /// overload probe allocation-free and — unlike a HashMap — gives a
    /// deterministic float-summation order, which the golden-seed
    /// regression relies on.
    peer_reports: Vec<LoadReport>,
    successor_reports: Vec<LoadReport>,
    /// Throughput estimate via exponentially weighted token rate.
    tokens_in_window: f64,
    last_rate_update: Time,
    rate_ema: f64,
}

/// Insert-or-replace into a Vec kept sorted by instance id, keeping
/// only the freshest report per instance.
fn upsert_report(reports: &mut Vec<LoadReport>, report: LoadReport) {
    match reports.binary_search_by_key(&report.instance, |r| r.instance) {
        Ok(i) => {
            if report.at >= reports[i].at {
                reports[i] = report;
            }
        }
        Err(i) => reports.insert(i, report),
    }
}

impl LoadTracker {
    pub fn new(instance: InstanceId, window: Time) -> Self {
        Self {
            instance,
            window,
            samples: std::collections::VecDeque::new(),
            peer_reports: Vec::new(),
            successor_reports: Vec::new(),
            tokens_in_window: 0.0,
            last_rate_update: 0.0,
            rate_ema: 0.0,
        }
    }

    /// Record the lengths of the instance's current batch.
    pub fn observe_batch(&mut self, now: Time, rows: &[(Tokens, Tokens)]) {
        for &(input_len, current_len) in rows {
            if self.samples.len() >= MAX_SAMPLES {
                self.samples.pop_front();
            }
            self.samples.push_back(LengthSample { at: now, input_len, current_len });
        }
    }

    /// Record `tokens` emitted at `now` (throughput estimation).
    pub fn observe_tokens(&mut self, now: Time, tokens: u64) {
        let dt = (now - self.last_rate_update).max(1e-9);
        if dt > 0.05 {
            let rate = self.tokens_in_window / dt;
            // EMA with ~1s time constant.
            let alpha = (dt / 1.0).min(1.0);
            self.rate_ema = (1.0 - alpha) * self.rate_ema + alpha * rate;
            self.tokens_in_window = 0.0;
            self.last_rate_update = now;
        }
        self.tokens_in_window += tokens as f64;
    }

    /// Current decode-throughput estimate (tokens/s).
    pub fn throughput(&self) -> f64 {
        self.rate_ema.max(1.0)
    }

    /// The in-window length samples (diagnostics / offline tooling —
    /// the cluster's boundary refinement reads live engine state, not
    /// this reservoir).  Age filtering happens lazily here, not on the
    /// write path.
    pub fn window_samples(&self, now: Time) -> Vec<LengthSample> {
        let cutoff = now - self.window;
        self.samples.iter().copied().filter(|s| s.at >= cutoff).collect()
    }

    /// Store a peer (same-stage) report, keeping only the freshest per
    /// instance.
    pub fn record_peer(&mut self, report: LoadReport) {
        upsert_report(&mut self.peer_reports, report);
    }

    /// Store a successor (next-stage) report.
    pub fn record_successor(&mut self, report: LoadReport) {
        upsert_report(&mut self.successor_reports, report);
    }

    /// Drop every stored report from `instance` — called when it
    /// leaves the fleet (drain completion, spot kill) so its last
    /// gossiped load cannot linger as a stale comparison input.
    pub fn forget_instance(&mut self, instance: InstanceId) {
        self.peer_reports.retain(|r| r.instance != instance);
        self.successor_reports.retain(|r| r.instance != instance);
    }

    /// Fresh peer reports (age <= max_age at `now`), in instance order.
    pub fn peers(&self, now: Time, max_age: Time) -> Vec<LoadReport> {
        self.peer_reports
            .iter()
            .filter(|r| now - r.at <= max_age)
            .copied()
            .collect()
    }

    pub fn successors(&self, now: Time, max_age: Time) -> Vec<LoadReport> {
        self.successor_reports
            .iter()
            .filter(|r| now - r.at <= max_age)
            .copied()
            .collect()
    }

    /// Is this instance an overloaded outlier within its stage?
    /// (§4.4: request-memory demand 25% above the stage average.)
    /// `my_load` and the gossiped loads are capacity-normalized, so on
    /// a mixed fleet "outlier" means *relative to what the instance can
    /// absorb*, not raw token count.
    ///
    /// Allocation-free: iterates the sorted report list directly (the
    /// old path materialised + sorted a Vec on every post-step check).
    /// Summation order is the fixed instance order, so results are
    /// bit-stable run to run.
    pub fn is_overloaded(&self, now: Time, my_load: f64, threshold: f64, max_age: Time) -> bool {
        let mut total = 0.0f64;
        let mut n_peers = 0usize;
        for r in &self.peer_reports {
            if now - r.at <= max_age {
                total += r.norm_load;
                n_peers += 1;
            }
        }
        if n_peers == 0 {
            return false;
        }
        let avg = (total + my_load) / (n_peers + 1) as f64;
        my_load > avg * (1.0 + threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(instance: usize, at: f64, load: u64) -> LoadReport {
        LoadReport {
            instance,
            at,
            token_load: load,
            norm_load: load as f64,
            n_seqs: 1,
            memory_demand: 0.5,
            throughput: 100.0,
        }
    }

    #[test]
    fn window_discards_old_samples() {
        let mut t = LoadTracker::new(0, 10.0);
        t.observe_batch(0.0, &[(10, 20)]);
        t.observe_batch(5.0, &[(30, 40)]);
        t.observe_batch(20.0, &[(50, 60)]);
        let w = t.window_samples(20.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].input_len, 50);
    }

    #[test]
    fn freshest_report_wins() {
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 5.0, 100));
        t.record_peer(report(1, 3.0, 999)); // stale, ignored
        let peers = t.peers(6.0, 100.0);
        assert_eq!(peers.len(), 1);
        assert_eq!(peers[0].token_load, 100);
    }

    #[test]
    fn stale_reports_filtered_by_age() {
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 0.0, 100));
        t.record_peer(report(2, 9.5, 200));
        let fresh = t.peers(10.0, 1.0);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].instance, 2);
    }

    #[test]
    fn overload_detection_25_percent() {
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 0.0, 100));
        t.record_peer(report(2, 0.0, 100));
        // avg(100,100,140) = 113.3; 140 > 1.25*113 is false.
        assert!(!t.is_overloaded(0.0, 140.0, 0.25, 10.0));
        // avg(100,100,200) = 133.3; 200 > 166.7 is true.
        assert!(t.is_overloaded(0.0, 200.0, 0.25, 10.0));
    }

    #[test]
    fn overload_compares_capacity_normalized_loads() {
        // Peers report raw loads of 100 at capacity 0.5 -> norm 200.
        // A raw load of 150 at capacity 1.0 (norm 150) is *below* the
        // normalized stage average even though its raw count is higher.
        let mut t = LoadTracker::new(0, 10.0);
        for i in [1usize, 2] {
            let mut r = report(i, 0.0, 100);
            r.norm_load = 200.0;
            t.record_peer(r);
        }
        assert!(!t.is_overloaded(0.0, 150.0, 0.25, 10.0));
        // The same raw count on a half-capacity instance is an outlier.
        assert!(t.is_overloaded(0.0, 300.0, 0.25, 10.0));
    }

    #[test]
    fn no_peers_never_overloaded() {
        let t = LoadTracker::new(0, 10.0);
        assert!(!t.is_overloaded(0.0, 10_000.0, 0.25, 10.0));
    }

    #[test]
    fn throughput_ema_tracks_rate() {
        let mut t = LoadTracker::new(0, 10.0);
        let mut now = 0.0;
        for _ in 0..100 {
            now += 0.1;
            t.observe_tokens(now, 50); // 500 tokens/s
        }
        let est = t.throughput();
        assert!(est > 250.0 && est < 1000.0, "estimate {est}");
    }

    #[test]
    fn silent_instance_ages_out_of_overload_comparison() {
        // Regression: an instance that stops gossiping (dead, wedged)
        // must not keep winning overload-outlier comparisons with its
        // last report.  Peer 1 reported a tiny load once at t=0 and
        // went silent; by t=10 with a 3-gossip-period age bound its
        // report must no longer drag the stage average down.
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 0.0, 10));
        // While fresh, a load of 100 is a >25% outlier vs avg(10,100).
        assert!(t.is_overloaded(0.5, 100.0, 0.25, 3.0));
        // Silent for 10s: the report is out of the 3-period window, no
        // live peers remain, and the probe must decline to flag.
        assert!(!t.is_overloaded(10.0, 100.0, 0.25, 3.0));
        // A fresh report from a live peer re-enables the comparison.
        t.record_peer(report(2, 9.8, 10));
        assert!(t.is_overloaded(10.0, 100.0, 0.25, 3.0));
    }

    #[test]
    fn forget_instance_drops_its_reports() {
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 0.0, 100));
        t.record_peer(report(2, 0.0, 100));
        t.record_successor(report(3, 0.0, 100));
        t.forget_instance(1);
        t.forget_instance(3);
        let peers = t.peers(0.0, 10.0);
        assert_eq!(peers.len(), 1);
        assert_eq!(peers[0].instance, 2);
        assert!(t.successors(0.0, 10.0).is_empty());
        // Forgetting an unknown instance is a no-op.
        t.forget_instance(99);
        assert_eq!(t.peers(0.0, 10.0).len(), 1);
    }

    #[test]
    fn successors_separate_from_peers() {
        let mut t = LoadTracker::new(0, 10.0);
        t.record_peer(report(1, 0.0, 1));
        t.record_successor(report(2, 0.0, 2));
        assert_eq!(t.peers(0.0, 10.0).len(), 1);
        assert_eq!(t.successors(0.0, 10.0).len(), 1);
        assert_eq!(t.successors(0.0, 10.0)[0].instance, 2);
    }
}
