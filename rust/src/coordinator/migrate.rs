//! Live KV-cache migration — §5's transmission subsystem.
//!
//! Models the Llumnix-style multi-round live migration CascadeInfer
//! adopts: while the source instance keeps decoding a sequence, its KV
//! cache is copied round by round; each round transfers the delta that
//! accumulated during the previous round, until the delta is small
//! enough for a brief final stop-the-world round.
//!
//! Flow-control properties from §5 are enforced here:
//! * a strict concurrency cap (3 parallel transfers per instance),
//! * idle-slot targeting (migration is skipped when the destination
//!   has no free KV blocks),
//! * bandwidth sharing across concurrent transfers on the same link.

use crate::gpu::LinkKind;
use crate::{InstanceId, RequestId, Time, Tokens};
use std::collections::BTreeMap;

/// §5: "a strict concurrency limit (capped at three parallel
/// transfers in our implementation)".
pub const MAX_CONCURRENT_TRANSFERS: usize = 3;

/// Number of live rounds before the stop-the-world finish.
pub const MAX_ROUNDS: u32 = 4;

/// One in-flight migration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub request: RequestId,
    pub from: InstanceId,
    pub to: InstanceId,
    pub started_at: Time,
    pub finish_at: Time,
    /// Tokens of KV state moved (final, incl. rounds' deltas).
    pub tokens_moved: Tokens,
    /// Decode time lost on the source (the final frozen round).
    pub stall: Time,
}

/// Analytic multi-round live-migration schedule.
///
/// Round 0 copies the current `seq_len` tokens; while it flies, the
/// sequence keeps decoding at `decode_tokens_per_s`, accruing a delta;
/// each subsequent round copies the previous round's delta.  After
/// [`MAX_ROUNDS`] (or when a round's delta stops shrinking), the final
/// delta is copied with decode frozen — that's the stall.
///
/// Returns `(total_time, total_tokens_moved, stall_time)`.
pub fn live_migration_schedule(
    seq_len: Tokens,
    kv_bytes_per_token: f64,
    link_bytes_per_s: f64,
    decode_tokens_per_s: f64,
) -> (Time, Tokens, Time) {
    let bw_tokens_per_s = link_bytes_per_s / kv_bytes_per_token.max(1.0);
    // A non-positive (or NaN) link bandwidth would divide every round
    // below into NaN/∞ and poison the event clock; an unreachable link
    // is reported as an infinite-duration transfer instead.  (+∞
    // bandwidth needs no guard — each round degenerates to zero time.)
    if bw_tokens_per_s.is_nan() || bw_tokens_per_s <= 0.0 {
        return (f64::INFINITY, seq_len.max(1), f64::INFINITY);
    }
    let mut to_move = seq_len.max(1) as f64;
    let mut total_time = 0.0;
    let mut total_tokens = 0.0;
    for _round in 0..MAX_ROUNDS {
        let t = to_move / bw_tokens_per_s;
        total_time += t;
        total_tokens += to_move;
        let delta = decode_tokens_per_s * t;
        // Converged enough for the final round when the delta is tiny
        // or not shrinking (bw <= decode rate would never converge).
        if delta < 1.0 || delta >= to_move {
            to_move = delta.max(0.0);
            break;
        }
        to_move = delta;
    }
    // Final stop-the-world round.
    let stall = to_move / bw_tokens_per_s;
    total_time += stall;
    total_tokens += to_move;
    (total_time, total_tokens.ceil() as Tokens, stall)
}

/// Per-cluster migration bookkeeping: concurrency caps and link
/// bandwidth sharing.
#[derive(Debug, Clone)]
pub struct MigrationManager {
    pub kv_bytes_per_token: f64,
    /// Per-instance KV footprint (bytes/token) of the *sender's
    /// resolved model slice* — on a tensor-parallel instance each rank
    /// holds `1/tp` of the heads, so the wire transfer per source rank
    /// is the sliced footprint, not the full-model one.  Empty (the
    /// default) falls back to `kv_bytes_per_token` for every instance,
    /// which keeps homogeneous fleets bit-identical to before.
    per_instance_kv_bytes: Vec<f64>,
    /// Active transfers keyed by request.  `BTreeMap` (not `HashMap`)
    /// so the bandwidth-sharing scans below visit transfers in a
    /// deterministic order — detlint rule D1.
    active: BTreeMap<RequestId, Transfer>,
    /// Per-instance active-transfer counts (as source or destination).
    busy: BTreeMap<InstanceId, usize>,
    /// Per-receiver running sum of in-flight tokens, so
    /// [`Self::inbound_tokens`] is O(1) on the routing/bid hot paths.
    inbound: BTreeMap<InstanceId, Tokens>,
    /// Per-sender count of outgoing transfers, so [`Self::sender_busy`]
    /// is O(1) in the receiver pull loop.
    outbound: BTreeMap<InstanceId, usize>,
    pub total_completed: u64,
    pub total_tokens_moved: Tokens,
    pub total_skipped_no_slot: u64,
    pub total_rejected_concurrency: u64,
}

impl MigrationManager {
    pub fn new(kv_bytes_per_token: f64) -> Self {
        Self {
            kv_bytes_per_token,
            per_instance_kv_bytes: Vec::new(),
            active: BTreeMap::new(),
            busy: BTreeMap::new(),
            inbound: BTreeMap::new(),
            outbound: BTreeMap::new(),
            total_completed: 0,
            total_tokens_moved: 0,
            total_skipped_no_slot: 0,
            total_rejected_concurrency: 0,
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Install per-instance KV footprints (bytes/token of each
    /// instance's resolved TP slice), indexed by [`InstanceId`].
    /// Transfers started afterwards are priced from the *sender's*
    /// entry.
    pub fn set_instance_footprints(&mut self, per_instance_kv_bytes: Vec<f64>) {
        self.per_instance_kv_bytes = per_instance_kv_bytes;
    }

    /// Bytes/token a transfer out of `from` actually moves: the
    /// sender's sliced footprint when installed, the base model
    /// footprint otherwise.
    fn kv_bytes_for(&self, from: InstanceId) -> f64 {
        self.per_instance_kv_bytes.get(from).copied().unwrap_or(self.kv_bytes_per_token)
    }

    pub fn is_migrating(&self, request: RequestId) -> bool {
        self.active.contains_key(&request)
    }

    /// Is `instance` transmitting (or receiving) at its cap?
    pub fn at_capacity(&self, instance: InstanceId) -> bool {
        self.busy.get(&instance).copied().unwrap_or(0) >= MAX_CONCURRENT_TRANSFERS
    }

    /// Is the given sender currently transmitting anything? (the
    /// receiver-queue "sender busy" probe of §4.4).
    /// Maintained incrementally; O(1).
    pub fn sender_busy(&self, instance: InstanceId) -> bool {
        let busy = self.outbound.get(&instance).copied().unwrap_or(0) > 0;
        debug_assert_eq!(busy, self.active.values().any(|t| t.from == instance));
        busy
    }

    /// Try to start a migration at `now`. Fails (returning `None`)
    /// when either side is at its concurrency cap or the destination
    /// has no idle KV capacity (`dest_has_slot == false` — §5 "skipped
    /// if no idle cache is available").
    #[allow(clippy::too_many_arguments)]
    pub fn try_start(
        &mut self,
        now: Time,
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        seq_len: Tokens,
        link: LinkKind,
        decode_tokens_per_s: f64,
        dest_has_slot: bool,
    ) -> Option<Transfer> {
        if self.active.contains_key(&request) {
            return None;
        }
        if !dest_has_slot {
            self.total_skipped_no_slot += 1;
            return None;
        }
        if self.at_capacity(from) || self.at_capacity(to) {
            self.total_rejected_concurrency += 1;
            return None;
        }
        // Bandwidth shared across this instance pair's active flows.
        let concurrent = 1 + self
            .active
            .values()
            .filter(|t| (t.from == from && t.to == to) || (t.from == to && t.to == from))
            .count();
        let bw = link.bytes_per_s() / concurrent as f64;
        let (dur, tokens_moved, stall) =
            live_migration_schedule(seq_len, self.kv_bytes_for(from), bw, decode_tokens_per_s);
        let t = Transfer {
            request,
            from,
            to,
            started_at: now,
            finish_at: now + link.latency_s() + dur,
            tokens_moved,
            stall,
        };
        self.active.insert(request, t);
        *self.busy.entry(from).or_insert(0) += 1;
        *self.busy.entry(to).or_insert(0) += 1;
        *self.inbound.entry(to).or_insert(0) += t.tokens_moved;
        *self.outbound.entry(from).or_insert(0) += 1;
        Some(t)
    }

    fn release(&mut self, t: &Transfer) {
        for side in [t.from, t.to] {
            if let Some(c) = self.busy.get_mut(&side) {
                *c = c.saturating_sub(1);
            }
        }
        if let Some(v) = self.inbound.get_mut(&t.to) {
            *v = v.saturating_sub(t.tokens_moved);
        }
        if let Some(c) = self.outbound.get_mut(&t.from) {
            *c = c.saturating_sub(1);
        }
    }

    /// Complete a transfer (caller observed `finish_at` pass).
    pub fn finish(&mut self, request: RequestId) -> Option<Transfer> {
        let t = self.active.remove(&request)?;
        self.release(&t);
        self.total_completed += 1;
        self.total_tokens_moved += t.tokens_moved;
        Some(t)
    }

    /// Tokens currently inbound to `instance` over active transfers —
    /// the receiver-side "buffered length" of the §4.4 bids.
    /// Maintained incrementally; O(1).
    pub fn inbound_tokens(&self, instance: InstanceId) -> Tokens {
        let v = self.inbound.get(&instance).copied().unwrap_or(0);
        debug_assert_eq!(
            v,
            self.active
                .values()
                .filter(|t| t.to == instance)
                .map(|t| t.tokens_moved)
                .sum::<Tokens>()
        );
        v
    }

    /// Abort a transfer (e.g. the sequence finished mid-flight).
    pub fn abort(&mut self, request: RequestId) -> Option<Transfer> {
        let t = self.active.remove(&request)?;
        self.release(&t);
        Some(t)
    }

    /// Does the active transfer for `request` match these endpoints
    /// and finish instant?  Guards stale `MigrationDone` events: a
    /// transfer aborted by churn (and possibly restarted with new
    /// endpoints or a new finish time after re-admission) must not be
    /// completed by the event scheduled for its aborted predecessor.
    /// Bit-exact time match: the event fires at exactly the
    /// `finish_at` it was scheduled with.
    pub fn matches(
        &self,
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        finish_at: Time,
    ) -> bool {
        self.active
            .get(&request)
            .is_some_and(|t| t.from == from && t.to == to && t.finish_at.to_bits() == finish_at.to_bits())
    }

    /// Active transfers touching instance `i` as either endpoint, in
    /// ascending-request order (`active` is a `BTreeMap` — detlint D1)
    /// — the churn kill sweep enumerates these to abort them
    /// deterministically.
    pub fn transfers_touching(&self, i: InstanceId) -> Vec<Transfer> {
        self.active.values().filter(|t| t.from == i || t.to == i).copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KVB: f64 = 114_688.0; // Llama-3.2-3B bytes/token

    #[test]
    fn schedule_transfers_more_than_seq_len() {
        // Multi-round: deltas accumulate while decoding continues.
        let (time, tokens, stall) = live_migration_schedule(10_000, KVB, 25e9, 50.0);
        assert!(tokens >= 10_000);
        assert!(time > 0.0);
        assert!(stall >= 0.0 && stall < time);
    }

    #[test]
    fn faster_link_means_less_stall() {
        let (_, _, stall_nvl) = live_migration_schedule(50_000, KVB, 450e9, 100.0);
        let (_, _, stall_pcie) = live_migration_schedule(50_000, KVB, 25e9, 100.0);
        assert!(stall_nvl < stall_pcie);
    }

    #[test]
    fn stall_is_small_fraction_for_realistic_rates() {
        // §8: "KV migration is efficient and rarely impacts performance
        // under realistic bandwidth" — final stall should be a small
        // fraction of the total for NVLink.
        let (time, _, stall) = live_migration_schedule(100_000, KVB, 450e9, 100.0);
        assert!(stall / time < 0.05, "stall {stall} of {time}");
    }

    #[test]
    fn degenerate_bandwidth_is_guarded() {
        // Zero, negative, and NaN bandwidths must never produce NaN
        // schedules (NaN would poison the event clock's ordering).
        for bad_bw in [0.0, -25e9, f64::NAN] {
            let (time, tokens, stall) = live_migration_schedule(1000, KVB, bad_bw, 50.0);
            assert!(time.is_infinite() && time > 0.0, "bw {bad_bw}: time {time}");
            assert_eq!(tokens, 1000);
            assert!(stall.is_infinite() && stall > 0.0);
        }
        // Infinite bandwidth degenerates to an instant transfer.
        let (time, tokens, stall) = live_migration_schedule(1000, KVB, f64::INFINITY, 50.0);
        assert!(time.abs() < 1e-12 && stall.abs() < 1e-12);
        assert_eq!(tokens, 1000);
    }

    #[test]
    fn zero_decode_rate_single_round() {
        let (time, tokens, stall) = live_migration_schedule(1000, KVB, 25e9, 0.0);
        assert_eq!(tokens, 1000);
        assert!(stall.abs() < 1e-12);
        assert!((time - 1000.0 * KVB / 25e9).abs() < 1e-9);
    }

    #[test]
    fn concurrency_cap_enforced() {
        let mut m = MigrationManager::new(KVB);
        for i in 0..MAX_CONCURRENT_TRANSFERS as u64 {
            assert!(m
                .try_start(0.0, i, 0, 1, 1000, LinkKind::NvLink, 10.0, true)
                .is_some());
        }
        // Fourth transfer from instance 0 rejected.
        assert!(m
            .try_start(0.0, 99, 0, 2, 1000, LinkKind::NvLink, 10.0, true)
            .is_none());
        assert_eq!(m.total_rejected_concurrency, 1);
        // Finishing one frees a slot.
        assert!(m.finish(0).is_some());
        assert!(m
            .try_start(0.0, 99, 0, 2, 1000, LinkKind::NvLink, 10.0, true)
            .is_some());
    }

    #[test]
    fn no_idle_slot_skips() {
        let mut m = MigrationManager::new(KVB);
        assert!(m
            .try_start(0.0, 1, 0, 1, 1000, LinkKind::NvLink, 10.0, false)
            .is_none());
        assert_eq!(m.total_skipped_no_slot, 1);
    }

    #[test]
    fn duplicate_request_rejected() {
        let mut m = MigrationManager::new(KVB);
        assert!(m.try_start(0.0, 1, 0, 1, 100, LinkKind::Rdma, 10.0, true).is_some());
        assert!(m.try_start(0.0, 1, 0, 2, 100, LinkKind::Rdma, 10.0, true).is_none());
    }

    #[test]
    fn bandwidth_shared_between_same_pair() {
        let mut m = MigrationManager::new(KVB);
        let t1 = m.try_start(0.0, 1, 0, 1, 10_000, LinkKind::Pcie, 0.0, true).unwrap();
        let t2 = m.try_start(0.0, 2, 0, 1, 10_000, LinkKind::Pcie, 0.0, true).unwrap();
        // Second transfer sees half bandwidth -> ~2x duration.
        let d1 = t1.finish_at - t1.started_at;
        let d2 = t2.finish_at - t2.started_at;
        assert!(d2 > 1.8 * d1, "d1={d1} d2={d2}");
    }

    #[test]
    fn sender_busy_probe() {
        let mut m = MigrationManager::new(KVB);
        assert!(!m.sender_busy(0));
        m.try_start(0.0, 1, 0, 1, 100, LinkKind::Rdma, 10.0, true);
        assert!(m.sender_busy(0));
        assert!(!m.sender_busy(1), "receiving != transmitting");
        m.finish(1);
        assert!(!m.sender_busy(0));
    }

    #[test]
    fn sender_slice_footprint_prices_the_transfer() {
        let mut base = MigrationManager::new(KVB);
        let t_base = base.try_start(0.0, 1, 0, 1, 50_000, LinkKind::NvLink, 0.0, true).unwrap();
        let mut sliced = MigrationManager::new(KVB);
        sliced.set_instance_footprints(vec![KVB / 4.0, KVB]);
        let t_slice = sliced.try_start(0.0, 1, 0, 1, 50_000, LinkKind::NvLink, 0.0, true).unwrap();
        // A TP4 sender moves a quarter of the bytes -> ~4x faster.
        let d_base = t_base.finish_at - t_base.started_at;
        let d_slice = t_slice.finish_at - t_slice.started_at;
        assert!(d_slice < d_base / 3.0, "base {d_base} slice {d_slice}");
        // Senders beyond the installed table fall back to the base
        // footprint, so partial tables stay safe.
        let mut fallback = MigrationManager::new(KVB);
        fallback.set_instance_footprints(vec![KVB / 4.0]);
        let t_fb = fallback.try_start(0.0, 2, 1, 0, 50_000, LinkKind::NvLink, 0.0, true).unwrap();
        assert!((t_fb.finish_at - t_base.finish_at).abs() < 1e-9);
    }

    #[test]
    fn abort_releases_slots_without_counting() {
        let mut m = MigrationManager::new(KVB);
        m.try_start(0.0, 1, 0, 1, 100, LinkKind::Rdma, 10.0, true);
        assert!(m.abort(1).is_some());
        assert_eq!(m.total_completed, 0);
        assert!(!m.at_capacity(0));
        assert!(m.abort(1).is_none());
    }
}
