//! Parallel rate x scheduler (x fleet) sweep grids — the engine behind
//! the `sweep` subcommand.
//!
//! Every grid cell is an independently seeded [`Experiment`]: cells
//! share nothing but the immutable request trace of their rate, so
//! they parallelize embarrassingly.  [`run_sweep`] builds every cell
//! up front (serial — name resolution and trace generation stay
//! deterministic and fail fast), then runs the cells across
//! `jobs` scoped worker threads pulling from an atomic cursor.
//! Results land in their cell's slot, so the rendered table is
//! **byte-identical for any job count** — enforced by the
//! `parallel_table_matches_serial` test below.

use crate::cluster::{run_experiment, ClusterConfig, PolicySpec};
use crate::experiment::ExperimentBuilder;
use crate::fleet::FleetSpec;
use crate::metrics::Slo;
use crate::predict::PredictorSpec;
use crate::workload::Request;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The grid axes of one sweep invocation.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub rates: Vec<f64>,
    /// Registry names or `custom:` axis strings.
    pub schedulers: Vec<String>,
    /// Fleet grid axis; `[None]` is the single legacy (homogeneous
    /// `--gpu`/`--instances`) cell.
    pub fleets: Vec<Option<String>>,
    /// Length-predictor grid axis (`oracle`, `noisy:CV`, `bucket:ACC`,
    /// `ltr:PACC`); `[None]` is the single legacy cell running
    /// whatever predictor the scheduler spec carries.  When any entry
    /// is set, the table gains predictor, SLO-attainment, re-route,
    /// and misprediction columns — the QoE-vs-accuracy robustness
    /// result.
    pub predictors: Vec<Option<String>>,
    /// Fault-injection spec applied to *every* cell (not a grid axis:
    /// churn compares schedulers under one fault schedule — see
    /// [`crate::cluster::ChurnSpec::parse`]).  When set, the table
    /// gains churn-recovery columns (preempted / recovered requests).
    pub churn: Option<String>,
    /// Worker threads; clamped to the cell count, minimum 1.
    pub jobs: usize,
}

/// Default worker count: the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One materialised grid cell, ready to run.  Holds only the resolved
/// cluster configuration — the (potentially large) request trace is
/// shared per rate through `traces[rate_idx]`, not cloned per cell.
struct Cell {
    rate: f64,
    /// Index into the per-rate shared traces.
    rate_idx: usize,
    fleet: Option<String>,
    scheduler: String,
    predictor: Option<String>,
    cfg: ClusterConfig,
}

/// Run the whole grid and render the comparison table (the shape of
/// Figs. 6/7/10 from the CLI).  Validation errors (unknown scheduler,
/// malformed fleet, empty axes) return `Err` before any cell runs.
pub fn run_sweep(base: &ExperimentBuilder, spec: &SweepSpec) -> Result<String, String> {
    if spec.rates.is_empty() || spec.schedulers.is_empty() {
        return Err("sweep needs at least one rate and one scheduler".into());
    }
    if spec.fleets.is_empty() {
        return Err(
            "--fleets needs at least one fleet, e.g. --fleets \"h20:4;h20:2,h100:2\"".into(),
        );
    }
    if spec.predictors.is_empty() {
        return Err(
            "--predictors needs at least one predictor, e.g. --predictors \"oracle;noisy:0.5\""
                .into(),
        );
    }
    // Fail fast on any unresolvable scheduler, fleet, or predictor
    // *before* running grid cells.
    for name in &spec.schedulers {
        PolicySpec::resolve(name).map_err(|e| e.to_string())?;
    }
    for f in spec.fleets.iter().flatten() {
        FleetSpec::parse(f)?;
    }
    for p in spec.predictors.iter().flatten() {
        PredictorSpec::parse(p)?;
    }
    if let Some(c) = &spec.churn {
        crate::cluster::ChurnSpec::parse(c)?;
    }
    let fleet_col = spec.fleets.iter().any(Option::is_some);
    let pred_col = spec.predictors.iter().any(Option::is_some);
    let churn_col = spec.churn.is_some();

    // Materialise every cell serially: one shared workload per rate
    // (identical trace across that rate's schedulers and fleets —
    // apples-to-apples columns, and a `trace:` CSV is read once).
    // Cell configs are fully resolved up front (fail fast on any bad
    // combination), but each holds only a ClusterConfig: the builder
    // probe uses a one-request stand-in trace, because the resolved
    // configuration does not depend on the trace contents and cloning
    // the real trace per cell would hold cells x trace in memory.
    let mut traces: Vec<Vec<Request>> = Vec::new();
    let mut cells: Vec<Cell> = Vec::new();
    for &rate in &spec.rates {
        let shared = base.clone().rate(rate).build().map_err(|e| e.to_string())?.requests;
        let probe = vec![shared[0]];
        for fleet in &spec.fleets {
            for name in &spec.schedulers {
                // Predictor varies fastest, so rows group by scheduler
                // — the QoE-vs-accuracy robustness table reads per
                // scheduler top to bottom.
                for predictor in &spec.predictors {
                    let mut b = base.clone().rate(rate).scheduler(name).trace(probe.clone());
                    if let Some(f) = fleet {
                        b = b.fleet(f);
                    }
                    if let Some(p) = predictor {
                        b = b.predictor(p);
                    }
                    if let Some(c) = &spec.churn {
                        b = b.churn(c);
                    }
                    let exp = b.build().map_err(|e| e.to_string())?;
                    cells.push(Cell {
                        rate,
                        rate_idx: traces.len(),
                        fleet: fleet.clone(),
                        scheduler: name.clone(),
                        predictor: predictor.clone(),
                        cfg: exp.cfg,
                    });
                }
            }
        }
        traces.push(shared);
    }

    // The fleet column renders as a prefix string so the row format
    // exists exactly once.
    let fleet_cell = |label: &str| -> String {
        if fleet_col {
            format!("{label:<20} ")
        } else {
            String::new()
        }
    };
    // Likewise the predictor column, plus the robustness suffix
    // columns (SLO attainment + recovery counters), only when the
    // predictor axis is actually in play — legacy sweeps render
    // byte-identical tables.
    let pred_cell = |label: &str| -> String {
        if pred_col {
            format!("{label:<12} ")
        } else {
            String::new()
        }
    };
    let mut table = format!(
        "{:<6} {}{:<42} {}{:>10} {:>10} {:>10} {:>11} {:>8}",
        "rate",
        fleet_cell("fleet"),
        "scheduler",
        pred_cell("predictor"),
        "TTFT",
        "TPOT",
        "p95TPOT",
        "tok/s",
        "migr"
    );
    if pred_col {
        table.push_str(&format!(" {:>7} {:>8} {:>7}", "SLO%", "reroute", "mispred"));
    }
    if churn_col {
        table.push_str(&format!(" {:>8} {:>7} {:>6}", "preempt", "recov", "rej"));
    }

    // Run the cells across scoped workers; each slot is claimed once
    // through the cursor and filled in place, so assembly order (and
    // therefore the table bytes) is independent of scheduling.
    let jobs = spec.jobs.max(1).min(cells.len());
    let cursor = AtomicUsize::new(0);
    let rows: Mutex<Vec<Option<String>>> = Mutex::new(vec![None; cells.len()]);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let cell = &cells[i];
                let (r, stats) = run_experiment(cell.cfg.clone(), &traces[cell.rate_idx]);
                let mut row = format!(
                    "{:<6.1} {}{:<42} {}{:>9.4}s {:>9.5}s {:>9.5}s {:>11.1} {:>8}",
                    cell.rate,
                    fleet_cell(cell.fleet.as_deref().unwrap_or("-")),
                    cell.scheduler,
                    pred_cell(cell.predictor.as_deref().unwrap_or("-")),
                    r.mean_ttft(),
                    r.mean_tpot(),
                    r.p95_tpot(),
                    r.throughput_tokens_per_s(),
                    stats.migrations
                );
                if pred_col {
                    let slo = 100.0 * r.slo_attainment(Slo { ttft: 1.0, tpot: 0.1 });
                    row.push_str(&format!(
                        " {:>6.1}% {:>8} {:>7}",
                        slo, stats.predict_reroutes, stats.mispredictions
                    ));
                }
                if churn_col {
                    row.push_str(&format!(
                        " {:>8} {:>7} {:>6}",
                        stats.preempted_requests, stats.recovered, stats.rejected
                    ));
                }
                rows.lock().expect("no poisoned sweep rows")[i] = Some(row);
            });
        }
    });

    for row in rows.into_inner().expect("no poisoned sweep rows") {
        table.push('\n');
        table.push_str(&row.expect("every claimed cell produced a row"));
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::Experiment;

    fn tiny_base() -> ExperimentBuilder {
        Experiment::builder().instances(4).requests(60).plan_sample(200).seed(9)
    }

    fn tiny_spec(jobs: usize) -> SweepSpec {
        SweepSpec {
            rates: vec![8.0, 16.0],
            schedulers: vec!["cascade".into(), "vllm".into()],
            fleets: vec![None],
            predictors: vec![None],
            churn: None,
            jobs,
        }
    }

    #[test]
    fn parallel_table_matches_serial() {
        // The satellite guarantee: the grid table is byte-identical
        // between a serial run and any parallel job count.
        let base = tiny_base();
        let serial = run_sweep(&base, &tiny_spec(1)).unwrap();
        let parallel = run_sweep(&base, &tiny_spec(4)).unwrap();
        assert_eq!(serial, parallel);
        // Sanity on shape: header + one row per cell.
        assert_eq!(serial.lines().count(), 1 + 4);
        assert!(serial.lines().next().unwrap().contains("scheduler"));
    }

    #[test]
    fn oversubscribed_jobs_are_clamped() {
        let base = tiny_base();
        let mut spec = tiny_spec(64);
        spec.rates = vec![10.0];
        spec.schedulers = vec!["sjf".into()];
        let table = run_sweep(&base, &spec).unwrap();
        assert_eq!(table.lines().count(), 2);
    }

    #[test]
    fn fleet_axis_renders_a_fleet_column() {
        let base = tiny_base();
        let spec = SweepSpec {
            rates: vec![8.0],
            schedulers: vec!["cascade".into()],
            fleets: vec![None, Some("h20:2,h100:2".into())],
            predictors: vec![None],
            churn: None,
            jobs: 2,
        };
        let table = run_sweep(&base, &spec).unwrap();
        assert!(table.lines().next().unwrap().contains("fleet"));
        assert!(table.contains("h20:2,h100:2"));
        assert!(table.contains(" - "), "legacy cell renders a dash");
    }

    #[test]
    fn invalid_axes_fail_fast() {
        let base = tiny_base();
        let mut spec = tiny_spec(1);
        spec.schedulers = vec!["bogus".into()];
        assert!(run_sweep(&base, &spec).is_err());
        let mut spec = tiny_spec(1);
        spec.fleets = vec![Some("a100:4".into())];
        assert!(run_sweep(&base, &spec).is_err());
        let mut spec = tiny_spec(1);
        spec.rates.clear();
        assert!(run_sweep(&base, &spec).is_err());
        let mut spec = tiny_spec(1);
        spec.predictors = vec![Some("psychic".into())];
        assert!(run_sweep(&base, &spec).is_err());
        let mut spec = tiny_spec(1);
        spec.predictors.clear();
        assert!(run_sweep(&base, &spec).is_err());
    }

    #[test]
    fn churn_spec_renders_recovery_columns_and_fails_fast() {
        let base = tiny_base();
        let mut spec = tiny_spec(2);
        spec.rates = vec![10.0];
        spec.schedulers = vec!["cascade".into()];
        spec.churn = Some("spot:1.0@1".into());
        let table = run_sweep(&base, &spec).unwrap();
        let header = table.lines().next().unwrap();
        assert!(header.contains("preempt"));
        assert!(header.contains("recov"));
        assert_eq!(table.lines().count(), 1 + 1);
        // Churn-free sweeps keep the legacy table shape byte for byte.
        let legacy = run_sweep(&base, &tiny_spec(1)).unwrap();
        assert!(!legacy.lines().next().unwrap().contains("preempt"));
        // A malformed churn spec fails before any cell runs.
        let mut spec = tiny_spec(1);
        spec.churn = Some("reboot:1.0@2".into());
        assert!(run_sweep(&base, &spec).is_err());
    }

    #[test]
    fn predictor_axis_renders_robustness_columns() {
        // The tentpole deliverable shape: a QoE-vs-accuracy table with
        // predictor, SLO-attainment, and recovery-counter columns.
        let base = tiny_base();
        let mut spec = tiny_spec(2);
        spec.rates = vec![10.0];
        spec.schedulers = vec!["cascade".into()];
        spec.predictors = vec![Some("oracle".into()), Some("noisy:0.5".into())];
        let table = run_sweep(&base, &spec).unwrap();
        let header = table.lines().next().unwrap();
        assert!(header.contains("predictor"));
        assert!(header.contains("SLO%"));
        assert!(header.contains("reroute"));
        assert!(header.contains("mispred"));
        assert_eq!(table.lines().count(), 1 + 2);
        assert!(table.contains("oracle"));
        assert!(table.contains("noisy:0.5"));
        // Legacy spec (predictor axis unset) must not grow the table.
        let legacy = run_sweep(&base, &tiny_spec(1)).unwrap();
        assert!(!legacy.lines().next().unwrap().contains("predictor"));
    }
}
