//! Real serving path: CascadeInfer over the PJRT-compiled model.
//!
//! Where [`crate::cluster`] *simulates* 16 H20s, this module actually
//! serves the AOT-compiled tiny GPT (python/compile) on N in-process
//! instances, proving the three layers compose: Rust routes, batches,
//! decodes through XLA executables, tracks per-sequence KV state, and
//! live-migrates sequences across length-specialized stages — with no
//! Python anywhere on the request path.
//!
//! Threading model: one OS thread per instance, each owning its own
//! [`crate::runtime::Runtime`] (PJRT clients are not shared across
//! threads).  Instances exchange control messages and KV payloads over
//! `std::sync::mpsc` channels — the offline stand-in for the paper's
//! C++ cudaMemcpyPeerAsync/RDMA backend (§5).  The router applies the
//! same length-aware stage routing as the simulator; inter-stage
//! handover reuses the §4.4 bid-ask receiver selection over gossiped
//! load reports.

use crate::coordinator::balance::{select_receiver, Bid};
use crate::runtime::Runtime;
use crate::{InstanceId, RequestId, Tokens};
use anyhow::Result;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A request to the real server.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: RequestId,
    /// Prompt token ids (byte-level vocab). Must fit the compiled
    /// prefill window.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub submitted_at: Instant,
    pub first_token_at: Instant,
    pub finished_at: Instant,
    /// Instances that served this request, in order (len > 1 means the
    /// request migrated).
    pub served_by: Vec<InstanceId>,
}

impl ServeResponse {
    pub fn ttft(&self) -> Duration {
        self.first_token_at - self.submitted_at
    }

    pub fn e2e(&self) -> Duration {
        self.finished_at - self.submitted_at
    }
}

/// Per-sequence KV state, host-resident between steps: `[L, H, S, Dh]`
/// row-major.  Keeping KV per-sequence makes continuous batching
/// (regroup every step) and migration (ship the vectors) trivial and
/// exact.
#[derive(Debug, Clone)]
struct SeqKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// A live sequence inside an instance.
#[derive(Debug, Clone)]
struct LiveSeq {
    id: RequestId,
    tokens: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    kv: SeqKv,
    kv_len: i32,
    last_token: i32,
    submitted_at: Instant,
    first_token_at: Option<Instant>,
    served_by: Vec<InstanceId>,
}

/// Messages into an instance thread.
enum ToInstance {
    New(ServeRequest, Instant),
    /// A migrated sequence (KV payload included — the "RDMA transfer").
    Migrated(Box<LiveSeq>),
    Shutdown,
}

/// Gossiped load report (lock-free: atomics snapshotted by senders).
#[derive(Default)]
struct SharedLoad {
    token_load: AtomicU64,
    n_seqs: AtomicU64,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    /// Stage boundaries over *current* sequence length; instances are
    /// assigned one per stage in order. len(boundaries)+1 == instances.
    pub stage_boundaries: Vec<Tokens>,
    pub instances_per_stage: usize,
    /// Decode batch cap (clamped to the largest compiled variant).
    pub max_batch: usize,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            stage_boundaries: vec![48, 80],
            instances_per_stage: 1,
            max_batch: 8,
        }
    }

    pub fn n_instances(&self) -> usize {
        (self.stage_boundaries.len() + 1) * self.instances_per_stage
    }

    fn stage_of_len(&self, len: Tokens) -> usize {
        for (i, &b) in self.stage_boundaries.iter().enumerate() {
            if len < b {
                return i;
            }
        }
        self.stage_boundaries.len()
    }
}

/// The running server.
pub struct Server {
    cfg: ServerConfig,
    to_instances: Vec<Sender<ToInstance>>,
    results: Receiver<ServeResponse>,
    loads: Vec<Arc<SharedLoad>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    submitted: u64,
}

impl Server {
    /// Spawn all instance threads (each compiles its own executables).
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        let n = cfg.n_instances();
        let (res_tx, res_rx) = channel::<ServeResponse>();
        let loads: Vec<Arc<SharedLoad>> =
            (0..n).map(|_| Arc::new(SharedLoad::default())).collect();

        // Build the instance channel mesh first so each thread can own
        // senders to every other instance (decentralized handover).
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<ToInstance>();
            txs.push(tx);
            rxs.push(rx);
        }

        let ready = Arc::new(std::sync::Barrier::new(n + 1));
        let mut handles = Vec::with_capacity(n);
        for (i, rx) in rxs.into_iter().enumerate() {
            let cfg_i = cfg.clone();
            let res_tx = res_tx.clone();
            let peer_txs: Vec<Sender<ToInstance>> = txs.clone();
            let loads_i: Vec<Arc<SharedLoad>> = loads.clone();
            let ready_i = ready.clone();
            handles.push(std::thread::spawn(move || {
                let rt = Runtime::load(&cfg_i.artifacts_dir)
                    .expect("artifacts must be built (make artifacts)");
                // Executables compiled: rendezvous so `start` returns a
                // warmed-up server and latency metrics exclude compile.
                ready_i.wait();
                instance_loop(i, cfg_i, rt, rx, peer_txs, res_tx, loads_i);
            }));
        }
        ready.wait();
        Ok(Self { cfg, to_instances: txs, results: res_rx, loads, handles, submitted: 0 })
    }

    /// Route a request to the earliest stage covering its prompt length
    /// (least-loaded member within the stage).
    pub fn submit(&mut self, req: ServeRequest) {
        let stage = self.cfg.stage_of_len(req.prompt.len() as Tokens);
        let members: Vec<usize> = (0..self.cfg.instances_per_stage)
            .map(|j| stage * self.cfg.instances_per_stage + j)
            .collect();
        let target = members
            .iter()
            .copied()
            .min_by_key(|&i| self.loads[i].token_load.load(Ordering::Relaxed))
            .unwrap();
        self.submitted += 1;
        self.to_instances[target]
            .send(ToInstance::New(req, Instant::now()))
            .expect("instance alive");
    }

    /// Block until `n` responses arrive.
    pub fn collect(&self, n: usize) -> Vec<ServeResponse> {
        (0..n).map(|_| self.results.recv().expect("instances alive")).collect()
    }

    /// Shut down all instance threads.
    pub fn shutdown(self) {
        for tx in &self.to_instances {
            let _ = tx.send(ToInstance::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Core per-instance serving loop: admit → prefill → batched decode →
/// handover/complete.
fn instance_loop(
    me: InstanceId,
    cfg: ServerConfig,
    rt: Runtime,
    rx: Receiver<ToInstance>,
    peers: Vec<Sender<ToInstance>>,
    results: Sender<ServeResponse>,
    loads: Vec<Arc<SharedLoad>>,
) {
    let meta = rt.meta.clone();
    let max_batch = cfg.max_batch.min(*meta.batches.last().unwrap());
    let my_stage = me / cfg.instances_per_stage;
    let last_stage = my_stage == cfg.stage_boundaries.len();
    let stage_hi: Tokens = if last_stage {
        meta.max_seq as Tokens
    } else {
        cfg.stage_boundaries[my_stage]
    };

    let mut waiting: VecDeque<(ServeRequest, Instant)> = VecDeque::new();
    let mut active: Vec<LiveSeq> = Vec::new();
    let mut shutdown = false;

    let l = meta.n_layers;
    let h = meta.n_heads;
    let s = meta.max_seq;
    let dh = meta.head_dim;
    let row_elems = s * dh; // per (layer is outer) per head
    let seq_kv_elems = l * h * row_elems;

    while !shutdown || !active.is_empty() || !waiting.is_empty() {
        // Drain inbox (block briefly when idle).
        loop {
            let msg = if active.is_empty() && waiting.is_empty() && !shutdown {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => m,
                    Err(_) => break,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                ToInstance::New(req, at) => waiting.push_back((req, at)),
                ToInstance::Migrated(seq) => {
                    let mut seq = *seq;
                    seq.served_by.push(me);
                    active.push(seq);
                }
                ToInstance::Shutdown => shutdown = true,
            }
        }
        if active.is_empty() && waiting.is_empty() {
            if shutdown {
                break;
            }
            continue;
        }

        // --- Admit + prefill newly waiting prompts as one batch.
        let room = max_batch.saturating_sub(active.len());
        let n_new = waiting.len().min(room);
        if n_new > 0 {
            let batch: Vec<(ServeRequest, Instant)> = waiting.drain(..n_new).collect();
            let t = meta.prefill_t;
            let mut tokens = vec![0i32; batch.len() * t];
            let mut lens = vec![0i32; batch.len()];
            for (bi, (req, _)) in batch.iter().enumerate() {
                let plen = req.prompt.len().min(t);
                tokens[bi * t..bi * t + plen].copy_from_slice(&req.prompt[..plen]);
                lens[bi] = plen as i32;
            }
            let out = rt.prefill(&tokens, &lens).expect("prefill executes");
            let now = Instant::now();
            let kc: Vec<f32> = out.k_cache.to_vec().expect("k cache reads");
            let vc: Vec<f32> = out.v_cache.to_vec().expect("v cache reads");
            let variant = meta.variant_for(batch.len()).unwrap();
            let first_tokens = rt.argmax_tokens(&out.logits);
            for (bi, (req, submitted_at)) in batch.into_iter().enumerate() {
                // Slice this sequence's rows out of [L, B*H, S, Dh].
                let mut kv = SeqKv {
                    k: vec![0.0; seq_kv_elems],
                    v: vec![0.0; seq_kv_elems],
                };
                for li in 0..l {
                    for hi in 0..h {
                        let src = ((li * variant * h) + bi * h + hi) * row_elems;
                        let dst = (li * h + hi) * row_elems;
                        kv.k[dst..dst + row_elems].copy_from_slice(&kc[src..src + row_elems]);
                        kv.v[dst..dst + row_elems].copy_from_slice(&vc[src..src + row_elems]);
                    }
                }
                let plen = req.prompt.len().min(t);
                let first = first_tokens[bi];
                active.push(LiveSeq {
                    id: req.id,
                    tokens: vec![first],
                    prompt_len: plen,
                    max_new: req.max_new_tokens,
                    kv,
                    kv_len: plen as i32,
                    last_token: first,
                    submitted_at,
                    first_token_at: Some(now),
                    served_by: vec![me],
                });
            }
        }

        // --- One batched decode step over all active sequences.
        if !active.is_empty() {
            let rows = active.len().min(max_batch);
            let variant = meta.variant_for(rows).unwrap();
            // Assemble the variant-sized cache from per-seq KV.
            let cache_elems = l * variant * h * row_elems;
            let mut kc = vec![0.0f32; cache_elems];
            let mut vc = vec![0.0f32; cache_elems];
            let mut toks = vec![0i32; rows];
            let mut lens = vec![0i32; rows];
            for (bi, seq) in active.iter().take(rows).enumerate() {
                toks[bi] = seq.last_token;
                lens[bi] = seq.kv_len;
                for li in 0..l {
                    for hi in 0..h {
                        let dst = ((li * variant * h) + bi * h + hi) * row_elems;
                        let src = (li * h + hi) * row_elems;
                        kc[dst..dst + row_elems].copy_from_slice(&seq.kv.k[src..src + row_elems]);
                        vc[dst..dst + row_elems].copy_from_slice(&seq.kv.v[src..src + row_elems]);
                    }
                }
            }
            let dims: Vec<i64> = vec![l as i64, (variant * h) as i64, s as i64, dh as i64];
            let k_lit = xla::Literal::vec1(&kc).reshape(&dims).unwrap();
            let v_lit = xla::Literal::vec1(&vc).reshape(&dims).unwrap();
            let out = rt.decode(&toks, &k_lit, &v_lit, &lens).expect("decode executes");
            let now = Instant::now();
            let kc2: Vec<f32> = out.k_cache.to_vec().expect("k cache reads");
            let vc2: Vec<f32> = out.v_cache.to_vec().expect("v cache reads");
            let next = rt.argmax_tokens(&out.logits);
            for (bi, seq) in active.iter_mut().take(rows).enumerate() {
                for li in 0..l {
                    for hi in 0..h {
                        let src = ((li * variant * h) + bi * h + hi) * row_elems;
                        let dst = (li * h + hi) * row_elems;
                        seq.kv.k[dst..dst + row_elems]
                            .copy_from_slice(&kc2[src..src + row_elems]);
                        seq.kv.v[dst..dst + row_elems]
                            .copy_from_slice(&vc2[src..src + row_elems]);
                    }
                }
                seq.kv_len = out.lengths[bi];
                seq.last_token = next[bi];
                seq.tokens.push(next[bi]);
                if seq.first_token_at.is_none() {
                    seq.first_token_at = Some(now);
                }
            }

            // --- Complete, hand over, or keep.
            let mut i = 0;
            while i < active.len() {
                let done = active[i].tokens.len() > active[i].max_new
                    || active[i].kv_len as usize >= s - 1;
                if done {
                    let seq = active.remove(i);
                    let mut tokens = seq.tokens;
                    tokens.truncate(seq.max_new);
                    let _ = results.send(ServeResponse {
                        id: seq.id,
                        tokens,
                        submitted_at: seq.submitted_at,
                        first_token_at: seq.first_token_at.unwrap_or(now),
                        finished_at: now,
                        served_by: seq.served_by,
                    });
                    continue;
                }
                let outgrown = !last_stage
                    && (active[i].kv_len as Tokens) >= stage_hi
                    && active[i].tokens.len() < active[i].max_new;
                if outgrown {
                    // Bid-ask over the next stage's members using the
                    // gossiped load snapshots.
                    let next_stage = my_stage + 1;
                    let members: Vec<usize> = (0..cfg.instances_per_stage)
                        .map(|j| next_stage * cfg.instances_per_stage + j)
                        .collect();
                    let bids: Vec<Bid> = members
                        .iter()
                        .map(|&m| Bid {
                            receiver: m,
                            request: active[i].id,
                            load: loads[m].token_load.load(Ordering::Relaxed),
                            earliest_start: loads[m].n_seqs.load(Ordering::Relaxed) as f64,
                            reply_at: m as f64,
                        })
                        .collect();
                    if let Some(target) = select_receiver(&bids) {
                        let seq = active.remove(i);
                        let _ = peers[target].send(ToInstance::Migrated(Box::new(seq)));
                        continue;
                    }
                }
                i += 1;
            }
        }

        // --- Publish load report.
        let token_load: u64 = active.iter().map(|a| a.kv_len as u64).sum();
        loads[me].token_load.store(token_load, Ordering::Relaxed);
        loads[me].n_seqs.store(active.len() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_routing_by_prompt_len() {
        let cfg = ServerConfig::new("artifacts");
        assert_eq!(cfg.stage_of_len(0), 0);
        assert_eq!(cfg.stage_of_len(47), 0);
        assert_eq!(cfg.stage_of_len(48), 1);
        assert_eq!(cfg.stage_of_len(80), 2);
        assert_eq!(cfg.n_instances(), 3);
    }

    #[test]
    fn response_timing_accessors() {
        let t0 = Instant::now();
        let r = ServeResponse {
            id: 1,
            tokens: vec![1, 2],
            submitted_at: t0,
            first_token_at: t0 + Duration::from_millis(5),
            finished_at: t0 + Duration::from_millis(20),
            served_by: vec![0, 1],
        };
        assert!(r.ttft() >= Duration::from_millis(5));
        assert!(r.e2e() >= r.ttft());
    }
}
