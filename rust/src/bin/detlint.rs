//! `detlint` — determinism & invariant lint for the simulator crate.
//!
//! ```text
//! detlint [--list-allows] [rust-root]
//! ```
//!
//! Walks `src/` under `rust-root` (default: this crate's manifest
//! directory) and enforces rules D1–D4 — see the `cascade_infer::lint`
//! module docs for the rule catalogue and the allow-annotation
//! grammar.  Exit codes: 0 clean, 1 unsuppressed findings, 2 usage or
//! I/O error.  `--list-allows` additionally exits 1 when any allow
//! annotation is stale (suppresses nothing).

use cascade_infer::lint;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut list_allows = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--list-allows" => list_allows = true,
            "--help" | "-h" => {
                println!("usage: detlint [--list-allows] [rust-root]");
                println!("  --list-allows  print the allow-annotation audit trail and exit");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("detlint: unknown flag `{other}` (try --help)");
                return ExitCode::from(2);
            }
            other => {
                if root.replace(PathBuf::from(other)).is_some() {
                    eprintln!("detlint: more than one root argument (try --help)");
                    return ExitCode::from(2);
                }
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));

    let report = match lint::check_crate(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: failed to lint {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if list_allows {
        if report.allows.is_empty() {
            println!("no detlint allow annotations in {}", root.display());
        }
        for a in &report.allows {
            let stale = if a.used { "" } else { "  [STALE: suppresses nothing]" };
            println!("{}:{}: allow({}) -- {}{stale}", a.file, a.line, a.rule, a.reason);
        }
        // The audit mode is the enforcement point for annotation
        // hygiene: a stale allow is a failure here (delete it or fix
        // the detector), while the regular run only warns.
        let stale = report.allows.iter().filter(|a| !a.used).count();
        if stale > 0 {
            eprintln!(
                "detlint: {stale} stale allow annotation{} — each suppresses nothing; \
                 remove them (or fix the detector they were written for)",
                if stale == 1 { "" } else { "s" }
            );
            return ExitCode::FAILURE;
        }
        return ExitCode::SUCCESS;
    }

    for a in report.allows.iter().filter(|a| !a.used) {
        eprintln!(
            "detlint: warning: stale allow({}) at {}:{} suppresses nothing \
             (run --list-allows for the audit trail)",
            a.rule, a.file, a.line
        );
    }
    if report.findings.is_empty() {
        let allows = report.allows.len();
        println!(
            "detlint: clean — 0 findings, {allows} justified allow annotation{} \
             (rules D1-D4; see `cascade_infer::lint` docs)",
            if allows == 1 { "" } else { "s" }
        );
        ExitCode::SUCCESS
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        eprintln!(
            "detlint: {} unsuppressed finding{} — migrate to a deterministic structure \
             or justify with `// detlint: allow(<rule>) -- <reason>` on the offending line",
            report.findings.len(),
            if report.findings.len() == 1 { "" } else { "s" }
        );
        ExitCode::FAILURE
    }
}
