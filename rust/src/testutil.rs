//! proptest-lite: seeded property testing without external crates.
//!
//! The offline vendor set only contains the `xla` crate's dependency
//! closure, so this module provides the small slice of proptest we
//! need: seeded generators and a case runner that reports the failing
//! seed so any counterexample reproduces with one constant.

use crate::sim::Rng;

/// Number of cases each property runs by default.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` on `cases` generated inputs; panic with the offending
/// seed on the first failure.
///
/// ```
/// use cascade_infer::testutil::{for_all, gen_vec};
/// for_all("sorted-idempotent", 0xCAFE, 64, |rng| {
///     let mut v = gen_vec(rng, 0, 50, |r| r.next_range(1000));
///     v.sort_unstable();
///     let w = { let mut w = v.clone(); w.sort_unstable(); w };
///     assert_eq!(v, w);
/// });
/// ```
pub fn for_all<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Generate a vector with length in [min_len, max_len].
pub fn gen_vec<T>(rng: &mut Rng, min_len: usize, max_len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let len = min_len + rng.next_range((max_len - min_len + 1) as u64) as usize;
    (0..len).map(|_| f(rng)).collect()
}

/// A plausible batch of sequence lengths: mixture of short & long.
pub fn gen_lengths(rng: &mut Rng, max_rows: usize, max_len: u64) -> Vec<u64> {
    gen_vec(rng, 1, max_rows, |r| {
        if r.next_f64() < 0.1 {
            1 + r.next_range(max_len)
        } else {
            1 + r.next_range((max_len / 64).max(2))
        }
    })
}

/// Assert `a` and `b` are within relative tolerance.
pub fn assert_close(a: f64, b: f64, rtol: f64) {
    let denom = a.abs().max(b.abs()).max(1e-300);
    assert!(
        ((a - b).abs() / denom) <= rtol || (a - b).abs() < 1e-12,
        "not close: {a} vs {b} (rtol {rtol})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all("counter", 1, 10, |_| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn for_all_reports_failures() {
        for_all("fails", 2, 10, |rng| {
            assert!(rng.next_range(10) < 100, "never");
            assert!(rng.next_range(2) == 0, "coin flip");
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = gen_vec(&mut rng, 2, 7, |r| r.next_u64());
            assert!(v.len() >= 2 && v.len() <= 7);
        }
    }

    #[test]
    fn gen_lengths_positive() {
        let mut rng = Rng::new(4);
        let lens = gen_lengths(&mut rng, 64, 131_072);
        assert!(lens.iter().all(|&l| l >= 1));
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
    }

    #[test]
    #[should_panic(expected = "not close")]
    fn assert_close_rejects_far() {
        assert_close(1.0, 2.0, 1e-3);
    }
}
