//! Dependency-free CLI argument parsing + the `cascade-infer`
//! subcommands (serve, plan, sim, fit, gen-trace).

use std::collections::BTreeMap;

/// Parsed command line: positional args and `--key value` / `--flag`
/// options.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Resolve a scheduler name to the **legacy** closed enum.  Kept as a
/// compatibility shim: only the ten paper schedulers resolve here;
/// the CLI itself resolves through the open
/// [`crate::cluster::PolicySpec`] registry, which additionally accepts
/// `sjf` and `custom:` axis strings.
pub fn scheduler_by_name(name: &str) -> Option<crate::cluster::SchedulerKind> {
    use crate::cluster::SchedulerKind as K;
    Some(match name.to_ascii_lowercase().as_str() {
        "cascade" | "cascadeinfer" => K::Cascade,
        "rr" | "roundrobin" | "vllm" => K::RoundRobin,
        "sglang" => K::SgLangLike,
        "llumnix" => K::LlumnixLike,
        "chain" => K::Chain,
        "nopipeline" | "flat" => K::NoPipeline,
        "quantity" => K::CascadeQuantityRefine,
        "memory" => K::CascadeMemoryRefine,
        "interstage" => K::CascadeInterStageOnly,
        "rrintra" => K::CascadeRoundRobinIntra,
        _ => return None,
    })
}

pub const USAGE: &str = "\
cascade-infer — length-aware MILS scheduling (CascadeInfer reproduction)

USAGE:
  cascade-infer sim   [--config FILE] [--model NAME] [--gpu H20|L40|H100]
                      [--instances N] [--fleet SPEC] [--rate R] [--requests N]
                      [--seed S] [--scheduler NAME] [--workload NAME]
                      [--predictor P] [--layout L] [--churn SPEC]
                      [--micro-step] [--stream]
  cascade-infer sweep [--rates R1,R2,..] [--schedulers N1,N2,..]
                      [--fleets F1;F2;..] [--predictors P1;P2;..]
                      [--model NAME] [--gpu H20|L40|H100]
                      [--instances N] [--requests N] [--seed S]
                      [--workload NAME] [--churn SPEC] [--jobs N]
  cascade-infer plan  [--model NAME] [--instances N] [--requests N] [--seed S]
  cascade-infer fit   [--model NAME] [--gpu H20|L40|H100]
  cascade-infer gen-trace --out FILE [--rate R] [--requests N] [--seed S]
  cascade-infer serve [--artifacts DIR] [--requests N]

RUNNING EXPERIMENTS
  `sim` runs one experiment through the Experiment builder and prints
  the paper's metrics.  `sweep` runs a grid of rates x schedulers over
  one shared workload and prints a comparison table (use `;` to
  separate schedulers whose names contain commas, e.g. custom specs).

  Schedulers: cascade|vllm|sglang|llumnix|chain|nopipeline|quantity|
              memory|interstage|rrintra|sjf, or an ad-hoc axis spec
              custom:layout=planned|chain|flat,refine=adaptive|quantity|
              memory|off,balance=full|interstage|rrintra|periodic|off,
              dispatch=roundrobin|leastloaded|stagerouted|shortestfirst
              [,gossip=on|off][,speed=F]
  Workloads:  sharegpt|heavytail|uniformshort|mix|bursty|trace:FILE
  Predictors: oracle|noisy:CV|bucket:ACC|ltr:PACC (see Length
              prediction below)
  Layouts:    planned|chain|flat|pd[:P/D[:BOUNDARY[:WINDOW_US]]] —
              --layout L (also `custom:..,layout=L` and the config
              `layout` key) overrides the layout carried by the
              scheduler spec.  See Prefill/decode disaggregation
              below for the pd grammar.
  Fleets:     --fleet describes a heterogeneous fleet as comma-separated
              GPU:COUNT groups, each optionally followed by speed=F
              and/or tp=N options for that group, e.g.
              `h20:12,h100:4,speed=1.37` or `h20:4,tp=2,h20:2,tp=4`.
              It replaces --gpu/--instances: the instance count is the
              fleet size, each instance is priced by its own GPU, and
              the planner, router, and bid-ask balancer normalize load
              by modeled per-instance capacity.  tp=N serves the model
              as a tensor-parallel N-way slice on that group: per-GPU
              weight/KV traffic shrink Nx, the KV pool derives ~Nx the
              token headroom (how a 70B model holds 128K contexts), and
              every forward pass pays per-layer all-reduce collectives
              priced from the topology's intra-node link.  The stage
              planner prices KV feasibility and the collective premium,
              so long-sequence stages land on TP-sharded instances —
              list sharded groups last (stages are contiguous in fleet
              order; long ranges sit at the end).  `sweep` grids over
              --fleets F1;F2;.. (`;`-separated — fleet specs contain
              commas).  A homogeneous fleet (e.g. `h20:16`, tp=1)
              reproduces --gpu H20 --instances 16 bit-for-bit.
              Unknown option keys are hard errors listing valid keys.
  Length prediction:
              The scheduler plans on *predicted* output lengths while
              execution runs on ground truth, so predictor quality is
              an experimental axis.  --predictor P (also available as
              `custom:..,predictor=P` and the config `predictor` key):
                oracle      perfect foresight — bit-identical to the
                            pre-predictor simulator (the default)
                noisy:CV    lognormal multiplicative error with
                            coefficient of variation CV on the output
                            length (e.g. noisy:0.5)
                bucket:ACC  exponential length-bucket classifier that
                            picks the true bucket with probability ACC
                            and a neighbor otherwise
                ltr:PACC    rank-only (learning-to-rank) predictor:
                            pairwise-accuracy PACC ordering, no
                            absolute lengths — stages route by rank
                            quantile, admission falls back to prompt
                            length
              Predictions are deterministic per (seed, request id).
              When a running request outgrows its predicted stage
              boundary it re-routes once via live KV migration
              (counted in `re-routes`); an under-predicted request
              that cannot fit its true length escalates through
              admission rejection (`escalations`).  `sim` prints the
              misprediction/recovery counters for non-oracle runs;
              `sweep --predictors P1;P2;..` grids predictors as an
              axis and adds SLO%/reroute/mispred columns — the
              QoE-vs-accuracy robustness table.
  Prefill/decode disaggregation:
              --layout pd[:P/D[:BOUNDARY[:WINDOW_US]]] splits the
              fleet into a prefill pool (P instances, prompt phases
              only) and a decode pool (D instances); bare `pd`
              auto-splits ~1/4 of the fleet into the prefill pool,
              explicit pools must sum to the instance count.  Each
              completed prefill's KV hands off to the least-loaded
              feasible decode instance as a frozen-KV transfer priced
              by the existing migration cost model over the topology
              link.  Prompts at or below BOUNDARY tokens (default 512)
              enter a short queue that drains before the long queue,
              and arrivals accumulate for WINDOW_US microseconds
              (default 20000; 0 = dispatch immediately) so each
              prefill batch holds similar-length prompts.  A periodic
              controller moves an idle instance between the pools on
              sustained 2x backlog imbalance (disable with
              balance=off).  pd does not compose with --churn or a
              forced pipeline.  `sim` prints handoff/re-allocation
              counters under pd; colocated layouts are guaranteed
              bit-identical to the pre-pd simulator (CI pins this).
  Config:     --config FILE loads an [experiment] section (model, gpu,
              instances, fleet, rate, requests, seed, scheduler,
              workload, predictor, layout, churn); explicit CLI flags
              override file values.
  Parallel:   `sweep` cells are independent experiments and run across
              --jobs N worker threads (default: all cores).  The grid
              table is byte-identical for any job count.
  Streaming:  `sim --stream` never materializes the request trace:
              arrivals are pulled lazily from the workload generator
              (or read row-by-row from a trace:FILE replay), so
              resident memory is O(instances + in-flight requests)
              instead of O(requests) — this is how multi-million-
              request, 1000-instance fleets fit in RAM.  Reports are
              bit-identical to the default materialized run over the
              same spec (CI pins this across every registry
              scheduler); the offline planner sees the same head
              prefix either way.  `sim --stream` additionally prints
              the arena high-water mark — the measured peak of
              simultaneously-live requests.  Trace replays must be
              sorted by arrival time (gen-trace output always is);
              unsorted traces need the materialized path.
  Debugging:  `sim --micro-step` drives every engine iteration through
              its own queue event (the pre-macro-step hot loop).
              Reports are bit-identical to the default macro-stepped
              driver — it exists to verify exactly that, at a large
              wall-time cost.

FAULT INJECTION
  --churn SPEC injects deterministic instance churn — the elastic,
  fault-tolerant fleet axis.  SPEC is a comma-separated list of:
    spot:T@I          spot preemption: instance I dies at time T
                      mid-decode.  Its resident requests re-enter
                      admission as re-prefills (prompt + generated
                      prefix), retried with exponential backoff and
                      capped attempts before a counted rejection —
                      every request is accounted, never wedged.
    drain:T@I[:D]     graceful scale-in: I stops admitting at T,
                      requeues its queued work onto live instances and
                      evacuates decoding KV through the bid-ask
                      migration path, leaving when empty.  If still
                      non-empty at T+D (default 10s) it is forcibly
                      killed and recovers like a spot preemption.
    join:T[@GPU]      scale-out: a pre-allocated slot starts booting
                      at T and goes live only after its weight load
                      (model footprint over the inter-node link).
                      @GPU overrides the fleet's reference profile.
    auto:P:MIN..MAX   SLO-feedback autoscaler: every P seconds a
                      controller reads windowed SLO attainment and
                      queue depth; low attainment or deep queues boot
                      a new slot, comfortable attainment with empty
                      queues drains the highest live id — always
                      within MIN..MAX live instances.
  The literal `none` (the default) disables churn and is guaranteed
  bit-identical to the pre-churn simulator for every scheduler and
  predictor (CI pins this).  All churn is deterministic: same spec +
  seed => same report fingerprint.  `sim` prints churn/recovery
  counters when events fired; `sweep --churn SPEC` applies one fault
  schedule to every cell and adds preempt/recov/rej columns.
  Membership propagates everywhere: dispatch and the rebalancers only
  see admitting instances, gossip from departed instances expires,
  re-planning runs over live membership, and in-flight migrations
  touching a dead endpoint are cancelled with the request recovered.

STATIC ANALYSIS
  `cargo run --release --bin detlint` lints src/ for determinism
  hazards (D1 hash-order iteration, D2 NaN-unsafe partial_cmp, D3
  wall-clock/entropy in sim paths, D4 registry schedulers *and
  predictors* missing from the golden-seed/macro-equivalence coverage
  lists) and exits non-zero on any unsuppressed finding; CI gates on
  it.  D4 also covers churn event kinds: every `ChurnSpec::names()`
  entry must appear in the elastic-suite coverage lists.  Suppress a
  finding only with a justified annotation on the offending line:
  `// detlint: allow(<rule>) -- <reason>`.
  `detlint --list-allows` prints the annotation audit trail and fails
  when any annotation is stale (suppresses nothing) — dead allows
  must be deleted.  See the `cascade_infer::lint` module docs for the
  rule catalogue.

PERF BASELINE
  `cargo bench --bench perf_hotpath` prints the hot-path table and
  writes machine-readable `BENCH_hotpath.json` (ops/s per hot path,
  cluster-sim simulated-iterations per wall-second, a 1000-instance
  fleet cell, and a streaming-replay requests-per-second cell).  Flags
  after `--`: `--quick` (CI-sized runs), `--json PATH`, and
  `--check BASELINE.json` which exits non-zero if cluster-sim
  throughput regressed >30% (use `--tolerance F` to adjust) and prints
  a per-metric delta line for every key shared with the baseline.  The
  gate only compares runs whose size matches the baseline's recorded
  `quick` field — quick and full runs are not comparable.
  Blessing procedure (after an intentional perf change):
    1. push the change and let CI's bench step upload its fresh
       `BENCH_hotpath.json` artifact (a --quick run on the CI runner —
       local full-size numbers are NOT comparable to it), or run
       `cargo bench --bench perf_hotpath -- --quick --bless`
       on a comparable machine — `--bless` runs quick-sized and
       writes the result straight over the committed baseline at
       rust/benches/baseline/BENCH_hotpath.json;
    2. review the per-metric deltas the `--check` step printed, and
       say in the PR why the regression is intended;
    3. commit the refreshed baseline with the change — never
       hand-edit individual numbers.  (Without --bless: copy the CI
       artifact's JSON over the committed baseline.)

  Examples:
    cascade-infer sim --rate 16 --scheduler cascade --workload heavytail
    cascade-infer sim --fleet h20:6,h100:2 --scheduler cascade --workload heavytail
    cascade-infer sim --fleet h20:4,tp=2,h20:2,tp=4 --model llama70b --workload heavytail
    cascade-infer sim --scheduler custom:layout=planned,refine=memory,balance=rrintra
    cascade-infer sim --scheduler cascade --predictor noisy:0.5 --workload heavytail
    cascade-infer sim --layout pd:2/2 --instances 4 --workload heavytail
    cascade-infer sweep --rates 8,16,32 --schedulers cascade,vllm,llumnix
    cascade-infer sweep --rates 8,16 --schedulers cascade,vllm --fleets \"h20:8;h20:6,h100:2\"
    cascade-infer sweep --rates 16 --schedulers cascade,vllm \\
                        --predictors \"oracle;noisy:0.2;noisy:0.5;bucket:0.7;ltr:0.8\"
    cascade-infer sim --churn \"spot:2.0@1,drain:4.0@2:3.0,join:6.0\" --workload heavytail
    cascade-infer sweep --rates 12 --schedulers cascade,vllm --churn \"auto:1.0:2..6\"

`serve` drives the real PJRT-served model end to end.";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_positional_options_flags() {
        let a = Args::parse(
            ["sim", "--rate", "8.5", "--verbose", "--seed=7"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["sim"]);
        assert_eq!(a.get_f64("rate", 0.0), 8.5);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(["--fast"].iter().map(|s| s.to_string()));
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(std::iter::empty());
        assert_eq!(a.get_usize("instances", 16), 16);
        assert_eq!(a.get_or("model", "Llama-3.2-3B"), "Llama-3.2-3B");
    }

    #[test]
    fn scheduler_names_resolve() {
        use crate::cluster::SchedulerKind as K;
        assert_eq!(scheduler_by_name("cascade"), Some(K::Cascade));
        assert_eq!(scheduler_by_name("VLLM"), Some(K::RoundRobin));
        assert_eq!(scheduler_by_name("llumnix"), Some(K::LlumnixLike));
        assert_eq!(scheduler_by_name("bogus"), None);
    }
}
