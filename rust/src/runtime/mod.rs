//! PJRT runtime — loads the AOT artifacts and serves them from Rust.
//!
//! The build-time Python path (`make artifacts`) lowers the L2 JAX
//! model (with its L1 Pallas kernels) to **HLO text** plus a parameter
//! blob; this module is the request-path half: parse the artifacts,
//! compile one executable per batch variant on the PJRT CPU client,
//! and expose typed `prefill` / `decode` calls that move only
//! activations — parameters are uploaded to the device once.
//!
//! HLO *text* (not serialized protos) is deliberate: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Geometry of the served model, parsed from `artifacts/model.meta`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub prefill_t: usize,
    pub batches: Vec<usize>,
    pub n_params: usize,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("bad meta line: {line}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| anyhow!("meta missing key {k}"))?
                .parse()
                .with_context(|| format!("meta key {k}"))
        };
        let batches = kv
            .get("batches")
            .ok_or_else(|| anyhow!("meta missing batches"))?
            .split(',')
            .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("batches: {e}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            n_layers: get("n_layers")?,
            max_seq: get("max_seq")?,
            head_dim: get("head_dim")?,
            prefill_t: get("prefill_t")?,
            batches,
            n_params: get("n_params")?,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("model.meta"))
            .context("reading model.meta — run `make artifacts` first")?;
        Self::parse(&text)
    }

    /// Smallest compiled batch variant that fits `n` live rows.
    pub fn variant_for(&self, n: usize) -> Option<usize> {
        self.batches.iter().copied().find(|&b| b >= n)
    }

    /// Cache shape per variant: `[L, B*H, S, Dh]`.
    pub fn cache_dims(&self, batch: usize) -> [i64; 4] {
        [
            self.n_layers as i64,
            (batch * self.n_heads) as i64,
            self.max_seq as i64,
            self.head_dim as i64,
        ]
    }
}

/// One named parameter from `params.manifest`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<i64>,
    /// Offset into params.bin, in f32 elements.
    pub offset: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }
}

/// Parse `params.manifest` (`name ndim dims... offset`).
pub fn parse_manifest(text: &str) -> Result<Vec<ParamSpec>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let err = || anyhow!("bad manifest line {}: {line}", i + 1);
        if parts.len() < 3 {
            bail!(err());
        }
        let name = parts[0].to_string();
        let ndim: usize = parts[1].parse().map_err(|_| err())?;
        if parts.len() != 3 + ndim {
            bail!(err());
        }
        let dims = parts[2..2 + ndim]
            .iter()
            .map(|s| s.parse::<i64>().map_err(|_| err()))
            .collect::<Result<Vec<i64>>>()?;
        let offset: usize = parts[2 + ndim].parse().map_err(|_| err())?;
        out.push(ParamSpec { name, dims, offset });
    }
    Ok(out)
}

/// Load the parameter blob as per-parameter `Literal`s.
pub fn load_params(dir: &Path) -> Result<Vec<(ParamSpec, xla::Literal)>> {
    let manifest = std::fs::read_to_string(dir.join("params.manifest"))?;
    let specs = parse_manifest(&manifest)?;
    let blob = std::fs::read(dir.join("params.bin"))?;
    if blob.len() % 4 != 0 {
        bail!("params.bin not a multiple of 4 bytes");
    }
    let floats: Vec<f32> = blob
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let end = spec.offset + spec.numel();
        if end > floats.len() {
            bail!("param {} overruns blob ({} > {})", spec.name, end, floats.len());
        }
        let lit = xla::Literal::vec1(&floats[spec.offset..end]).reshape(&spec.dims)?;
        out.push((spec, lit));
    }
    Ok(out)
}

/// Output of a prefill call.
pub struct PrefillOut {
    /// `[B, V]` next-token logits at each row's last valid position.
    pub logits: Vec<f32>,
    pub k_cache: xla::Literal,
    pub v_cache: xla::Literal,
}

/// Output of a decode step.
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k_cache: xla::Literal,
    pub v_cache: xla::Literal,
    pub lengths: Vec<i32>,
}

/// The compiled model: one executable per (kind, batch) variant.
pub struct Runtime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    params: Vec<xla::Literal>,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Executions performed (for perf accounting).
    pub prefill_calls: std::cell::Cell<u64>,
    pub decode_calls: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load every artifact under `dir` and compile all batch variants.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let params: Vec<xla::Literal> =
            load_params(dir)?.into_iter().map(|(_, l)| l).collect();
        if params.len() != meta.n_params {
            bail!("param count mismatch: blob {} vs meta {}", params.len(), meta.n_params);
        }

        let compile = |path: PathBuf| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for &b in &meta.batches {
            prefill.insert(
                b,
                compile(dir.join(format!("prefill_b{b}_t{}.hlo.txt", meta.prefill_t)))?,
            );
            decode.insert(b, compile(dir.join(format!("decode_b{b}.hlo.txt")))?);
        }
        Ok(Self {
            meta,
            client,
            params,
            prefill,
            decode,
            prefill_calls: Default::default(),
            decode_calls: Default::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Prefill a batch of prompts (padded to the `prefill_t` window).
    ///
    /// `tokens` is `rows x prefill_t` row-major; `lengths[i]` counts the
    /// valid prompt tokens of row i (1..=prefill_t). Rows beyond the
    /// live count are padded internally.
    pub fn prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<PrefillOut> {
        let t = self.meta.prefill_t;
        let rows = lengths.len();
        if tokens.len() != rows * t {
            bail!("tokens must be rows*prefill_t = {}", rows * t);
        }
        let b = self
            .meta
            .variant_for(rows)
            .ok_or_else(|| anyhow!("batch {rows} exceeds largest variant"))?;
        let exe = &self.prefill[&b];

        // Pad rows up to the variant with inert length-1 rows.
        let mut tok = tokens.to_vec();
        tok.resize(b * t, 0);
        let mut lens = lengths.to_vec();
        lens.resize(b, 1);

        let tok_lit = xla::Literal::vec1(&tok).reshape(&[b as i64, t as i64])?;
        let lens_lit = xla::Literal::vec1(&lens);
        // Borrow the parameter literals — no per-call copies.
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&tok_lit);
        args.push(&lens_lit);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        self.prefill_calls.set(self.prefill_calls.get() + 1);
        let mut parts = result.to_tuple()?;
        if parts.len() != 3 {
            bail!("prefill returned {} outputs, want 3", parts.len());
        }
        let v_cache = parts.pop().unwrap();
        let k_cache = parts.pop().unwrap();
        let logits_all: Vec<f32> = parts.pop().unwrap().to_vec()?;
        // Trim padded rows.
        let v = self.meta.vocab;
        Ok(PrefillOut { logits: logits_all[..rows * v].to_vec(), k_cache, v_cache })
    }

    /// One decode step over the batch the caches were built for.
    pub fn decode(
        &self,
        tokens: &[i32],
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        lengths: &[i32],
    ) -> Result<DecodeOut> {
        let rows = tokens.len();
        if lengths.len() != rows {
            bail!("tokens/lengths mismatch");
        }
        // The cache fixes the variant.
        let cache_rows = k_cache.array_shape()?.dims()[1] as usize;
        let b = cache_rows / self.meta.n_heads;
        if rows > b {
            bail!("batch {rows} larger than cache variant {b}");
        }
        let exe = self
            .decode
            .get(&b)
            .ok_or_else(|| anyhow!("no decode variant for batch {b}"))?;

        let mut tok = tokens.to_vec();
        tok.resize(b, 0);
        let mut lens = lengths.to_vec();
        // Inert rows park at position 0 with length 0 (they write KV at
        // slot 0 but their outputs are discarded and lengths reset).
        lens.resize(b, 0);

        let tok_lit = xla::Literal::vec1(&tok);
        let lens_lit = xla::Literal::vec1(&lens);
        // Borrow params and caches — the caches come straight from the
        // previous step's outputs in the right shape already.
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&tok_lit);
        args.push(k_cache);
        args.push(v_cache);
        args.push(&lens_lit);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        self.decode_calls.set(self.decode_calls.get() + 1);
        let mut parts = result.to_tuple()?;
        if parts.len() != 4 {
            bail!("decode returned {} outputs, want 4", parts.len());
        }
        let new_lens: Vec<i32> = parts.pop().unwrap().to_vec()?;
        let v_cache = parts.pop().unwrap();
        let k_cache = parts.pop().unwrap();
        let logits_all: Vec<f32> = parts.pop().unwrap().to_vec()?;
        let v = self.meta.vocab;
        Ok(DecodeOut {
            logits: logits_all[..rows * v].to_vec(),
            k_cache,
            v_cache,
            lengths: new_lens[..rows].to_vec(),
        })
    }

    /// Greedy next-token choice per row from flat `[rows, vocab]` logits.
    pub fn argmax_tokens(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.meta.vocab;
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Default artifacts directory: `$CASCADE_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("CASCADE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = "vocab=256\nd_model=64\nn_heads=4\nn_layers=2\nmax_seq=128\nhead_dim=16\nprefill_t=32\nbatches=1,2,4,8\nn_params=28\n";

    #[test]
    fn meta_parses() {
        let m = ModelMeta::parse(META).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.batches, vec![1, 2, 4, 8]);
        assert_eq!(m.cache_dims(2), [2, 8, 128, 16]);
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(ModelMeta::parse("vocab=1\n").is_err());
        assert!(ModelMeta::parse("garbage line").is_err());
    }

    #[test]
    fn variant_selection() {
        let m = ModelMeta::parse(META).unwrap();
        assert_eq!(m.variant_for(1), Some(1));
        assert_eq!(m.variant_for(3), Some(4));
        assert_eq!(m.variant_for(8), Some(8));
        assert_eq!(m.variant_for(9), None);
    }

    #[test]
    fn manifest_parses() {
        let text = "tok_emb 2 256 64 0\npos_emb 2 128 64 16384\nlnf_bias 1 64 24576\n";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].numel(), 256 * 64);
        assert_eq!(specs[1].offset, 16384);
        assert_eq!(specs[2].dims, vec![64]);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("name 2 64").is_err());
        assert!(parse_manifest("name x 1 2 3").is_err());
    }
}
