//! # cascade_infer
//!
//! A from-scratch reproduction of **CascadeInfer** (Yuan et al., 2025):
//! length-aware, decentralized inter-instance scheduling for
//! multi-instance LLM serving (MILS).
//!
//! The crate is organised as the three-layer stack described in
//! `DESIGN.md`:
//!
//! * **L3 (this crate)** — the paper's contribution: length-specialized
//!   pipeline stages ([`coordinator::plan`]), adaptive range refinement
//!   ([`coordinator::refine`]), the decentralized bid-ask protocol
//!   ([`coordinator::balance`]) and live KV migration
//!   ([`coordinator::migrate`]), running over a deterministic
//!   discrete-event MILS cluster ([`cluster`]) *and* over a real
//!   PJRT-served model ([`server`], [`runtime`]).
//! * **L2/L1 (python/, build time only)** — a small GPT with Pallas
//!   attention kernels, AOT-lowered to `artifacts/*.hlo.txt`, which
//!   [`runtime`] loads and executes with no Python on the request path.
//!
//! Substrate modules ([`sim`], [`gpu`], [`fleet`], [`kernelmodel`],
//! [`models`], [`qoe`], [`workload`], [`engine`], [`metrics`]) rebuild everything
//! the paper's evaluation depends on — GPUs, attention-backend cost
//! behaviour, the model zoo, ShareGPT-like traffic — as faithful,
//! seedable simulations (see DESIGN.md §1 for the substitution table).

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiment;
pub mod fleet;
pub mod gpu;
pub mod kernelmodel;
pub mod lint;
pub mod metrics;
pub mod models;
pub mod predict;
pub mod qoe;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod sim;
pub mod sweep;
pub mod testutil;
pub mod workload;

/// Seconds — the universal time unit of the simulation layer.
pub type Time = f64;

/// Token counts and sequence lengths.
pub type Tokens = u64;

/// Request identifier, unique per run.
pub type RequestId = u64;

/// Engine-instance identifier (index into the cluster's instance table).
pub type InstanceId = usize;
