//! Arena/SoA storage for in-flight request metadata.
//!
//! At planet scale the driver cannot afford one heap object per
//! request: a 1000-instance fleet replaying millions of arrivals would
//! scatter request fields across the heap and drag the macro-step hot
//! loop through cache misses.  [`RequestArena`] keeps the metadata of
//! *live* requests (arrived but not yet completed or rejected) in
//! parallel columns indexed by a dense slot id, with released slots
//! recycled through a free list — resident size tracks the number of
//! in-flight requests, not the length of the trace.
//!
//! Lifetime rule (enforced by the cluster driver): a request is
//! interned at admission (`on_arrival`, before routing) together with
//! its cached predictor output, and released at completion recording or
//! admission rejection.  The cached `predicted` column is bit-identical
//! to recomputing the predictor on demand because every
//! [`crate::predict::LengthPredictor`] is a pure seeded hash of the
//! request — caching is a pure representation change.
//!
//! [`RecentWindow`] is the companion fixed-capacity ring replacing the
//! driver's unbounded completion log: replanning only ever reads the
//! newest `cap` observations (newest first), so the ring reproduces the
//! `Vec` path's `.iter().rev().take(cap)` order exactly while holding
//! O(cap) memory.

use std::collections::BTreeMap;

use crate::workload::Request;
use crate::{RequestId, Time, Tokens};

/// Dense columnar storage for live request metadata, keyed by request
/// id through an ordered index (keyed lookups only — never iterated, so
/// determinism is structural, not incidental).
#[derive(Debug, Clone, Default)]
pub struct RequestArena {
    // Parallel columns, indexed by slot.
    id: Vec<RequestId>,
    arrival: Vec<Time>,
    input_len: Vec<Tokens>,
    output_len: Vec<Tokens>,
    /// Cached predictor output (`predicted_final`) for the request.
    predicted: Vec<Tokens>,
    /// Released slots available for reuse, LIFO.
    free: Vec<u32>,
    /// Live id -> slot.
    index: BTreeMap<RequestId, u32>,
    /// Maximum simultaneous live count ever observed.
    high_water: usize,
}

impl RequestArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a request with its cached prediction; returns its slot.
    /// Re-interning a live id refreshes that slot in place.
    pub fn intern(&mut self, req: &Request, predicted: Tokens) -> u32 {
        let slot = match self.index.get(&req.id) {
            Some(&s) => s,
            None => {
                let s = match self.free.pop() {
                    Some(s) => s,
                    None => {
                        self.id.push(0);
                        self.arrival.push(0.0);
                        self.input_len.push(0);
                        self.output_len.push(0);
                        self.predicted.push(0);
                        (self.id.len() - 1) as u32
                    }
                };
                self.index.insert(req.id, s);
                s
            }
        };
        let s = slot as usize;
        self.id[s] = req.id;
        self.arrival[s] = req.arrival;
        self.input_len[s] = req.input_len;
        self.output_len[s] = req.output_len;
        self.predicted[s] = predicted;
        self.high_water = self.high_water.max(self.index.len());
        slot
    }

    /// Release a live request's slot back to the free list.  Returns
    /// `false` if the id was not live (already released or never
    /// interned) — callers treat that as "nothing cached".
    pub fn release(&mut self, id: RequestId) -> bool {
        match self.index.remove(&id) {
            Some(slot) => {
                self.free.push(slot);
                true
            }
            None => false,
        }
    }

    /// Slot of a live request.
    pub fn slot_of(&self, id: RequestId) -> Option<u32> {
        self.index.get(&id).copied()
    }

    /// Cached predicted final length of a live request.
    pub fn predicted(&self, id: RequestId) -> Option<Tokens> {
        self.slot_of(id).map(|s| self.predicted[s as usize])
    }

    /// Reconstruct the full [`Request`] of a live id from the columns.
    pub fn request(&self, id: RequestId) -> Option<Request> {
        self.slot_of(id).map(|slot| {
            let s = slot as usize;
            Request {
                id: self.id[s],
                arrival: self.arrival[s],
                input_len: self.input_len[s],
                output_len: self.output_len[s],
            }
        })
    }

    /// Number of live (interned, not yet released) requests.
    pub fn live(&self) -> usize {
        self.index.len()
    }

    /// Allocated slot count (live + free-listed).
    pub fn capacity(&self) -> usize {
        self.id.len()
    }

    /// Maximum simultaneous live count over the arena's lifetime — the
    /// O(in-flight) memory claim, measurable.
    pub fn high_water(&self) -> usize {
        self.high_water
    }
}

/// Fixed-capacity ring over the most recent observations.
///
/// `iter_rev` yields newest-to-oldest — exactly the order an unbounded
/// `Vec` produced via `.iter().rev().take(cap)`, so float accumulations
/// over the window are bit-identical to the unbounded path whenever the
/// consumer never looked past the newest `cap` entries.
#[derive(Debug, Clone)]
pub struct RecentWindow<T> {
    buf: Vec<T>,
    cap: usize,
    /// Next write position (wraps once the buffer is full).
    head: usize,
    /// Count of all pushes ever, monotone (the unbounded `len()`).
    total: u64,
}

impl<T: Copy> RecentWindow<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "RecentWindow needs a positive capacity");
        Self { buf: Vec::new(), cap, head: 0, total: 0 }
    }

    pub fn push(&mut self, v: T) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.head] = v;
        }
        self.head = (self.head + 1) % self.cap;
        self.total += 1;
    }

    /// Retained entries (saturates at the capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Count of all pushes ever — what the unbounded log's `len()` was.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Newest-to-oldest iteration over the retained window.
    pub fn iter_rev(&self) -> impl Iterator<Item = &T> + '_ {
        let n = self.buf.len();
        (0..n).map(move |k| &self.buf[(self.head + self.cap - 1 - k) % self.cap])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId) -> Request {
        Request { id, arrival: id as f64 * 0.5, input_len: 100 + id, output_len: 10 + id }
    }

    #[test]
    fn intern_lookup_release_roundtrip() {
        let mut a = RequestArena::new();
        let s0 = a.intern(&req(7), 200);
        let s1 = a.intern(&req(9), 300);
        assert_ne!(s0, s1);
        assert_eq!(a.live(), 2);
        assert_eq!(a.predicted(7), Some(200));
        assert_eq!(a.request(9), Some(req(9)));
        assert!(a.release(7));
        assert!(!a.release(7), "double release must be a no-op");
        assert_eq!(a.predicted(7), None);
        assert_eq!(a.live(), 1);
    }

    #[test]
    fn released_slots_are_recycled_keeping_capacity_at_high_water() {
        let mut a = RequestArena::new();
        // Interleave intern/release with at most 3 live at a time.
        for wave in 0..50u64 {
            for k in 0..3 {
                a.intern(&req(wave * 3 + k), 100);
            }
            for k in 0..3 {
                a.release(wave * 3 + k);
            }
        }
        assert_eq!(a.live(), 0);
        assert_eq!(a.high_water(), 3);
        assert!(a.capacity() <= 3, "capacity {} must not grow past high water", a.capacity());
    }

    #[test]
    fn reinterning_a_live_id_refreshes_in_place() {
        let mut a = RequestArena::new();
        let s = a.intern(&req(4), 111);
        let s2 = a.intern(&req(4), 222);
        assert_eq!(s, s2);
        assert_eq!(a.live(), 1);
        assert_eq!(a.predicted(4), Some(222));
    }

    #[test]
    fn recent_window_matches_unbounded_vec_reference() {
        let cap = 7;
        let mut win = RecentWindow::new(cap);
        let mut log: Vec<u32> = Vec::new();
        for v in 0..40u32 {
            win.push(v);
            log.push(v);
            let expect: Vec<u32> = log.iter().rev().take(cap).copied().collect();
            let got: Vec<u32> = win.iter_rev().copied().collect();
            assert_eq!(expect, got, "after {} pushes", v + 1);
            assert_eq!(win.total(), log.len() as u64);
            assert_eq!(win.len(), log.len().min(cap));
        }
    }

    #[test]
    fn recent_window_total_counts_past_the_cap() {
        let mut win = RecentWindow::new(2);
        assert!(win.is_empty());
        for v in 0..10u8 {
            win.push(v);
        }
        assert_eq!(win.total(), 10);
        assert_eq!(win.len(), 2);
    }
}
