//! Probability distributions used by the workload generator.
//!
//! Each distribution is a small value type sampling from a caller-owned
//! [`Rng`], keeping every stream seed-addressable.

use super::rng::Rng;

/// Exponential(rate) — inter-arrival gaps of a Poisson process.
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        Self { rate }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        -u.ln() / self.rate
    }
}

/// Poisson(lambda) counts via inversion (small lambda) or normal
/// approximation (large lambda).
#[derive(Debug, Clone, Copy)]
pub struct Poisson {
    pub lambda: f64,
}

impl Poisson {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0);
        Self { lambda }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.lambda < 30.0 {
            // Knuth inversion.
            let l = (-self.lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= rng.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.lambda + self.lambda.sqrt() * rng.normal();
            x.max(0.0).round() as u64
        }
    }
}

/// LogNormal(mu, sigma) of the underlying normal.
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma > 0.0);
        Self { mu, sigma }
    }

    /// Construct from the distribution's own median and the sigma of
    /// the underlying normal (median = e^mu).
    pub fn from_median(median: f64, sigma: f64) -> Self {
        Self::new(median.ln(), sigma)
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        (self.mu + self.sigma * rng.normal()).exp()
    }

    pub fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }
}

/// Pareto tail: `x_min * u^(-1/alpha)` — models the rare extremely long
/// contexts (up to 128K) that make MILS workloads heavy-tailed (paper
/// Fig. 1).
#[derive(Debug, Clone, Copy)]
pub struct ParetoTail {
    pub x_min: f64,
    pub alpha: f64,
}

impl ParetoTail {
    pub fn new(x_min: f64, alpha: f64) -> Self {
        assert!(x_min > 0.0 && alpha > 0.0);
        Self { x_min, alpha }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        self.x_min * u.powf(-1.0 / self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_of(n: usize, mut f: impl FnMut() -> f64) -> f64 {
        (0..n).map(|_| f()).sum::<f64>() / n as f64
    }

    #[test]
    fn exponential_mean_is_inverse_rate() {
        let mut rng = Rng::new(1);
        let d = Exponential::new(4.0);
        let m = mean_of(100_000, || d.sample(&mut rng));
        assert!((m - 0.25).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut rng = Rng::new(2);
        let d = Poisson::new(3.0);
        let m = mean_of(50_000, || d.sample(&mut rng) as f64);
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut rng = Rng::new(3);
        let d = Poisson::new(200.0);
        let m = mean_of(20_000, || d.sample(&mut rng) as f64);
        assert!((m - 200.0).abs() < 1.0, "mean {m}");
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Rng::new(4);
        let d = LogNormal::from_median(100.0, 1.0);
        let mut xs: Vec<f64> = (0..50_001).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(f64::total_cmp);
        let med = xs[xs.len() / 2];
        assert!((med / 100.0 - 1.0).abs() < 0.05, "median {med}");
    }

    #[test]
    fn lognormal_analytic_mean() {
        let mut rng = Rng::new(5);
        let d = LogNormal::new(2.0, 0.5);
        let m = mean_of(200_000, || d.sample(&mut rng));
        assert!((m / d.mean() - 1.0).abs() < 0.02, "mean {m} vs {}", d.mean());
    }

    #[test]
    fn pareto_respects_x_min_and_is_heavy_tailed() {
        let mut rng = Rng::new(6);
        let d = ParetoTail::new(1000.0, 1.2);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| x >= 1000.0));
        // Heavy tail: some sample exceeds 50x the minimum.
        assert!(xs.iter().any(|&x| x > 50_000.0));
    }
}
