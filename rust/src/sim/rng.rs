//! SplitMix64 pseudo-random generator.
//!
//! Small, fast, and splittable — each instance/component derives its own
//! stream from the run seed, so adding a component never perturbs the
//! random draws of another (a prerequisite for the ablation figures to
//! be comparable run-to-run).

/// SplitMix64 state. Period 2^64; passes BigCrush when used as designed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream, e.g. per instance id.
    pub fn split(&self, stream: u64) -> Self {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        r.next_u64(); // decorrelate
        Self { state: r.next_u64() }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → double mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Rejection-free (tiny modulo bias is
    /// irrelevant at the n << 2^64 scales used here).
    #[inline]
    pub fn next_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift reduction.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.next_range(xs.len() as u64) as usize])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_streams_are_independent_of_later_draws() {
        let base = Rng::new(7);
        let mut s1 = base.split(1);
        let first = s1.next_u64();
        // Splitting other streams never changes stream 1's draws.
        let _ = base.split(2);
        let mut s1b = base.split(1);
        assert_eq!(first, s1b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_is_bounded_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "vanishingly unlikely");
    }
}
