//! Deterministic discrete-event simulation core.
//!
//! Everything stochastic in the repository flows through [`rng::Rng`]
//! (a SplitMix64 generator) and everything temporal through
//! [`EventQueue`], so every figure in `EXPERIMENTS.md` regenerates
//! bit-identically from its seed.  No wall-clock time is ever consulted
//! on the simulation path.

pub mod dist;
pub mod rng;

pub use dist::{Exponential, LogNormal, ParetoTail, Poisson};
pub use rng::Rng;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Time;

/// A scheduled simulation event carrying an opaque payload `E`.
///
/// Ordering: earliest `at` first; ties broken by insertion sequence so
/// simultaneous events pop in a deterministic FIFO order.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotonically advancing clock.
///
/// ```
/// use cascade_infer::sim::EventQueue;
/// let mut q = EventQueue::new();
/// q.schedule(2.0, "b");
/// q.schedule(1.0, "a");
/// assert_eq!(q.pop(), Some((1.0, "a")));
/// assert_eq!(q.now(), 1.0);
/// assert_eq!(q.pop(), Some((2.0, "b")));
/// ```
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: Time,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Current simulated time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// Events scheduled in the past are clamped to `now` (they fire
    /// immediately but never move the clock backwards).
    pub fn schedule(&mut self, at: Time, payload: E) {
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.at >= self.now, "time went backwards");
            self.now = s.at;
            (s.at, s.payload)
        })
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Simple online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation — the imbalance statistic of Fig. 16.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-12 {
            0.0
        } else {
            self.stddev() / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, 3);
        q.schedule(1.0, 1);
        q.schedule(2.0, 2);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_tie_break_survives_interleaved_pops() {
        // Insertion order must keep deciding equal-timestamp ordering
        // even when pops interleave with later schedules at that time.
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(1.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        q.schedule(1.0, "c"); // same timestamp, scheduled after a pop
        q.schedule(0.5, "late"); // past: clamps to now=1.0, after c
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((1.0, "c")));
        assert_eq!(q.pop(), Some((1.0, "late")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_tie_break_mixed_timestamps() {
        let mut q = EventQueue::new();
        q.schedule(2.0, 20);
        q.schedule(1.0, 10);
        q.schedule(2.0, 21);
        q.schedule(1.0, 11);
        q.schedule(2.0, 22);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![10, 11, 20, 21, 22]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(1.0, ());
        q.schedule(4.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Past events clamp to now.
        q.schedule(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "a");
        q.pop();
        q.schedule_in(3.0, "b");
        assert_eq!(q.peek_time(), Some(5.0));
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert!(w.cv() > 0.0);
    }

    #[test]
    fn welford_zero_mean_cv_is_zero() {
        let mut w = Welford::default();
        w.push(0.0);
        w.push(0.0);
        assert_eq!(w.cv(), 0.0);
    }
}
