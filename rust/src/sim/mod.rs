//! Deterministic discrete-event simulation core.
//!
//! Everything stochastic in the repository flows through [`rng::Rng`]
//! (a SplitMix64 generator) and everything temporal through
//! [`EventQueue`], so every figure in `EXPERIMENTS.md` regenerates
//! bit-identically from its seed.  No wall-clock time is ever consulted
//! on the simulation path.

pub mod arena;
pub mod dist;
pub mod rng;

pub use arena::{RecentWindow, RequestArena};
pub use dist::{Exponential, LogNormal, ParetoTail, Poisson};
pub use rng::Rng;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Time;

/// A scheduled simulation event carrying an opaque payload `E`.
///
/// Ordering: earliest `at` first; ties broken by insertion sequence so
/// simultaneous events pop in a deterministic FIFO order.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        // `total_cmp` (not `partial_cmp`): event times are finite and
        // non-negative, but a NaN-total order keeps the heap invariant
        // unconditionally — detlint rule D2.
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Number of calendar-wheel slots; with [`WHEEL_WIDTH`] this gives a
/// near-future horizon of ~1 simulated second — wide enough that decode
/// completions, gossip ticks, and the next Poisson arrival all land in
/// the wheel, while refine/replan timers (multi-second periods) stay in
/// the far-tier heap.
const WHEEL_SLOTS: usize = 512;

/// Width of one calendar-wheel slot in simulated seconds.  Engine
/// iterations are O(ms), so a 2 ms slot keeps per-slot occupancy small.
const WHEEL_WIDTH: f64 = 0.002;

/// Insertion sequences at or above this base belong to the *normal*
/// class; sequences below it are reserved for
/// [`EventQueue::schedule_front_class`], whose events therefore win
/// every same-timestamp tie against normally scheduled events.
const NORMAL_SEQ_BASE: u64 = 1 << 63;

/// Absolute calendar slot of a timestamp (monotone in `at`).
fn slot_of(at: Time) -> u64 {
    (at / WHEEL_WIDTH) as u64
}

/// The total event order: earliest timestamp first, then insertion seq.
fn orders_before(a_at: Time, a_seq: u64, b_at: Time, b_seq: u64) -> bool {
    matches!(a_at.total_cmp(&b_at).then_with(|| a_seq.cmp(&b_seq)), Ordering::Less)
}

/// Earliest-first event queue with a monotonically advancing clock.
///
/// Storage is three tiers, all sharing one total order (timestamp,
/// then insertion seq — FIFO on ties):
///
/// 1. **Front register** (PR 4): a one-slot holder for the minimum
///    element.  The driver's dominant pattern is "schedule the next
///    completion and immediately pop it" — when the scheduled event
///    precedes everything queued it lands in the register (no
///    sift-up) and the following `pop` takes it back out, so the hot
///    loop does zero O(log n) operations.
/// 2. **Calendar wheel**: events within ~[`WHEEL_SLOTS`]·
///    [`WHEEL_WIDTH`] seconds of `now` are bucketed by quantized
///    timestamp into a ring of [`WHEEL_SLOTS`] cells.  Because the
///    clock never passes an unpopped event, all resident events fit in
///    one wheel revolution, so each cell holds at most one absolute
///    slot's events at a time and the earliest resident is always in
///    the tracked minimum cell — pop scans that one cell (O(cell
///    occupancy), no global sift).
/// 3. **Far heap**: everything beyond the wheel horizon (and any
///    non-finite timestamp) falls back to the `BinaryHeap`.  Far
///    events are *not* migrated as the wheel rotates; pop simply
///    compares the wheel minimum against the heap top under the total
///    order, which keeps pop order bit-identical to a pure heap.
///
/// Two insertion-sequence lanes exist: [`EventQueue::schedule`] draws
/// from the normal lane, [`EventQueue::schedule_front_class`] from a
/// reserved lower lane whose events win every same-timestamp tie
/// against the normal lane (see that method for why).
///
/// ```
/// use cascade_infer::sim::EventQueue;
/// let mut q = EventQueue::new();
/// q.schedule(2.0, "b");
/// q.schedule(1.0, "a");
/// assert_eq!(q.pop(), Some((1.0, "a")));
/// assert_eq!(q.now(), 1.0);
/// assert_eq!(q.pop(), Some((2.0, "b")));
/// ```
#[derive(Debug)]
pub struct EventQueue<E> {
    /// Far tier: events beyond the wheel horizon at insertion time.
    heap: BinaryHeap<Scheduled<E>>,
    /// Near tier: cell `slot % WHEEL_SLOTS` holds the events of
    /// absolute calendar slot `slot` (unique per cell; see invariant
    /// discussion on the type docs).
    wheel: Vec<Vec<Scheduled<E>>>,
    /// Total events resident in the wheel.
    wheel_len: usize,
    /// Absolute slot of the earliest wheel resident; meaningful only
    /// while `wheel_len > 0`.
    min_slot: u64,
    /// Invariant: when `Some`, the front event orders before every
    /// wheel and heap element.  It may be `None` while the tiers are
    /// non-empty (after a pop); the next schedule/pop consults them.
    front: Option<Scheduled<E>>,
    now: Time,
    seq: u64,
    front_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            wheel: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            wheel_len: 0,
            min_slot: 0,
            front: None,
            now: 0.0,
            seq: NORMAL_SEQ_BASE,
            front_seq: 0,
        }
    }

    /// Current simulated time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// Events scheduled in the past are clamped to `now` (they fire
    /// immediately but never move the clock backwards).
    pub fn schedule(&mut self, at: Time, payload: E) {
        let seq = self.seq;
        self.seq += 1;
        self.insert(at, seq, payload);
    }

    /// Schedule `payload` at absolute time `at` in the reserved *front
    /// class*: these events win every same-timestamp tie against
    /// normally scheduled events, and keep FIFO order among
    /// themselves.
    ///
    /// This exists for lazily scheduled workload arrivals.  The
    /// materializing driver schedules every arrival before any timer,
    /// so arrivals always carry the smallest insertion seqs and win
    /// all ties; a streaming driver that schedules each arrival as it
    /// is pulled would otherwise assign them *later* seqs and lose
    /// those ties, diverging from the materialized pop order.
    pub fn schedule_front_class(&mut self, at: Time, payload: E) {
        let seq = self.front_seq;
        self.front_seq += 1;
        debug_assert!(self.front_seq < NORMAL_SEQ_BASE, "front-class seq lane exhausted");
        self.insert(at, seq, payload);
    }

    fn insert(&mut self, at: Time, seq: u64, payload: E) {
        let at = if at < self.now { self.now } else { at };
        let s = Scheduled { at, seq, payload };
        match &self.front {
            // Orders before the register occupant: displace it.  (For
            // normal-lane inserts this is exactly "strictly earlier
            // timestamp" — the occupant always has an older seq; a
            // front-class insert can also win a timestamp tie.)
            Some(f) if orders_before(s.at, s.seq, f.at, f.seq) => {
                let old = self.front.take().expect("front checked Some");
                self.push_tier(old);
                self.front = Some(s);
            }
            Some(_) => self.push_tier(s),
            None => match self.tier_peek() {
                // Ties and later events go behind the stored minimum.
                Some((t, q)) if !orders_before(s.at, s.seq, t, q) => self.push_tier(s),
                // Earlier than everything queued: the fast path — the
                // event touches neither wheel nor heap.
                _ => self.front = Some(s),
            },
        }
    }

    /// Route an event to the wheel (near) or heap (far) tier.
    fn push_tier(&mut self, s: Scheduled<E>) {
        if s.at.is_finite() {
            let slot = slot_of(s.at);
            if slot < slot_of(self.now) + WHEEL_SLOTS as u64 {
                if self.wheel_len == 0 || slot < self.min_slot {
                    self.min_slot = slot;
                }
                self.wheel[(slot % WHEEL_SLOTS as u64) as usize].push(s);
                self.wheel_len += 1;
                return;
            }
        }
        self.heap.push(s);
    }

    /// (timestamp, seq) of the earliest wheel resident.
    fn wheel_peek(&self) -> Option<(Time, u64)> {
        if self.wheel_len == 0 {
            return None;
        }
        let cell = &self.wheel[(self.min_slot % WHEEL_SLOTS as u64) as usize];
        debug_assert!(!cell.is_empty(), "min_slot points at an empty cell");
        let mut best = (cell[0].at, cell[0].seq);
        for s in &cell[1..] {
            if orders_before(s.at, s.seq, best.0, best.1) {
                best = (s.at, s.seq);
            }
        }
        Some(best)
    }

    /// (timestamp, seq) of the earliest stored (non-register) event.
    fn tier_peek(&self) -> Option<(Time, u64)> {
        let w = self.wheel_peek();
        let h = self.heap.peek().map(|s| (s.at, s.seq));
        match (w, h) {
            (Some(w), Some(h)) => Some(if orders_before(w.0, w.1, h.0, h.1) { w } else { h }),
            (w, h) => w.or(h),
        }
    }

    /// Remove and return the earliest stored (non-register) event.
    fn pop_tier(&mut self) -> Option<Scheduled<E>> {
        let from_wheel = match (self.wheel_peek(), self.heap.peek()) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(w), Some(h)) => orders_before(w.0, w.1, h.at, h.seq),
        };
        if !from_wheel {
            return self.heap.pop();
        }
        let cell_idx = (self.min_slot % WHEEL_SLOTS as u64) as usize;
        let cell = &mut self.wheel[cell_idx];
        let mut best = 0;
        for i in 1..cell.len() {
            if orders_before(cell[i].at, cell[i].seq, cell[best].at, cell[best].seq) {
                best = i;
            }
        }
        let s = cell.swap_remove(best);
        self.wheel_len -= 1;
        if self.wheel_len > 0 && self.wheel[cell_idx].is_empty() {
            // All residents fit in one revolution, so the next
            // occupied cell (in slot order) holds the new minimum.
            for d in 1..WHEEL_SLOTS as u64 {
                let slot = self.min_slot + d;
                if !self.wheel[(slot % WHEEL_SLOTS as u64) as usize].is_empty() {
                    self.min_slot = slot;
                    break;
                }
            }
        }
        Some(s)
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let s = match self.front.take() {
            Some(s) => s,
            None => self.pop_tier()?,
        };
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        Some((s.at, s.payload))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        match &self.front {
            Some(f) => Some(f.at),
            None => self.tier_peek().map(|(t, _)| t),
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len() + self.wheel_len + usize::from(self.front.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.front.is_none() && self.wheel_len == 0 && self.heap.is_empty()
    }
}

/// Simple online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation — the imbalance statistic of Fig. 16.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-12 {
            0.0
        } else {
            self.stddev() / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, 3);
        q.schedule(1.0, 1);
        q.schedule(2.0, 2);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_tie_break_survives_interleaved_pops() {
        // Insertion order must keep deciding equal-timestamp ordering
        // even when pops interleave with later schedules at that time.
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(1.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        q.schedule(1.0, "c"); // same timestamp, scheduled after a pop
        q.schedule(0.5, "late"); // past: clamps to now=1.0, after c
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((1.0, "c")));
        assert_eq!(q.pop(), Some((1.0, "late")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_tie_break_mixed_timestamps() {
        let mut q = EventQueue::new();
        q.schedule(2.0, 20);
        q.schedule(1.0, 10);
        q.schedule(2.0, 21);
        q.schedule(1.0, 11);
        q.schedule(2.0, 22);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![10, 11, 20, 21, 22]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(1.0, ());
        q.schedule(4.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Past events clamp to now.
        q.schedule(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "a");
        q.pop();
        q.schedule_in(3.0, "b");
        assert_eq!(q.peek_time(), Some(5.0));
    }

    #[test]
    fn front_register_schedule_pop_cycle() {
        // The macro-step pattern: a pending far event, then repeated
        // schedule-next-completion + pop — each new event is earlier
        // than the heap top and must come back first.
        let mut q = EventQueue::new();
        q.schedule(100.0, -1);
        let mut t = 0.0;
        for i in 0..50 {
            t += 0.5;
            q.schedule(t, i);
            assert_eq!(q.len(), 2);
            assert_eq!(q.peek_time(), Some(t));
            assert_eq!(q.pop(), Some((t, i)));
        }
        assert_eq!(q.pop(), Some((100.0, -1)));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn front_register_preserves_fifo_ties() {
        // A register occupant must win timestamp ties against later
        // schedules, and displaced occupants must keep their order.
        let mut q = EventQueue::new();
        q.schedule(5.0, "first");
        q.schedule(5.0, "second"); // tie: goes behind the register
        q.schedule(3.0, "early"); // displaces the register occupant
        q.schedule(3.0, "early2"); // tie with new register occupant
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["early", "early2", "first", "second"]);
    }

    #[test]
    fn front_register_random_interleaving_matches_total_order() {
        // Property: any interleaving of schedules and pops yields the
        // global (timestamp, insertion) order, register or not.
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(0xFEED);
        let mut q = EventQueue::new();
        let mut expected: Vec<(u64, u64)> = Vec::new(); // (time-key, seq)
        let mut seq = 0u64;
        let mut popped: Vec<(u64, u64)> = Vec::new();
        for _ in 0..400 {
            if rng.next_range(3) < 2 || q.is_empty() {
                // Times quantized so ties actually occur; never in the
                // past relative to the clock.
                let base = q.now() as u64;
                let t = base + rng.next_range(8);
                q.schedule(t as f64, (t, seq));
                // The queue clamps past times to `now`; t >= now here.
                expected.push((t, seq));
                seq += 1;
            } else {
                popped.push(q.pop().unwrap().1);
            }
        }
        while let Some((_, e)) = q.pop() {
            popped.push(e);
        }
        assert_eq!(popped.len(), expected.len());
        // The clock is monotone, so popped times never decrease; and
        // within an equal-timestamp run FIFO insertion order holds
        // (any event scheduled after a pop at that time has a larger
        // seq, so increasing seq is exactly FIFO).
        for w in popped.windows(2) {
            assert!(
                w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[1].1 > w[0].1),
                "order violated: {w:?}"
            );
        }
    }

    #[test]
    fn far_future_events_survive_wheel_rotation() {
        // Events beyond the wheel horizon live in the far heap and
        // must interleave correctly with near events as the clock
        // sweeps past many wheel revolutions.
        let mut q = EventQueue::new();
        let horizon = WHEEL_SLOTS as f64 * WHEEL_WIDTH;
        q.schedule(horizon * 5.0, "far");
        q.schedule(horizon * 2.5, "mid");
        let mut t = 0.0;
        for _ in 0..40 {
            t += horizon / 8.0;
            q.schedule(t, "near");
        }
        let mut last = -1.0;
        let mut seen = Vec::new();
        while let Some((at, e)) = q.pop() {
            assert!(at >= last, "pop order regressed: {at} after {last}");
            last = at;
            seen.push(e);
        }
        assert_eq!(seen.iter().filter(|e| **e == "near").count(), 40);
        assert_eq!(seen.last(), Some(&"far"));
        assert!(q.is_empty());
    }

    #[test]
    fn zero_delta_events_fire_now_in_fifo_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "tick");
        assert_eq!(q.pop(), Some((1.0, "tick")));
        q.schedule_in(0.0, "a");
        q.schedule_in(0.0, "b");
        q.schedule(1.0, "c"); // same instant via absolute schedule
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((1.0, "c")));
    }

    #[test]
    fn front_class_wins_timestamp_ties() {
        // Front-class events beat normal events scheduled *earlier* at
        // the same instant, while keeping FIFO among themselves — the
        // property that lets a streaming driver reproduce the
        // materialized driver's arrivals-first seq assignment.
        let mut q = EventQueue::new();
        q.schedule(2.0, "timer");
        q.schedule_front_class(2.0, "arrival-0");
        q.schedule(2.0, "timer2");
        q.schedule_front_class(2.0, "arrival-1");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["arrival-0", "arrival-1", "timer", "timer2"]);
    }

    #[test]
    fn front_class_displaces_register_on_tie() {
        // A normal event sits in the front register; a front-class
        // event at the same timestamp must still pop first.
        let mut q = EventQueue::new();
        q.schedule(3.0, "step-done"); // lands in the register
        q.schedule_front_class(3.0, "arrival");
        assert_eq!(q.pop(), Some((3.0, "arrival")));
        assert_eq!(q.pop(), Some((3.0, "step-done")));
    }

    #[test]
    fn wheel_cells_reused_across_revolutions() {
        // Drain/refill cycles that wrap the ring: each pass lands in
        // cells used by a previous revolution.
        let mut q = EventQueue::new();
        let step = WHEEL_WIDTH * 3.0;
        let mut expect = 0u64;
        for round in 0..5u64 {
            for i in 0..200u64 {
                q.schedule(q.now() + step * (i % 7 + 1) as f64, round * 1000 + i);
            }
            for _ in 0..200 {
                assert!(q.pop().is_some());
                expect += 1;
            }
            assert!(q.is_empty());
        }
        assert_eq!(expect, 1000);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert!(w.cv() > 0.0);
    }

    #[test]
    fn welford_zero_mean_cv_is_zero() {
        let mut w = Welford::default();
        w.push(0.0);
        w.push(0.0);
        assert_eq!(w.cv(), 0.0);
    }
}
