//! Deterministic discrete-event simulation core.
//!
//! Everything stochastic in the repository flows through [`rng::Rng`]
//! (a SplitMix64 generator) and everything temporal through
//! [`EventQueue`], so every figure in `EXPERIMENTS.md` regenerates
//! bit-identically from its seed.  No wall-clock time is ever consulted
//! on the simulation path.

pub mod dist;
pub mod rng;

pub use dist::{Exponential, LogNormal, ParetoTail, Poisson};
pub use rng::Rng;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Time;

/// A scheduled simulation event carrying an opaque payload `E`.
///
/// Ordering: earliest `at` first; ties broken by insertion sequence so
/// simultaneous events pop in a deterministic FIFO order.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        // `total_cmp` (not `partial_cmp`): event times are finite and
        // non-negative, but a NaN-total order keeps the heap invariant
        // unconditionally — detlint rule D2.
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotonically advancing clock.
///
/// Internally the minimum element is held in a one-slot *front
/// register* outside the binary heap.  This is the macro-step fast
/// path: the driver's dominant pattern is "schedule the next completion
/// and immediately pop it" — when the scheduled event precedes
/// everything in the heap it lands in the register (no sift-up) and the
/// following `pop` takes it back out (no sift-down), so the hot loop
/// does zero O(log n) heap operations.  Ordering semantics are exactly
/// the heap's: earliest timestamp first, FIFO on ties (a register
/// occupant always has a smaller insertion seq than any new event, so a
/// new event displaces it only with a strictly earlier timestamp).
///
/// ```
/// use cascade_infer::sim::EventQueue;
/// let mut q = EventQueue::new();
/// q.schedule(2.0, "b");
/// q.schedule(1.0, "a");
/// assert_eq!(q.pop(), Some((1.0, "a")));
/// assert_eq!(q.now(), 1.0);
/// assert_eq!(q.pop(), Some((2.0, "b")));
/// ```
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    /// Invariant: when `Some`, the front event orders before every
    /// heap element.  It may be `None` while the heap is non-empty
    /// (after a pop); the next schedule/pop consults the heap then.
    front: Option<Scheduled<E>>,
    now: Time,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), front: None, now: 0.0, seq: 0 }
    }

    /// Current simulated time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// Events scheduled in the past are clamped to `now` (they fire
    /// immediately but never move the clock backwards).
    pub fn schedule(&mut self, at: Time, payload: E) {
        let at = if at < self.now { self.now } else { at };
        let s = Scheduled { at, seq: self.seq, payload };
        self.seq += 1;
        match self.front.as_ref().map(|f| f.at) {
            // Strictly earlier than the register: displace it.  On a
            // timestamp tie the register wins (older seq — FIFO).
            Some(front_at) if s.at < front_at => {
                let old = self.front.take().expect("front checked Some");
                self.heap.push(old);
                self.front = Some(s);
            }
            Some(_) => self.heap.push(s),
            None => match self.heap.peek().map(|top| top.at) {
                // Ties go to the heap occupant (older seq — FIFO).
                Some(top_at) if s.at >= top_at => self.heap.push(s),
                // Earlier than everything queued: the fast path — the
                // event never touches the heap.
                _ => self.front = Some(s),
            },
        }
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let s = match self.front.take() {
            Some(s) => s,
            None => self.heap.pop()?,
        };
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        Some((s.at, s.payload))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        match &self.front {
            Some(f) => Some(f.at),
            None => self.heap.peek().map(|s| s.at),
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len() + usize::from(self.front.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.front.is_none() && self.heap.is_empty()
    }
}

/// Simple online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation — the imbalance statistic of Fig. 16.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-12 {
            0.0
        } else {
            self.stddev() / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, 3);
        q.schedule(1.0, 1);
        q.schedule(2.0, 2);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_tie_break_survives_interleaved_pops() {
        // Insertion order must keep deciding equal-timestamp ordering
        // even when pops interleave with later schedules at that time.
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(1.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        q.schedule(1.0, "c"); // same timestamp, scheduled after a pop
        q.schedule(0.5, "late"); // past: clamps to now=1.0, after c
        assert_eq!(q.pop(), Some((1.0, "b")));
        assert_eq!(q.pop(), Some((1.0, "c")));
        assert_eq!(q.pop(), Some((1.0, "late")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_tie_break_mixed_timestamps() {
        let mut q = EventQueue::new();
        q.schedule(2.0, 20);
        q.schedule(1.0, 10);
        q.schedule(2.0, 21);
        q.schedule(1.0, 11);
        q.schedule(2.0, 22);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![10, 11, 20, 21, 22]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(1.0, ());
        q.schedule(4.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Past events clamp to now.
        q.schedule(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "a");
        q.pop();
        q.schedule_in(3.0, "b");
        assert_eq!(q.peek_time(), Some(5.0));
    }

    #[test]
    fn front_register_schedule_pop_cycle() {
        // The macro-step pattern: a pending far event, then repeated
        // schedule-next-completion + pop — each new event is earlier
        // than the heap top and must come back first.
        let mut q = EventQueue::new();
        q.schedule(100.0, -1);
        let mut t = 0.0;
        for i in 0..50 {
            t += 0.5;
            q.schedule(t, i);
            assert_eq!(q.len(), 2);
            assert_eq!(q.peek_time(), Some(t));
            assert_eq!(q.pop(), Some((t, i)));
        }
        assert_eq!(q.pop(), Some((100.0, -1)));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn front_register_preserves_fifo_ties() {
        // A register occupant must win timestamp ties against later
        // schedules, and displaced occupants must keep their order.
        let mut q = EventQueue::new();
        q.schedule(5.0, "first");
        q.schedule(5.0, "second"); // tie: goes behind the register
        q.schedule(3.0, "early"); // displaces the register occupant
        q.schedule(3.0, "early2"); // tie with new register occupant
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["early", "early2", "first", "second"]);
    }

    #[test]
    fn front_register_random_interleaving_matches_total_order() {
        // Property: any interleaving of schedules and pops yields the
        // global (timestamp, insertion) order, register or not.
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(0xFEED);
        let mut q = EventQueue::new();
        let mut expected: Vec<(u64, u64)> = Vec::new(); // (time-key, seq)
        let mut seq = 0u64;
        let mut popped: Vec<(u64, u64)> = Vec::new();
        for _ in 0..400 {
            if rng.next_range(3) < 2 || q.is_empty() {
                // Times quantized so ties actually occur; never in the
                // past relative to the clock.
                let base = q.now() as u64;
                let t = base + rng.next_range(8);
                q.schedule(t as f64, (t, seq));
                // The queue clamps past times to `now`; t >= now here.
                expected.push((t, seq));
                seq += 1;
            } else {
                popped.push(q.pop().unwrap().1);
            }
        }
        while let Some((_, e)) = q.pop() {
            popped.push(e);
        }
        assert_eq!(popped.len(), expected.len());
        // The clock is monotone, so popped times never decrease; and
        // within an equal-timestamp run FIFO insertion order holds
        // (any event scheduled after a pop at that time has a larger
        // seq, so increasing seq is exactly FIFO).
        for w in popped.windows(2) {
            assert!(
                w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[1].1 > w[0].1),
                "order violated: {w:?}"
            );
        }
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert!(w.cv() > 0.0);
    }

    #[test]
    fn welford_zero_mean_cv_is_zero() {
        let mut w = Welford::default();
        w.push(0.0);
        w.push(0.0);
        assert_eq!(w.cv(), 0.0);
    }
}
