//! Single-instance continuous-batching engine simulator (§2.1–2.2).
//!
//! Reproduces the scheduler-visible behaviour of a vLLM 0.9.x instance:
//! iteration-level decoding with continuous batching, FCFS admission
//! bounded by paged-KV memory and a token budget, chunked prefill with
//! prefill-priority, and recompute-mode preemption when decode growth
//! overflows memory.  Execution cost comes from an [`ExecBackend`] —
//! the analytic attention cost model for the simulated figures, or a
//! fake backend in tests.
//!
//! The engine exposes migration hooks ([`Engine::extract`],
//! [`Engine::inject`]) so the CascadeInfer coordinator can move live
//! sequences between instances; the engine itself stays scheduler-
//! agnostic, mirroring the paper's "no modification to instance
//! internals" claim.

pub mod kvcache;

pub use kvcache::KvCache;

use crate::kernelmodel::AttentionModel;
use crate::metrics::RequestRecord;
use crate::workload::Request;
use crate::{RequestId, Time, Tokens};
use std::collections::VecDeque;

/// Prices one engine iteration.
pub trait ExecBackend {
    /// Cost of a prefill iteration over per-sequence chunk sizes,
    /// given each sequence's already-cached prefix length.
    fn prefill_cost(&self, chunks: &[(Tokens, Tokens)]) -> Time;
    /// Cost of one decode iteration over per-sequence current lengths.
    fn decode_cost(&self, lens: &[Tokens]) -> Time;
}

/// Backend priced by the analytic attention model of §2.3.
#[derive(Debug, Clone, Copy)]
pub struct CostModelBackend {
    pub model: AttentionModel,
}

impl CostModelBackend {
    pub fn new(model: AttentionModel) -> Self {
        Self { model }
    }
}

impl ExecBackend for CostModelBackend {
    fn prefill_cost(&self, chunks: &[(Tokens, Tokens)]) -> Time {
        // Chunked prefill over `new` tokens each, attending to
        // prefix+new; dominated by compute on the new tokens.
        let total_new: Tokens = chunks.iter().map(|&(new, _)| new).sum();
        if total_new == 0 {
            return 0.0;
        }
        // Cross-attention to the cached prefix adds memory traffic.
        let prefix_tokens: Tokens = chunks.iter().map(|&(_, prefix)| prefix).sum();
        let prefix_read = prefix_tokens as f64
            * self.model.model.kv_bytes_per_token() as f64
            / self.model.gpu.hbm_bytes_per_s;
        self.model.prefill_latency(total_new) + prefix_read
    }

    fn decode_cost(&self, lens: &[Tokens]) -> Time {
        self.model.decode_iteration_latency(lens)
    }
}

/// Engine scheduling limits (vLLM-equivalent knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    /// Max sequences in one decode batch (paper caps at 1024).
    pub max_batch: usize,
    /// Max new tokens per prefill iteration (chunked prefill budget).
    pub max_batched_tokens: Tokens,
    /// KV pool size in tokens.  `None` means "derive it": the cluster
    /// computes the capacity from the GPU memory budget, and a
    /// standalone [`Engine`] falls back to
    /// [`EngineConfig::STANDALONE_KV_CAPACITY`].  An explicit
    /// `Some(v)` is always honoured — there is no sentinel value that
    /// silently re-derives (the old code compared against the default,
    /// so explicitly passing the default was indistinguishable from
    /// not setting it).
    pub kv_capacity_tokens: Option<Tokens>,
    /// Paged-allocator block size.
    pub block_size: Tokens,
}

impl EngineConfig {
    /// KV capacity a standalone engine assumes when none is set.
    pub const STANDALONE_KV_CAPACITY: Tokens = 1_000_000;
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch: 1024,
            max_batched_tokens: 8192,
            kv_capacity_tokens: None,
            block_size: kvcache::DEFAULT_BLOCK_SIZE,
        }
    }
}

/// Lifecycle phase of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the FCFS queue (not yet admitted).
    Queued,
    /// Admitted; prefill partially done (`kv_len < prompt_len`).
    Prefilling,
    /// Autoregressive decoding.
    Decoding,
}

/// A live sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sequence {
    pub req: Request,
    /// Output tokens generated so far (logical decode progress —
    /// survives preemption).
    pub generated: Tokens,
    /// Tokens materialised in this instance's KV cache.
    pub kv_len: Tokens,
    /// Tokens to (re)prefill on admission: the prompt, plus any
    /// already-generated outputs whose KV was dropped by a
    /// recompute-mode preemption.
    pub prompt_len: Tokens,
    pub first_token_at: Option<Time>,
    pub phase: Phase,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Self {
            req,
            generated: 0,
            kv_len: 0,
            prompt_len: req.input_len,
            first_token_at: None,
            phase: Phase::Queued,
        }
    }

    /// Current total sequence length (cached tokens).
    pub fn current_len(&self) -> Tokens {
        self.kv_len
    }

    /// Logical sequence length (prompt + generated), independent of
    /// where the KV currently lives.
    pub fn logical_len(&self) -> Tokens {
        self.req.input_len + self.generated
    }

    pub fn is_finished(&self) -> bool {
        self.generated >= self.req.output_len
    }

    /// Remaining decode tokens.
    pub fn remaining(&self) -> Tokens {
        self.req.output_len.saturating_sub(self.generated)
    }
}

/// Result of advancing the engine by one iteration.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Iteration execution time (0 if the engine was idle).
    pub duration: Time,
    /// Requests that finished this iteration.
    pub completed: Vec<RequestRecord>,
    /// Output tokens emitted this iteration.
    pub tokens_emitted: u64,
    /// Sequences preempted back to the queue this iteration.
    pub preempted: u64,
    /// True if this was a prefill (vs decode) iteration.
    pub was_prefill: bool,
}

/// Why [`Engine::run_until`] handed control back to the driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MacroStop {
    /// No runnable work: the engine is empty, or everything queued is
    /// memory-blocked (mirrors a zero-duration [`Engine::step`]).
    #[default]
    Idle,
    /// The last committed iteration ends at or after the horizon; the
    /// driver must schedule its completion as a queue event, because
    /// another event pops first (or ties, and FIFO gives it priority).
    Event,
    /// The last committed iteration completed at least one request.
    /// Run progress changed, so the driver must take its per-boundary
    /// actions (records, snapshot marks) before continuing inline.
    Boundary,
}

/// Outcome of a macro-step: as many engine iterations as fit before
/// `horizon` without requiring driver attention, advanced in one
/// inline loop with zero event-queue traffic.  Per-iteration effects
/// are identical to calling [`Engine::step`] in a loop — same
/// latencies, same arithmetic order, same admission/preemption and
/// completion decisions — and completions carry their exact
/// end-of-iteration timestamps in iteration order.
#[derive(Debug, Clone, Default)]
pub struct MacroOutcome {
    /// End time of the last committed iteration (== the start time
    /// when no iteration ran).
    pub end: Time,
    /// Iterations committed by this macro-step.
    pub iterations: u64,
    /// Requests that finished, in iteration order with exact times.
    pub completed: Vec<RequestRecord>,
    /// Total output tokens emitted across the committed iterations.
    pub tokens_emitted: u64,
    /// Total preemptions across the committed iterations.
    pub preempted: u64,
    /// Why the macro-step stopped.
    pub stop: MacroStop,
}

/// Single-instance continuous-batching engine.
#[derive(Debug, Clone)]
pub struct Engine<B: ExecBackend> {
    pub cfg: EngineConfig,
    backend: B,
    /// FCFS arrival queue.
    queue: VecDeque<Sequence>,
    /// Admitted sequences (prefilling or decoding).
    running: Vec<Sequence>,
    kv: KvCache,
    /// Running aggregate: sum of `current_len()` over `running`.
    /// Maintained at every mutation so [`Self::token_load`] is O(1) —
    /// the cluster routes, gossips and bids off this value on every
    /// event, and recomputing it per call was the top O(batch) rescan.
    running_tokens: Tokens,
    /// Running aggregate: sum of `req.input_len` over `queue`.
    queued_tokens: Tokens,
    /// Reusable buffers for the per-iteration cost-model inputs (avoids
    /// one or two Vec allocations per simulated engine step).
    scratch_lens: Vec<Tokens>,
    scratch_chunks: Vec<(Tokens, Tokens)>,
    /// True when `scratch_lens` still holds the previous decode
    /// iteration's per-row lengths for an unchanged batch: the next
    /// decode input is then `lens[j] + 1` in place, so steady-state
    /// decoding never re-materialises the length slice.  Any batch
    /// mutation (admit, preempt, reap, extract, inject, prefill)
    /// clears it.
    lens_cached: bool,
    /// Running count of admitted sequences still in `Phase::Prefilling`
    /// (replaces the per-iteration O(batch) phase scan).
    n_prefilling: usize,
    /// Monotone upper bound on `max(current_len())` over `running`:
    /// bumped on every token of growth, never decreased on removal
    /// (callers re-tighten via [`Engine::tighten_len_hint`] after a
    /// scan).  Lets the driver skip outgrown-sequence scans entirely
    /// while the whole batch is provably below a stage boundary.
    max_len_hint: Tokens,
    /// True while every decoding sequence's KV-cache token count equals
    /// its `kv_len` (the invariant behind the arithmetic block-boundary
    /// fast path in decode).  Falsified permanently by degenerate
    /// admissions (zero-length prompts / empty injected sequences),
    /// which allocate a 1-token minimum the `kv_len` does not reflect.
    kv_len_exact: bool,
    /// Prefill-only admission mode (PD disaggregation): sequences whose
    /// prefill completes are parked in [`Self::handoff_ready`] instead
    /// of decoding locally; the cluster hands their KV off to a decode
    /// instance.  Never set on colocated layouts, so the default-false
    /// path is bit-identical to before the mode existed.
    prefill_only: bool,
    /// Completed prefills awaiting KV handoff (prefill-only mode).
    /// Their KV stays allocated here until the transfer completes and
    /// [`Engine::extract`] removes them; they cost no compute.
    handoff_ready: Vec<Sequence>,
    /// Cumulative stats.
    pub total_output_tokens: u64,
    pub total_iterations: u64,
    pub busy_time: Time,
}

impl<B: ExecBackend> Engine<B> {
    pub fn new(cfg: EngineConfig, backend: B) -> Self {
        let kv = KvCache::new(
            cfg.kv_capacity_tokens.unwrap_or(EngineConfig::STANDALONE_KV_CAPACITY),
            cfg.block_size,
        );
        Self {
            cfg,
            backend,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            running_tokens: 0,
            queued_tokens: 0,
            scratch_lens: Vec::new(),
            scratch_chunks: Vec::new(),
            lens_cached: false,
            n_prefilling: 0,
            max_len_hint: 0,
            kv_len_exact: true,
            prefill_only: false,
            handoff_ready: Vec::new(),
            total_output_tokens: 0,
            total_iterations: 0,
            busy_time: 0.0,
        }
    }

    /// Enqueue a fresh request (prefill pending).
    pub fn submit(&mut self, req: Request) {
        self.queued_tokens += req.input_len;
        self.queue.push_back(Sequence::new(req));
    }

    /// Inject a mid-life sequence arriving via migration. Its KV cache
    /// is materialised on this instance (allocation must succeed —
    /// the migration subsystem checks for idle slots first, §5).
    pub fn inject(&mut self, seq: Sequence) -> bool {
        if seq.phase == Phase::Queued {
            self.queued_tokens += seq.req.input_len;
            self.queue.push_back(seq);
            return true;
        }
        if !self.kv.allocate(seq.req.id, seq.current_len().max(1)) {
            return false;
        }
        if self.prefill_only && seq.phase == Phase::Decoding {
            // A decode-phase sequence bounced back to a prefill-only
            // engine (failed handoff) re-parks for the next attempt
            // instead of decoding here.
            self.handoff_ready.push(seq);
            return true;
        }
        if seq.current_len() == 0 {
            // The allocator reserved a 1-token minimum the sequence
            // length does not reflect — disable the arithmetic
            // block-boundary fast path for this engine.
            self.kv_len_exact = false;
        }
        if seq.phase == Phase::Prefilling {
            // A mid-prefill injection reserved only `current_len()`
            // tokens, but its remaining prefill chunks advance kv_len
            // without allocator growth (admission-path sequences have
            // the whole prompt reserved up front) — the allocator's
            // count permanently lags kv_len, so the arithmetic fast
            // path no longer holds for this engine.
            self.kv_len_exact = false;
            self.n_prefilling += 1;
        }
        self.max_len_hint = self.max_len_hint.max(seq.current_len());
        self.running_tokens += seq.current_len();
        self.running.push(seq);
        self.lens_cached = false;
        true
    }

    /// Remove a live sequence for migration out. Frees its KV.
    pub fn extract(&mut self, id: RequestId) -> Option<Sequence> {
        if let Some(pos) = self.running.iter().position(|s| s.req.id == id) {
            let seq = self.running.remove(pos);
            self.kv.free(id);
            self.running_tokens -= seq.current_len();
            if seq.phase == Phase::Prefilling {
                self.n_prefilling -= 1;
            }
            self.lens_cached = false;
            return Some(seq);
        }
        if let Some(pos) = self.handoff_ready.iter().position(|s| s.req.id == id) {
            let seq = self.handoff_ready.remove(pos);
            self.kv.free(id);
            return Some(seq);
        }
        if let Some(pos) = self.queue.iter().position(|s| s.req.id == id) {
            let seq = self.queue.remove(pos);
            if let Some(s) = &seq {
                self.queued_tokens -= s.req.input_len;
            }
            return seq;
        }
        None
    }

    /// Remove every sequence at once — running (batch order) then
    /// queued (FCFS order) — freeing all KV: the spot-preemption /
    /// forced-kill evacuation path of the elastic-fleet subsystem.
    /// Equivalent to calling [`Engine::extract`] for every id, but
    /// O(n) total and it leaves the aggregates in the exact
    /// empty-engine state.
    pub fn evacuate(&mut self) -> Vec<Sequence> {
        let mut out =
            Vec::with_capacity(self.running.len() + self.handoff_ready.len() + self.queue.len());
        for seq in self.running.drain(..) {
            self.kv.free(seq.req.id);
            out.push(seq);
        }
        for seq in self.handoff_ready.drain(..) {
            self.kv.free(seq.req.id);
            out.push(seq);
        }
        out.extend(self.queue.drain(..));
        self.running_tokens = 0;
        self.queued_tokens = 0;
        self.n_prefilling = 0;
        self.max_len_hint = 0;
        self.lens_cached = false;
        out
    }

    /// Sequences currently decoding/prefilling (for load trackers).
    pub fn running(&self) -> &[Sequence] {
        &self.running
    }

    pub fn queued(&self) -> impl Iterator<Item = &Sequence> {
        self.queue.iter()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    /// Could a sequence of `tokens` ever fit in this engine's KV
    /// pool?  Admission-time guard against the FCFS head-of-line wedge
    /// (see [`KvCache::can_ever_hold`]).
    pub fn can_ever_hold(&self, tokens: Tokens) -> bool {
        self.kv.can_ever_hold(tokens)
    }

    /// Token-level load: total cached tokens (the LoadTracker metric).
    /// Maintained as a running aggregate; O(1).
    pub fn token_load(&self) -> Tokens {
        debug_assert_eq!(
            self.running_tokens + self.queued_tokens,
            self.token_load_naive(),
            "incremental token_load drifted from the ground truth"
        );
        self.running_tokens + self.queued_tokens
    }

    /// Reference O(n) recomputation of [`Self::token_load`] — the
    /// ground truth the incremental aggregate is checked against (in
    /// debug builds on every call, and by the regression tests).
    pub fn token_load_naive(&self) -> Tokens {
        self.running.iter().map(|s| s.current_len()).sum::<Tokens>()
            + self.queue.iter().map(|s| s.req.input_len).sum::<Tokens>()
    }

    /// Memory demand as a fraction of KV capacity.
    pub fn memory_demand(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.queue.is_empty() || !self.handoff_ready.is_empty()
    }

    /// Enter/leave prefill-only admission mode (PD disaggregation).
    /// Only toggled on engines with no resident work (pool
    /// re-allocation moves idle instances), so no running sequence
    /// changes discipline mid-life.
    pub fn set_prefill_only(&mut self, on: bool) {
        debug_assert!(
            !self.has_work(),
            "prefill-only mode must only be toggled on an idle engine"
        );
        self.prefill_only = on;
    }

    pub fn prefill_only(&self) -> bool {
        self.prefill_only
    }

    /// Completed prefills parked for KV handoff (prefill-only mode).
    /// They stay resident — KV allocated — until the cluster's
    /// transfer completes and extracts them.
    pub fn handoff_ready(&self) -> &[Sequence] {
        &self.handoff_ready
    }

    /// Park every running sequence whose prefill just completed
    /// (phase flipped to `Decoding`, first token emitted) for handoff.
    /// Called at the end of each prefill iteration in prefill-only
    /// mode; batch order is preserved, so the sweep is deterministic.
    fn park_prefilled(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase == Phase::Decoding {
                let seq = self.running.remove(i);
                self.running_tokens -= seq.current_len();
                self.handoff_ready.push(seq);
                self.lens_cached = false;
            } else {
                i += 1;
            }
        }
    }

    /// Admit queued sequences while memory and batch slots allow (FCFS).
    fn admit(&mut self) {
        while self.running.len() < self.cfg.max_batch {
            let Some(front) = self.queue.front() else { break };
            let need = front.prompt_len.max(1);
            if !self.kv.can_allocate(need) {
                break;
            }
            let mut seq = self.queue.pop_front().unwrap();
            self.queued_tokens -= seq.req.input_len;
            // Reserve the prompt's KV up front (vLLM reserves on admit).
            let ok = self.kv.allocate(seq.req.id, need);
            debug_assert!(ok);
            if seq.prompt_len == 0 {
                // 1-token minimum reservation without a matching
                // kv_len: the arithmetic fast path no longer holds.
                self.kv_len_exact = false;
            }
            if seq.phase == Phase::Queued {
                seq.phase = Phase::Prefilling;
            }
            if seq.phase == Phase::Prefilling {
                self.n_prefilling += 1;
            }
            self.max_len_hint = self.max_len_hint.max(seq.current_len());
            self.running_tokens += seq.current_len();
            self.running.push(seq);
            self.lens_cached = false;
        }
    }

    /// Advance one iteration starting at absolute time `now`.
    ///
    /// Prefill-priority (§6.1 "all systems prioritize prefilling over
    /// decoding"): if any admitted sequence still has prompt tokens to
    /// ingest, run a chunked-prefill iteration; otherwise decode.
    pub fn step(&mut self, now: Time) -> StepOutcome {
        self.admit();
        if self.running.is_empty() {
            return StepOutcome::default();
        }

        debug_assert_eq!(
            self.n_prefilling,
            self.running.iter().filter(|s| s.phase == Phase::Prefilling).count(),
            "prefill counter drifted from the phase scan"
        );
        let outcome = if self.n_prefilling > 0 {
            self.prefill_iteration(now)
        } else {
            self.decode_iteration(now)
        };
        self.total_iterations += 1;
        self.busy_time += outcome.duration;
        outcome
    }

    /// Advance as many iterations as fit before `horizon` in one
    /// inline loop — the macro-step fast path of the cluster driver.
    ///
    /// Semantics are *exactly* a [`Engine::step`] loop: each iteration
    /// starts at the previous one's end, costs the same backend
    /// arithmetic in the same order, and takes the same admission,
    /// preemption, and completion decisions.  `on_iteration(end,
    /// tokens)` fires once per committed iteration with its exact end
    /// time and emitted tokens (the driver feeds its per-instance
    /// throughput tracker with it, preserving the per-iteration EMA
    /// updates bit for bit).
    ///
    /// The loop hands control back ([`MacroStop`]) when:
    /// * nothing is runnable (`Idle` — including the memory-blocked
    ///   zero-duration case, whose outcome is discarded exactly like
    ///   the driver's historical `duration <= 0` gate);
    /// * an iteration ends at/after `horizon` (`Event` — that
    ///   iteration is committed, like the in-flight iteration the
    ///   micro-stepped driver had already scheduled);
    /// * an iteration completed a request (`Boundary` — run progress
    ///   changed, so per-boundary driver logic must run before the
    ///   next iteration).
    pub fn run_until(
        &mut self,
        start: Time,
        horizon: Time,
        mut on_iteration: impl FnMut(Time, u64),
    ) -> MacroOutcome {
        let mut out = MacroOutcome { end: start, ..Default::default() };
        let mut now = start;
        loop {
            if !self.has_work() {
                return out;
            }
            let o = self.step(now);
            if o.duration <= 0.0 {
                // Queued-but-unadmittable work; outcome discarded to
                // mirror the driver's historical early return.
                return out;
            }
            let end = now + o.duration;
            out.iterations += 1;
            out.tokens_emitted += o.tokens_emitted;
            out.preempted += o.preempted;
            on_iteration(end, o.tokens_emitted);
            let completed_any = !o.completed.is_empty();
            out.completed.extend(o.completed);
            out.end = end;
            if end >= horizon {
                out.stop = MacroStop::Event;
                return out;
            }
            if completed_any {
                out.stop = MacroStop::Boundary;
                return out;
            }
            now = end;
        }
    }

    /// Monotone upper bound on the longest running sequence (grows
    /// with every token, never shrinks on removal).  O(1); see
    /// [`Engine::tighten_len_hint`].
    pub fn max_len_upper(&self) -> Tokens {
        self.max_len_hint
    }

    /// Recompute the length bound exactly (O(batch)); called by the
    /// driver after a boundary scan so a departed long sequence stops
    /// triggering scans forever.
    pub fn tighten_len_hint(&mut self) {
        self.max_len_hint =
            self.running.iter().map(Sequence::current_len).max().unwrap_or(0);
    }

    fn prefill_iteration(&mut self, now: Time) -> StepOutcome {
        let mut budget = self.cfg.max_batched_tokens;
        let mut chunks: Vec<(usize, Tokens, Tokens)> = Vec::new(); // (idx, new, prefix)
        for (i, seq) in self.running.iter().enumerate() {
            if seq.phase != Phase::Prefilling || budget == 0 {
                continue;
            }
            let pending = seq.prompt_len - seq.kv_len;
            let take = pending.min(budget);
            if take == 0 {
                continue;
            }
            budget -= take;
            chunks.push((i, take, seq.kv_len));
        }
        if chunks.is_empty() {
            // All prefilling seqs starved by budget 0 — run decode instead.
            return self.decode_iteration(now);
        }
        let mut cost_input = std::mem::take(&mut self.scratch_chunks);
        cost_input.clear();
        cost_input.extend(chunks.iter().map(|&(_, new, prefix)| (new, prefix)));
        let duration = self.backend.prefill_cost(&cost_input);
        self.scratch_chunks = cost_input;
        let end = now + duration;

        let mut outcome = StepOutcome { duration, was_prefill: true, ..Default::default() };
        for &(i, take, _) in &chunks {
            let seq = &mut self.running[i];
            seq.kv_len += take;
            self.running_tokens += take;
            if seq.kv_len >= seq.prompt_len {
                seq.phase = Phase::Decoding;
                self.n_prefilling -= 1;
                if seq.generated == 0 {
                    // Fresh prefill completes: emits the first token.
                    seq.generated = 1;
                    seq.first_token_at = Some(end);
                    self.kv.grow(seq.req.id, 1);
                    seq.kv_len += 1;
                    self.running_tokens += 1;
                    outcome.tokens_emitted += 1;
                    self.total_output_tokens += 1;
                }
                // Recompute re-prefill: KV rebuilt, no token emitted.
            }
            self.max_len_hint = self.max_len_hint.max(self.running[i].kv_len);
        }
        self.lens_cached = false;
        // A prompt of output_len==1 is done right after prefill.
        self.reap(end, &mut outcome);
        if self.prefill_only {
            // Everything that survived the reap with a completed
            // prefill parks for KV handoff instead of decoding here.
            self.park_prefilled();
        }
        outcome
    }

    fn decode_iteration(&mut self, now: Time) -> StepOutcome {
        // Grow every decoding sequence by one token; preempt from the
        // back (latest arrivals) if memory runs out — vLLM recompute.
        let mut preempted = 0u64;
        // For a purely-decoding batch with exact KV accounting, "needs
        // a fresh block" is pure arithmetic: the sequence exactly fills
        // its blocks iff its length is a block multiple (lengths are
        // >= 1 here).  Avoids one allocator-map lookup per row per
        // iteration; the budget-starved fallback (prefilling rows in a
        // decode pass) and degenerate admissions take the exact
        // allocator path.
        let fast = self.kv_len_exact && self.n_prefilling == 0;
        let bs = self.kv.block_size();
        // First ensure memory for everyone by preempting from the back.
        loop {
            let blocks_needed = if fast {
                self.running.iter().filter(|s| s.kv_len % bs == 0).count() as u64
            } else {
                self.running
                    .iter()
                    .filter(|s| self.kv.next_token_needs_block(s.req.id))
                    .count() as u64
            };
            debug_assert_eq!(
                blocks_needed,
                self.running
                    .iter()
                    .filter(|s| self.kv.next_token_needs_block(s.req.id))
                    .count() as u64,
                "arithmetic block-boundary fast path diverged from the allocator"
            );
            if blocks_needed <= self.kv.free_blocks() || self.running.is_empty() {
                break;
            }
            let victim = self.running.remove(self.running.len() - 1);
            self.kv.free(victim.req.id);
            self.running_tokens -= victim.current_len();
            if victim.phase == Phase::Prefilling {
                self.n_prefilling -= 1;
            }
            self.lens_cached = false;
            // Recompute mode: back to queue, lose the cached KV but
            // keep logical progress — prompt + generated become the new
            // "prompt" to re-prefill (vLLM recompute preemption).
            let mut requeued = victim;
            requeued.kv_len = 0;
            requeued.prompt_len = requeued.logical_len();
            requeued.phase = Phase::Queued;
            self.queued_tokens += requeued.req.input_len;
            self.queue.push_front(requeued);
            preempted += 1;
        }
        if self.running.is_empty() {
            return StepOutcome { preempted, ..Default::default() };
        }
        for seq in &self.running {
            let ok = self.kv.grow(seq.req.id, 1);
            debug_assert!(ok);
        }

        // Cost-model input: for an unchanged batch this is last
        // iteration's slice advanced by one token per row in place —
        // the steady-state decode loop never rebuilds it.
        let mut lens = std::mem::take(&mut self.scratch_lens);
        if self.lens_cached && lens.len() == self.running.len() {
            for l in lens.iter_mut() {
                *l += 1;
            }
        } else {
            lens.clear();
            lens.extend(self.running.iter().map(|s| s.current_len()));
        }
        debug_assert!(
            lens.iter().zip(self.running.iter()).all(|(l, s)| *l == s.current_len()),
            "cached length slice drifted from the live batch"
        );
        let duration = self.backend.decode_cost(&lens);
        self.scratch_lens = lens;
        self.lens_cached = true;
        let end = now + duration;

        let mut outcome =
            StepOutcome { duration, preempted, was_prefill: false, ..Default::default() };
        let mut any_finished = false;
        for seq in &mut self.running {
            seq.generated += 1;
            seq.kv_len += 1;
            outcome.tokens_emitted += 1;
            self.total_output_tokens += 1;
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(end);
            }
            any_finished |= seq.is_finished();
        }
        self.running_tokens += self.running.len() as Tokens;
        // Every row grew by one, so the bound advances by one.
        self.max_len_hint += 1;
        if any_finished {
            // Reap only when the growth pass saw a finished row (the
            // scan is a no-op otherwise — bit-identical decisions).
            self.reap(end, &mut outcome);
        }
        outcome
    }

    /// Remove finished sequences, emitting their records.
    fn reap(&mut self, end: Time, outcome: &mut StepOutcome) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].is_finished() {
                let seq = self.running.remove(i);
                self.kv.free(seq.req.id);
                self.running_tokens -= seq.current_len();
                if seq.phase == Phase::Prefilling {
                    self.n_prefilling -= 1;
                }
                self.lens_cached = false;
                outcome.completed.push(RequestRecord {
                    id: seq.req.id,
                    arrival: seq.req.arrival,
                    first_token: seq.first_token_at.unwrap_or(end),
                    completion: end,
                    input_len: seq.req.input_len,
                    output_len: seq.req.output_len,
                });
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unit-cost backend: prefill = 1s per 1000 tokens, decode = 1ms
    /// per row plus 1us per cached token.
    #[derive(Debug, Clone, Copy)]
    struct FakeBackend;

    impl ExecBackend for FakeBackend {
        fn prefill_cost(&self, chunks: &[(Tokens, Tokens)]) -> Time {
            let t: Tokens = chunks.iter().map(|&(n, _)| n).sum();
            t as f64 / 1000.0
        }

        fn decode_cost(&self, lens: &[Tokens]) -> Time {
            1e-3 * lens.len() as f64 + 1e-6 * lens.iter().sum::<Tokens>() as f64
        }
    }

    fn req(id: u64, arrival: f64, input: u64, output: u64) -> Request {
        Request { id, arrival, input_len: input, output_len: output }
    }

    fn engine() -> Engine<FakeBackend> {
        Engine::new(EngineConfig::default(), FakeBackend)
    }

    fn run_to_completion(e: &mut Engine<FakeBackend>) -> Vec<RequestRecord> {
        let mut now = 0.0;
        let mut records = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-9);
            records.extend(out.completed);
            guard += 1;
            assert!(guard < 1_000_000, "engine did not converge");
        }
        records
    }

    #[test]
    fn single_request_lifecycle() {
        let mut e = engine();
        e.submit(req(1, 0.0, 100, 5));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 1);
        let r = recs[0];
        // Prefill(100 tokens)=0.1s emits the first token.
        assert!((r.first_token - 0.1).abs() < 1e-9, "{}", r.first_token);
        assert_eq!(r.output_len, 5);
        assert!(r.completion > r.first_token);
        assert_eq!(e.kv().n_seqs(), 0, "kv fully freed");
    }

    #[test]
    fn output_len_one_completes_at_prefill() {
        let mut e = engine();
        e.submit(req(1, 0.0, 50, 1));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 1);
        assert!((recs[0].completion - recs[0].first_token).abs() < 1e-12);
    }

    #[test]
    fn continuous_batching_joins_midstream() {
        let mut e = engine();
        e.submit(req(1, 0.0, 10, 50));
        // Run a few iterations, then add another request; both finish.
        let mut now = 0.0;
        for _ in 0..5 {
            let out = e.step(now);
            now += out.duration;
        }
        e.submit(req(2, now, 10, 10));
        let mut recs = Vec::new();
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-9);
            recs.extend(out.completed);
        }
        assert_eq!(recs.len(), 2);
    }

    #[test]
    fn chunked_prefill_respects_token_budget() {
        let cfg = EngineConfig { max_batched_tokens: 1000, ..Default::default() };
        let mut e = Engine::new(cfg, FakeBackend);
        e.submit(req(1, 0.0, 3500, 2));
        // 4 prefill iterations needed (1000+1000+1000+500).
        let mut prefills = 0;
        let mut now = 0.0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-9);
            if out.was_prefill {
                prefills += 1;
            }
        }
        assert_eq!(prefills, 4);
    }

    #[test]
    fn fcfs_admission_order() {
        let cfg = EngineConfig { max_batch: 1, ..Default::default() };
        let mut e = Engine::new(cfg, FakeBackend);
        e.submit(req(1, 0.0, 10, 3));
        e.submit(req(2, 0.0, 10, 3));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs[0].id, 1);
        assert_eq!(recs[1].id, 2);
    }

    #[test]
    fn memory_bounded_admission() {
        let cfg = EngineConfig { kv_capacity_tokens: Some(160), block_size: 16, ..Default::default() };
        let mut e = Engine::new(cfg, FakeBackend);
        e.submit(req(1, 0.0, 100, 2));
        e.submit(req(2, 0.0, 100, 2));
        e.step(0.0);
        // Only one fits at a time.
        assert_eq!(e.n_running() + usize::from(e.queue_len() == 0), 1);
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 2, "second admitted after first frees");
    }

    #[test]
    fn preemption_on_decode_overflow() {
        // Two seqs fit initially but their decode growth overflows; the
        // later one must be preempted and still complete eventually.
        let cfg = EngineConfig { kv_capacity_tokens: Some(96), block_size: 16, ..Default::default() };
        let mut e = Engine::new(cfg, FakeBackend);
        e.submit(req(1, 0.0, 30, 40));
        e.submit(req(2, 0.0, 30, 40));
        let mut now = 0.0;
        let mut preempted = 0;
        let mut recs = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-9);
            preempted += out.preempted;
            recs.extend(out.completed);
            guard += 1;
            assert!(guard < 100_000);
        }
        assert!(preempted > 0, "expected at least one preemption");
        assert_eq!(recs.len(), 2);
    }

    #[test]
    fn extract_and_inject_preserve_sequence() {
        let mut e1 = engine();
        e1.submit(req(1, 0.0, 10, 20));
        let mut now = 0.0;
        for _ in 0..5 {
            let out = e1.step(now);
            now += out.duration;
        }
        let seq = e1.extract(1).expect("live seq");
        assert!(seq.generated > 0);
        assert!(!e1.has_work());
        assert_eq!(e1.kv().n_seqs(), 0);

        let mut e2 = engine();
        assert!(e2.inject(seq));
        let mut recs = Vec::new();
        while e2.has_work() {
            let out = e2.step(now);
            now += out.duration.max(1e-9);
            recs.extend(out.completed);
        }
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].output_len, 20);
        // First token was never re-emitted: timestamp from e1's run.
        assert!(recs[0].first_token <= now);
    }

    #[test]
    fn inject_fails_when_kv_full() {
        let cfg = EngineConfig { kv_capacity_tokens: Some(32), block_size: 16, ..Default::default() };
        let mut e = Engine::new(cfg, FakeBackend);
        e.submit(req(1, 0.0, 32, 5));
        e.step(0.0);
        let mid = Sequence {
            req: req(9, 0.0, 100, 50),
            generated: 10,
            kv_len: 110,
            prompt_len: 100,
            first_token_at: Some(0.5),
            phase: Phase::Decoding,
        };
        assert!(!e.inject(mid));
    }

    #[test]
    fn evacuate_drains_everything_and_resets_aggregates() {
        let mut e = engine();
        e.submit(req(1, 0.0, 100, 20));
        e.submit(req(2, 0.0, 50, 5));
        let mut now = 0.0;
        for _ in 0..3 {
            let out = e.step(now);
            now += out.duration;
        }
        e.submit(req(3, now, 40, 5));
        let seqs = e.evacuate();
        // Running sequences in batch order, then the queued one.
        assert_eq!(seqs.len(), 3);
        assert_eq!(seqs[2].req.id, 3);
        assert!(seqs.iter().any(|s| s.generated > 0), "progress rides along");
        assert!(!e.has_work());
        assert_eq!(e.token_load(), 0);
        assert_eq!(e.token_load_naive(), 0);
        assert_eq!(e.kv().n_seqs(), 0, "all KV freed");
        // The engine is reusable after evacuation.
        e.submit(req(4, now, 10, 2));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn prefill_only_parks_completed_prefills() {
        let mut e = engine();
        e.set_prefill_only(true);
        e.submit(req(1, 0.0, 100, 5));
        e.submit(req(2, 0.0, 50, 1));
        let mut now = 0.0;
        let mut recs = Vec::new();
        let mut guard = 0;
        loop {
            let out = e.step(now);
            if out.duration <= 0.0 {
                break;
            }
            now += out.duration;
            recs.extend(out.completed);
            guard += 1;
            assert!(guard < 1000);
        }
        // The output_len==1 request completes locally at prefill; the
        // other parks for handoff instead of decoding here.
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].id, 2);
        assert_eq!(e.handoff_ready().len(), 1);
        assert!(e.has_work(), "a parked sequence keeps the engine's work visible");
        let seq = e.handoff_ready()[0];
        assert_eq!(seq.req.id, 1);
        assert_eq!(seq.generated, 1, "first token emitted at prefill completion");
        assert!(seq.first_token_at.is_some());
        assert_eq!(seq.phase, Phase::Decoding);
        assert_eq!(e.token_load(), e.token_load_naive());
        // Extraction frees the KV like any migration source.
        let seq = e.extract(1).unwrap();
        assert_eq!(e.kv().n_seqs(), 0);
        assert!(!e.has_work());
        // The parked sequence finishes on a normal (decode) engine,
        // keeping its prefill-side first-token timestamp.
        let mut d = engine();
        assert!(d.inject(seq));
        let recs = run_to_completion(&mut d);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].output_len, 5);
        assert!(recs[0].first_token < recs[0].completion);
    }

    #[test]
    fn token_load_counts_running_and_queued() {
        let mut e = engine();
        e.submit(req(1, 0.0, 100, 5));
        e.submit(req(2, 0.0, 200, 5));
        assert_eq!(e.token_load(), 300);
    }

    #[test]
    fn token_load_incremental_matches_naive_property() {
        // The golden-seed refactor invariant: the O(1) running
        // aggregate must equal the O(n) rescan after every operation —
        // submit, step (admit/prefill/decode/preempt/reap), extract,
        // and inject — under randomized schedules.
        use crate::sim::Rng;
        use crate::testutil::for_all;
        for_all("engine-token-load", 0xD00D, 48, |rng: &mut Rng| {
            let cfg = EngineConfig {
                max_batch: 8,
                max_batched_tokens: 256,
                kv_capacity_tokens: Some(2048),
                block_size: 16,
            };
            let mut e = Engine::new(cfg, FakeBackend);
            let mut now = 0.0;
            let mut extracted: Vec<Sequence> = Vec::new();
            for op in 0..120u64 {
                match rng.next_range(4) {
                    0 => e.submit(req(
                        1000 + op,
                        now,
                        1 + rng.next_range(300),
                        1 + rng.next_range(40),
                    )),
                    1 => {
                        let out = e.step(now);
                        now += out.duration.max(1e-9);
                    }
                    2 => {
                        if let Some(s) = e.running().first().copied() {
                            if let Some(seq) = e.extract(s.req.id) {
                                extracted.push(seq);
                            }
                        }
                    }
                    _ => {
                        if let Some(seq) = extracted.pop() {
                            // May fail when KV is full; the invariant
                            // must hold either way.
                            let _ = e.inject(seq);
                        }
                    }
                }
                assert_eq!(e.token_load(), e.token_load_naive());
            }
        });
    }

    /// Drive an engine with a per-step loop (the micro reference),
    /// collecting records and iteration-end observations.
    fn drive_micro(e: &mut Engine<FakeBackend>) -> (Vec<RequestRecord>, Vec<(Time, u64)>) {
        let mut now = 0.0;
        let mut records = Vec::new();
        let mut observed = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            let out = e.step(now);
            if out.duration <= 0.0 {
                break;
            }
            now += out.duration;
            observed.push((now, out.tokens_emitted));
            records.extend(out.completed);
            guard += 1;
            assert!(guard < 1_000_000);
        }
        (records, observed)
    }

    /// Drive an engine with `run_until` (optionally in bounded horizon
    /// chunks), collecting the same observables.
    fn drive_macro(
        e: &mut Engine<FakeBackend>,
        chunk: Option<Time>,
    ) -> (Vec<RequestRecord>, Vec<(Time, u64)>) {
        let mut now = 0.0;
        let mut records = Vec::new();
        let mut observed = Vec::new();
        let mut guard = 0;
        loop {
            let horizon = chunk.map(|c| now + c).unwrap_or(f64::INFINITY);
            let mo = e.run_until(now, horizon, |t, k| observed.push((t, k)));
            records.extend(mo.completed);
            match mo.stop {
                MacroStop::Idle => {
                    if mo.iterations == 0 {
                        break;
                    }
                    now = mo.end;
                }
                MacroStop::Event | MacroStop::Boundary => now = mo.end,
            }
            guard += 1;
            assert!(guard < 1_000_000);
        }
        (records, observed)
    }

    #[test]
    fn run_until_matches_per_step_loop() {
        // The macro-step hard requirement at engine scope: identical
        // records (exact timestamps), identical iteration-end
        // observations, identical cumulative stats — with and without
        // horizon chunking that cuts the run at arbitrary instants.
        use crate::sim::Rng;
        use crate::testutil::for_all;
        for_all("engine-macro-equivalence", 0xACE5, 24, |rng: &mut Rng| {
            let cfg = EngineConfig {
                max_batch: 16,
                max_batched_tokens: 512,
                // Ample memory: no zero-duration stalls, so the micro
                // loop needs no stall guard.
                kv_capacity_tokens: Some(4_000_000),
                block_size: 16,
            };
            let mut micro = Engine::new(cfg, FakeBackend);
            for i in 0..30u64 {
                micro.submit(req(
                    i,
                    0.0,
                    1 + rng.next_range(800),
                    1 + rng.next_range(60),
                ));
            }
            let mut macro_inf = micro.clone();
            let mut macro_chunked = micro.clone();

            let (r_micro, o_micro) = drive_micro(&mut micro);
            let (r_inf, o_inf) = drive_macro(&mut macro_inf, None);
            let chunk = 0.001 + rng.next_range(50) as f64 * 1e-3;
            let (r_chunk, o_chunk) = drive_macro(&mut macro_chunked, Some(chunk));

            assert_eq!(r_micro, r_inf, "infinite-horizon macro diverged");
            assert_eq!(r_micro, r_chunk, "chunked macro diverged (chunk {chunk})");
            assert_eq!(o_micro, o_inf);
            assert_eq!(o_micro, o_chunk);
            assert_eq!(micro.total_iterations, macro_inf.total_iterations);
            assert_eq!(micro.total_iterations, macro_chunked.total_iterations);
            assert_eq!(micro.busy_time.to_bits(), macro_inf.busy_time.to_bits());
            assert_eq!(micro.busy_time.to_bits(), macro_chunked.busy_time.to_bits());
            assert_eq!(micro.token_load(), macro_chunked.token_load());
        });
    }

    #[test]
    fn run_until_stops_at_boundaries_and_horizon() {
        let mut e = engine();
        e.submit(req(1, 0.0, 100, 5));
        e.submit(req(2, 0.0, 100, 40));
        // A tiny horizon: the first committed iteration overruns it.
        let mo = e.run_until(0.0, 1e-9, |_, _| {});
        assert_eq!(mo.stop, MacroStop::Event);
        assert_eq!(mo.iterations, 1);
        assert!(mo.end >= 1e-9);
        // Run to the first completion: must stop there, not later.
        let mo = e.run_until(mo.end, f64::INFINITY, |_, _| {});
        assert_eq!(mo.stop, MacroStop::Boundary);
        assert_eq!(mo.completed.len(), 1);
        assert_eq!(mo.completed[0].id, 1);
        // And drain the rest.
        let mo = e.run_until(mo.end, f64::INFINITY, |_, _| {});
        assert_eq!(mo.stop, MacroStop::Boundary);
        assert_eq!(mo.completed[0].id, 2);
        assert!(!e.has_work());
        let mo = e.run_until(mo.end, f64::INFINITY, |_, _| {});
        assert_eq!(mo.stop, MacroStop::Idle);
        assert_eq!(mo.iterations, 0);
    }

    #[test]
    fn zero_length_prompt_takes_the_exact_allocator_path() {
        // input_len == 0 allocates a 1-token minimum the kv_len never
        // reflects; the engine must fall back to allocator-backed
        // block-boundary checks (the debug_assert in decode enforces
        // agreement) and still complete the request.
        let mut e = engine();
        e.submit(req(7, 0.0, 0, 3));
        e.submit(req(8, 0.0, 50, 3));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 2);
        assert!(!e.kv_len_exact);
    }

    #[test]
    fn max_len_hint_is_a_sound_upper_bound() {
        let mut e = engine();
        e.submit(req(1, 0.0, 300, 40));
        e.submit(req(2, 0.0, 50, 10));
        let mut now = 0.0;
        while e.has_work() {
            let out = e.step(now);
            now += out.duration.max(1e-9);
            let true_max =
                e.running().iter().map(Sequence::current_len).max().unwrap_or(0);
            assert!(e.max_len_upper() >= true_max);
        }
        e.tighten_len_hint();
        assert_eq!(e.max_len_upper(), 0);
    }

    #[test]
    fn decode_cost_sees_true_lengths() {
        // After prefill of 10 and 3 decode steps, the decode batch
        // reports length 13-ish to the backend — verify via busy time
        // growth being superlinear-free but positive.
        let mut e = engine();
        e.submit(req(1, 0.0, 10, 5));
        let recs = run_to_completion(&mut e);
        assert_eq!(recs.len(), 1);
        assert!(e.busy_time > 0.0);
        assert!(e.total_iterations >= 5);
        assert_eq!(e.total_output_tokens, 5);
    }
}
