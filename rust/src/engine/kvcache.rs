//! Paged KV-cache memory manager (vLLM-style block allocator).
//!
//! The scheduler-visible behaviour of PagedAttention's memory system:
//! sequences own integral numbers of fixed-size token blocks; admission
//! and decode growth must fit the device's KV budget; freeing returns
//! blocks to the pool.  Fragmentation is therefore bounded to one
//! partial block per sequence, exactly as in the real system.

use crate::{RequestId, Tokens};
use std::collections::HashMap;

/// Default tokens per block (vLLM's default block size is 16).
pub const DEFAULT_BLOCK_SIZE: Tokens = 16;

#[derive(Debug, Clone)]
pub struct KvCache {
    /// Total capacity in blocks.
    capacity_blocks: u64,
    block_size: Tokens,
    free_blocks: u64,
    /// Per-sequence allocation: (tokens stored, blocks held).
    seqs: HashMap<RequestId, (Tokens, u64)>,
}

impl KvCache {
    pub fn new(capacity_tokens: Tokens, block_size: Tokens) -> Self {
        let block_size = block_size.max(1);
        let capacity_blocks = capacity_tokens / block_size;
        Self { capacity_blocks, block_size, free_blocks: capacity_blocks, seqs: HashMap::new() }
    }

    pub fn block_size(&self) -> Tokens {
        self.block_size
    }

    pub fn capacity_tokens(&self) -> Tokens {
        self.capacity_blocks * self.block_size
    }

    pub fn free_tokens(&self) -> Tokens {
        self.free_blocks * self.block_size
    }

    pub fn used_tokens(&self) -> Tokens {
        self.seqs.values().map(|(t, _)| *t).sum() // detlint: allow(D1) -- u64 sum over values; order-insensitive, result independent of hash order
    }

    /// Tokens reserved (block-granular) — what actually occupies HBM.
    pub fn reserved_tokens(&self) -> Tokens {
        self.seqs.values().map(|(_, b)| b * self.block_size).sum() // detlint: allow(D1) -- u64 sum over values; order-insensitive, result independent of hash order
    }

    fn blocks_for(&self, tokens: Tokens) -> u64 {
        tokens.div_ceil(self.block_size)
    }

    /// Can a new sequence of `tokens` be admitted right now?
    pub fn can_allocate(&self, tokens: Tokens) -> bool {
        self.blocks_for(tokens.max(1)) <= self.free_blocks
    }

    /// Could a sequence of `tokens` *ever* fit, even on an empty
    /// cache?  `false` means admitting it would wedge the FCFS queue
    /// head forever — the router rejects such requests up front.
    pub fn can_ever_hold(&self, tokens: Tokens) -> bool {
        self.blocks_for(tokens.max(1)) <= self.capacity_blocks
    }

    /// Allocate a fresh sequence. Returns false (no change) if it
    /// doesn't fit or the id already exists.
    pub fn allocate(&mut self, id: RequestId, tokens: Tokens) -> bool {
        if self.seqs.contains_key(&id) {
            return false;
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free_blocks {
            return false;
        }
        self.free_blocks -= need;
        self.seqs.insert(id, (tokens, need));
        true
    }

    /// Grow a sequence by `delta` tokens (decode step / prefill chunk).
    /// Returns false if the growth doesn't fit (caller must preempt).
    /// Single map probe: this runs once per row per decode iteration,
    /// so the old lookup-then-insert pair was two hashes on the
    /// simulator's hottest path.
    pub fn grow(&mut self, id: RequestId, delta: Tokens) -> bool {
        let block_size = self.block_size;
        let free = self.free_blocks;
        let Some(entry) = self.seqs.get_mut(&id) else {
            return false;
        };
        let (tokens, blocks) = *entry;
        let need = (tokens + delta).div_ceil(block_size);
        let extra = need.saturating_sub(blocks);
        if extra > free {
            return false;
        }
        *entry = (tokens + delta, blocks + extra);
        self.free_blocks -= extra;
        true
    }

    /// Free a sequence entirely, returning its blocks.
    pub fn free(&mut self, id: RequestId) -> bool {
        if let Some((_, blocks)) = self.seqs.remove(&id) {
            self.free_blocks += blocks;
            debug_assert!(self.free_blocks <= self.capacity_blocks);
            true
        } else {
            false
        }
    }

    pub fn tokens_of(&self, id: RequestId) -> Option<Tokens> {
        self.seqs.get(&id).map(|(t, _)| *t)
    }

    /// Would growing `id` by one token require a fresh block?
    /// (True exactly when the sequence currently fills its blocks.)
    pub fn next_token_needs_block(&self, id: RequestId) -> bool {
        match self.seqs.get(&id) {
            Some(&(tokens, blocks)) => tokens >= blocks * self.block_size,
            None => false,
        }
    }

    /// Free blocks available right now.
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn n_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Utilization in [0, 1] of reserved blocks over capacity.
    pub fn utilization(&self) -> f64 {
        if self.capacity_blocks == 0 {
            return 1.0;
        }
        1.0 - self.free_blocks as f64 / self.capacity_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::testutil::for_all;

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut kv = KvCache::new(1600, 16);
        assert!(kv.allocate(1, 100));
        assert_eq!(kv.tokens_of(1), Some(100));
        assert_eq!(kv.free_tokens(), 1600 - 112); // 7 blocks of 16
        assert!(kv.free(1));
        assert_eq!(kv.free_tokens(), 1600);
        assert!(!kv.free(1), "double free is a no-op");
    }

    #[test]
    fn rejects_over_capacity() {
        let mut kv = KvCache::new(100, 16);
        assert!(!kv.allocate(1, 101));
        assert!(kv.allocate(1, 96));
        assert!(!kv.allocate(2, 16), "pool exhausted");
    }

    #[test]
    fn duplicate_allocation_rejected() {
        let mut kv = KvCache::new(1000, 16);
        assert!(kv.allocate(1, 10));
        assert!(!kv.allocate(1, 10));
    }

    #[test]
    fn grow_within_block_is_free() {
        let mut kv = KvCache::new(160, 16);
        assert!(kv.allocate(1, 10));
        let free_before = kv.free_tokens();
        assert!(kv.grow(1, 6)); // still one block (16 tokens)
        assert_eq!(kv.free_tokens(), free_before);
        assert!(kv.grow(1, 1)); // crosses to a second block
        assert_eq!(kv.free_tokens(), free_before - 16);
    }

    #[test]
    fn grow_fails_when_full_without_corruption() {
        let mut kv = KvCache::new(32, 16);
        assert!(kv.allocate(1, 16));
        assert!(kv.allocate(2, 16));
        assert!(!kv.grow(1, 1));
        assert_eq!(kv.tokens_of(1), Some(16), "failed grow must not mutate");
    }

    #[test]
    fn utilization_bounds() {
        let mut kv = KvCache::new(160, 16);
        assert_eq!(kv.utilization(), 0.0);
        kv.allocate(1, 160);
        assert!((kv.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_is_finite_and_rejects() {
        // capacity_blocks == 0 must not yield NaN occupancy: a
        // zero-capacity pool reports "full" (1.0) and admits nothing.
        let kv = KvCache::new(0, 16);
        assert!(kv.utilization().is_finite());
        assert_eq!(kv.utilization(), 1.0);
        assert!(!kv.can_allocate(1));
        // Sub-block capacities truncate to zero blocks, same story.
        let kv = KvCache::new(15, 16);
        assert_eq!(kv.capacity_tokens(), 0);
        assert!(kv.utilization().is_finite());
        assert!(!kv.can_allocate(1));
    }

    #[test]
    fn property_blocks_conserved() {
        for_all("kv-conservation", 0xBEEF, 64, |rng: &mut Rng| {
            let mut kv = KvCache::new(10_000, 16);
            let mut live: Vec<RequestId> = Vec::new();
            for op in 0..200 {
                match rng.next_range(3) {
                    0 => {
                        let id = op as RequestId;
                        if kv.allocate(id, 1 + rng.next_range(500)) {
                            live.push(id);
                        }
                    }
                    1 => {
                        if let Some(&id) = rng.choose(&live) {
                            kv.grow(id, 1 + rng.next_range(100));
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.next_range(live.len() as u64) as usize;
                            let id = live.swap_remove(i);
                            assert!(kv.free(id));
                        }
                    }
                }
                // Invariant: reserved + free == capacity.
                assert_eq!(kv.reserved_tokens() + kv.free_tokens(), kv.capacity_tokens());
                // Invariant: every live seq's tokens fit its blocks.
                for &id in &live {
                    let t = kv.tokens_of(id).unwrap();
                    assert!(t <= kv.capacity_tokens());
                }
            }
        });
    }
}
