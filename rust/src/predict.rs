//! Length prediction — scheduling on *predicted* request lengths.
//!
//! Every scheduling decision in the cluster historically consumed the
//! workload generator's ground-truth output length (the "oracle"):
//! stage routing and the admission guard read `Request::final_len()`,
//! and the §4.2 planner built its histograms from true final lengths.
//! Real systems only have predictions — vllm-ltr (arxiv 2408.15792)
//! shows relative ranking is the practical substitute, and UELLM
//! (arxiv 2409.14961) schedules on predicted response lengths.  This
//! module makes the predictor a first-class policy axis so the
//! robustness question (how fast does length-aware scheduling decay
//! with predictor accuracy?) is a sweepable experiment.
//!
//! Four deterministic, seed-derived predictor families:
//!
//! * `oracle` — the legacy default.  Every consumer receives exactly
//!   the value it read before this subsystem existed (prompt length
//!   for stage routing, true final length for admission), so runs are
//!   bit-identical to the pre-predictor cluster.
//! * `noisy:<cv>` — lognormal multiplicative error on the true output
//!   length with coefficient of variation `cv` (mean-one error:
//!   `E[factor] = 1`), the standard "imperfect regressor" model.
//! * `bucket:<acc>` — histogram-bucket classifier over the planner's
//!   exponential length buckets: with probability `acc` the true
//!   bucket, otherwise an adjacent bucket (symmetric confusion);
//!   predicts the bucket's geometric-mid representative length.
//! * `ltr:<pacc>` — relative-rank-only predictor (the vllm-ltr
//!   regime): produces a rank in [0,1] whose fidelity is tuned by
//!   `pacc` (1.0 preserves the true ordering exactly; lower values
//!   add rank noise, so pairwise agreement with the true order decays
//!   monotonically — `pacc` is a monotone knob, not an exactly
//!   calibrated pairwise-accuracy).  Stage routing consumes the rank
//!   as a stage quantile and never an absolute length; the admission
//!   guard falls back to the known prompt length (a rank cannot be
//!   compared against a KV pool), so under-sized admissions escalate
//!   through the cluster's reject path.
//!
//! **Which layers see what.**  Routing, admission, the planner
//! histogram, and periodic replans consume *predicted* lengths; engine
//! execution, completion records, KV growth, and the refinement
//! observations keep running on *true* lengths.  Mispredictions are
//! therefore observable events: a decode outgrowing its predicted
//! stage boundary re-routes through the bid-ask migration machinery,
//! and an under-predicted admission that could never fit the KV pool
//! escalates through the admission-reject path (`RunStats` counts all
//! three: `mispredictions`, `predict_reroutes`, `predict_escalations`).
//!
//! **Determinism.**  Predictions are pure functions of
//! `(request, cluster seed, predictor parameters)` via the same
//! splitmix-style integer hash the bid-ask jitter uses — no RNG
//! streams, no state, no iteration order.  The same request always
//! gets the same prediction, from any call site, in any run.

use crate::workload::{LengthHistogram, Request};
use crate::{RequestId, Tokens};

/// Canonical predictor family names — the D4 registry anchor: every
/// name listed here must appear in the golden-seed and
/// macro-equivalence coverage lists (`detlint` cross-references them).
pub fn names() -> [&'static str; 4] {
    ["oracle", "noisy", "bucket", "ltr"]
}

/// The predictor grammar, shared by every error message and USAGE.
pub const GRAMMAR: &str = "oracle|noisy:CV|bucket:ACC|ltr:PACC";

/// Declarative predictor selection — parsed from CLI/config strings,
/// carried on [`crate::cluster::PolicySpec`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredictorSpec {
    /// Ground-truth lengths (bit-identical legacy behaviour).
    Oracle,
    /// Lognormal multiplicative error on the output length.
    Noisy { cv: f64 },
    /// Exponential-bucket classifier with symmetric adjacent confusion.
    Bucket { acc: f64 },
    /// Relative-rank-only predictor (rank fidelity knob `pacc`).
    Ltr { pacc: f64 },
}

impl Default for PredictorSpec {
    fn default() -> Self {
        PredictorSpec::Oracle
    }
}

impl PredictorSpec {
    /// Parse `oracle`, `noisy:CV`, `bucket:ACC`, or `ltr:PACC`
    /// (case-insensitive; parameters validated, never silently
    /// clamped).
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim().to_ascii_lowercase();
        let (head, param) = match t.split_once(':') {
            Some((h, p)) => (h, Some(p.trim())),
            None => (t.as_str(), None),
        };
        let number = |p: Option<&str>, example: &str| -> Result<f64, String> {
            let raw = p.ok_or_else(|| {
                format!("predictor `{head}` needs a parameter, e.g. `{head}:{example}`")
            })?;
            let v: f64 = raw
                .parse()
                .map_err(|_| format!("bad `{head}` parameter `{raw}` (want a number)"))?;
            if !v.is_finite() {
                return Err(format!("bad `{head}` parameter `{raw}` (must be finite)"));
            }
            Ok(v)
        };
        match head {
            "oracle" => match param {
                None => Ok(PredictorSpec::Oracle),
                Some(p) => Err(format!("`oracle` takes no parameter (got `:{p}`)")),
            },
            "noisy" => {
                let cv = number(param, "0.5")?;
                if cv < 0.0 {
                    return Err(format!("noisy CV must be >= 0 (got {cv})"));
                }
                Ok(PredictorSpec::Noisy { cv })
            }
            "bucket" => {
                let acc = number(param, "0.7")?;
                if !(0.0..=1.0).contains(&acc) {
                    return Err(format!("bucket accuracy must be in [0, 1] (got {acc})"));
                }
                Ok(PredictorSpec::Bucket { acc })
            }
            "ltr" => {
                let pacc = number(param, "0.8")?;
                if !(0.0..=1.0).contains(&pacc) {
                    return Err(format!("ltr pairwise accuracy must be in [0, 1] (got {pacc})"));
                }
                Ok(PredictorSpec::Ltr { pacc })
            }
            _ => Err(format!("unknown predictor `{s}`; valid: {GRAMMAR}")),
        }
    }

    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> String {
        match self {
            PredictorSpec::Oracle => "oracle".into(),
            PredictorSpec::Noisy { cv } => format!("noisy:{cv}"),
            PredictorSpec::Bucket { acc } => format!("bucket:{acc}"),
            PredictorSpec::Ltr { pacc } => format!("ltr:{pacc}"),
        }
    }

    pub fn is_oracle(&self) -> bool {
        matches!(self, PredictorSpec::Oracle)
    }
}

/// A materialised predictor: spec + cluster seed + context cap.
/// Stateless and pure — every method is a deterministic function of
/// the request alone.
#[derive(Debug, Clone)]
pub struct LengthPredictor {
    spec: PredictorSpec,
    seed: u64,
    max_len: Tokens,
    /// Exponential bucket bounds (the §4.2 planner's log-buckets),
    /// precomputed for the `bucket` classifier.
    bounds: Vec<Tokens>,
}

impl LengthPredictor {
    pub fn new(spec: PredictorSpec, seed: u64, max_len: Tokens) -> Self {
        let max_len = max_len.max(2);
        Self { spec, seed, max_len, bounds: LengthHistogram::exponential_bounds(max_len) }
    }

    pub fn spec(&self) -> &PredictorSpec {
        &self.spec
    }

    pub fn is_oracle(&self) -> bool {
        self.spec.is_oracle()
    }

    /// True for families producing absolute length estimates usable in
    /// load arithmetic (`noisy`, `bucket`).  The oracle is excluded on
    /// purpose: its consumers must execute the exact legacy
    /// expressions, and `ltr` exposes only ranks.
    pub fn predicts_absolute(&self) -> bool {
        matches!(self.spec, PredictorSpec::Noisy { .. } | PredictorSpec::Bucket { .. })
    }

    /// Splitmix-style per-request hash (the bid-ask jitter idiom) —
    /// the sole entropy source, derived from `(seed, request id,
    /// salt)`.
    fn mix(&self, id: RequestId, salt: u64) -> u64 {
        let mut h = (self.seed ^ salt)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(id.wrapping_mul(0xFF51_AFD7_ED55_8CCD));
        h ^= h >> 33;
        h = h.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
        h ^= h >> 29;
        h
    }

    /// Uniform draw in (0, 1), strictly inside the open interval.
    fn unit(&self, id: RequestId, salt: u64) -> f64 {
        ((self.mix(id, salt) >> 11) as f64 + 0.5) / (1u64 << 53) as f64
    }

    /// Standard normal draw (Box–Muller over two hash uniforms).
    fn gauss(&self, id: RequestId, salt: u64) -> f64 {
        let u1 = self.unit(id, salt);
        let u2 = self.unit(id, salt ^ 0xA5A5_A5A5_A5A5_A5A5);
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    fn bucket_of(&self, len: Tokens) -> usize {
        let n = self.bounds.len();
        match self.bounds.binary_search(&len) {
            Ok(i) => (i + 1).min(n - 1),
            Err(i) => i.min(n - 1),
        }
    }

    /// Predicted *final* sequence length (prompt + predicted output).
    /// The oracle returns the true final length; every other family
    /// derives its estimate from the seeded hash.  Clamped to
    /// `[input_len + 1, max_len]`.
    pub fn predicted_final(&self, req: &Request) -> Tokens {
        match self.spec {
            PredictorSpec::Oracle => req.final_len(),
            PredictorSpec::Noisy { cv } => {
                // Lognormal with E[factor] = 1: sigma^2 = ln(1 + cv^2),
                // factor = exp(sigma z - sigma^2 / 2).
                let sigma2 = (1.0 + cv * cv).ln();
                let sigma = sigma2.sqrt();
                let z = self.gauss(req.id, 0x6E6F_6973_79);
                let factor = (sigma * z - 0.5 * sigma2).exp();
                let out = ((req.output_len as f64) * factor).round().max(1.0) as Tokens;
                self.clamp_final(req, req.input_len + out)
            }
            PredictorSpec::Bucket { acc } => {
                let k = self.bucket_of(req.final_len());
                let u = self.unit(req.id, 0x6275_636B_6574);
                let n = self.bounds.len();
                let k = if u < acc {
                    k
                } else if u < acc + (1.0 - acc) * 0.5 {
                    k.saturating_sub(1)
                } else {
                    (k + 1).min(n - 1)
                };
                let lo = if k == 0 { 1 } else { self.bounds[k - 1] };
                let hi = self.bounds[k];
                let rep = ((lo as f64) * (hi as f64)).sqrt().round() as Tokens;
                self.clamp_final(req, req.input_len.max(rep).max(req.input_len + 1))
            }
            PredictorSpec::Ltr { pacc } => {
                // The rank maps back through the log-length scale only
                // for observability consumers (planner histogram,
                // misprediction counters) — routing consumes the rank
                // itself via `stage_rank`, admission the prompt length.
                let p = self.rank_value(req, pacc);
                let f = (p * (self.max_len as f64).ln()).exp().round() as Tokens;
                self.clamp_final(req, f.max(req.input_len + 1))
            }
        }
    }

    fn clamp_final(&self, req: &Request, f: Tokens) -> Tokens {
        f.clamp((req.input_len + 1).min(self.max_len), self.max_len)
    }

    /// Noisy log-percentile of the true final length in [0, 1].
    fn rank_value(&self, req: &Request, pacc: f64) -> f64 {
        let p_true = (req.final_len().max(1) as f64).ln() / (self.max_len as f64).ln();
        let sigma = 2.0 * (1.0 - pacc).clamp(0.0, 1.0);
        let z = self.gauss(req.id, 0x6C74_72);
        (p_true + sigma * z).clamp(0.0, 1.0)
    }

    /// Rank-only stage quantile: `Some(rank)` for `ltr`, `None` for
    /// families the stage router keys by length.
    pub fn stage_rank(&self, req: &Request) -> Option<f64> {
        match self.spec {
            PredictorSpec::Ltr { pacc } => Some(self.rank_value(req, pacc)),
            _ => None,
        }
    }

    /// Length the stage router keys on.  The oracle preserves the
    /// legacy prompt-length key exactly (bit-identity); predictive
    /// families route on the predicted final length so a stage covers
    /// the request's full expected extent.
    pub fn route_len(&self, req: &Request) -> Tokens {
        match self.spec {
            PredictorSpec::Oracle => req.input_len,
            _ => self.predicted_final(req),
        }
    }

    /// Length the admission guard checks against the KV pool.  The
    /// oracle keeps the legacy true final length; `ltr` knows only
    /// ranks, so admission falls back to the known prompt length (the
    /// cluster's escalation path catches what that lets through).
    pub fn admit_len(&self, req: &Request) -> Tokens {
        match self.spec {
            PredictorSpec::Oracle => req.final_len(),
            PredictorSpec::Ltr { .. } => req.input_len,
            _ => self.predicted_final(req),
        }
    }

    /// The live-sequence length a periodic replan feeds its histogram:
    /// legacy observable progress under the oracle, the predicted
    /// final (never less than observed progress) otherwise.
    pub fn replan_live_len(&self, req: &Request, current: Tokens) -> Tokens {
        if self.is_oracle() {
            current
        } else {
            self.predicted_final(req).max(current)
        }
    }

    /// Planner histogram over a trace sample: the oracle path is the
    /// exact legacy constructor; predictive families bin by predicted
    /// final length (prompt features stay true — they are known at
    /// arrival).
    pub fn histogram(&self, reqs: &[Request], max_len: Tokens) -> LengthHistogram {
        if self.is_oracle() {
            return LengthHistogram::from_requests(reqs, max_len);
        }
        let mut h = LengthHistogram::new(LengthHistogram::exponential_bounds(max_len));
        for r in reqs {
            h.push(r.input_len, self.predicted_final(r));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId, input: Tokens, output: Tokens) -> Request {
        Request { id, arrival: 0.0, input_len: input, output_len: output }
    }

    #[test]
    fn parse_accepts_every_family_and_round_trips() {
        for (s, want) in [
            ("oracle", PredictorSpec::Oracle),
            ("NOISY:0.5", PredictorSpec::Noisy { cv: 0.5 }),
            ("bucket:0.7", PredictorSpec::Bucket { acc: 0.7 }),
            ("ltr:0.8", PredictorSpec::Ltr { pacc: 0.8 }),
            ("noisy:0", PredictorSpec::Noisy { cv: 0.0 }),
        ] {
            let spec = PredictorSpec::parse(s).unwrap();
            assert_eq!(spec, want, "{s}");
            assert_eq!(PredictorSpec::parse(&spec.name()).unwrap(), spec, "{s} round-trip");
        }
        assert_eq!(names().len(), 4);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "psychic",
            "noisy",
            "noisy:",
            "noisy:fast",
            "noisy:-0.5",
            "noisy:inf",
            "bucket:1.5",
            "bucket:-0.1",
            "ltr:2.0",
            "oracle:0.5",
            "",
        ] {
            assert!(PredictorSpec::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn oracle_reproduces_legacy_values_exactly() {
        let p = LengthPredictor::new(PredictorSpec::Oracle, 42, 131_072);
        let r = req(7, 120, 900);
        assert_eq!(p.predicted_final(&r), r.final_len());
        assert_eq!(p.route_len(&r), r.input_len);
        assert_eq!(p.admit_len(&r), r.final_len());
        assert_eq!(p.replan_live_len(&r, 300), 300);
        assert_eq!(p.stage_rank(&r), None);
        assert!(p.is_oracle() && !p.predicts_absolute());
    }

    #[test]
    fn predictions_are_pure_functions_of_request_and_seed() {
        let a = LengthPredictor::new(PredictorSpec::Noisy { cv: 0.5 }, 42, 131_072);
        let b = LengthPredictor::new(PredictorSpec::Noisy { cv: 0.5 }, 42, 131_072);
        let c = LengthPredictor::new(PredictorSpec::Noisy { cv: 0.5 }, 43, 131_072);
        let mut diverged = false;
        for id in 0..64 {
            let r = req(id, 64 + id, 200 + 3 * id);
            assert_eq!(a.predicted_final(&r), b.predicted_final(&r), "same seed, same value");
            diverged |= a.predicted_final(&r) != c.predicted_final(&r);
        }
        assert!(diverged, "a different seed must perturb at least one prediction");
    }

    #[test]
    fn noisy_zero_cv_predicts_the_true_final_length() {
        let p = LengthPredictor::new(PredictorSpec::Noisy { cv: 0.0 }, 42, 131_072);
        for id in 0..32 {
            let r = req(id, 50 + id, 100 + 7 * id);
            assert_eq!(p.predicted_final(&r), r.final_len());
        }
    }

    #[test]
    fn noisy_errors_are_bounded_and_two_sided() {
        let p = LengthPredictor::new(PredictorSpec::Noisy { cv: 0.5 }, 42, 131_072);
        let (mut under, mut over) = (0, 0);
        for id in 0..256 {
            let r = req(id, 100, 1000);
            let f = p.predicted_final(&r);
            assert!(f > r.input_len && f <= 131_072);
            if f < r.final_len() {
                under += 1;
            }
            if f > r.final_len() {
                over += 1;
            }
        }
        assert!(under > 20 && over > 20, "multiplicative noise must cut both ways ({under}/{over})");
    }

    #[test]
    fn bucket_at_full_accuracy_lands_in_the_true_bucket() {
        let p = LengthPredictor::new(PredictorSpec::Bucket { acc: 1.0 }, 42, 131_072);
        for id in 0..64 {
            let r = req(id, 10, 40 + 97 * id);
            let f = p.predicted_final(&r);
            assert_eq!(
                p.bucket_of(f),
                p.bucket_of(r.final_len()),
                "acc=1 must classify request {id} into its true bucket"
            );
        }
    }

    #[test]
    fn ltr_at_full_pairwise_accuracy_preserves_order() {
        let p = LengthPredictor::new(PredictorSpec::Ltr { pacc: 1.0 }, 42, 131_072);
        let short = req(1, 50, 100);
        let long = req(2, 50, 20_000);
        let (rs, rl) = (p.stage_rank(&short).unwrap(), p.stage_rank(&long).unwrap());
        assert!(rs < rl, "true order must survive at pacc=1 ({rs} vs {rl})");
        assert!((0.0..=1.0).contains(&rs) && (0.0..=1.0).contains(&rl));
        // Rank-only family: admission sees the prompt, not a guess.
        assert_eq!(p.admit_len(&long), long.input_len);
    }

    #[test]
    fn predicted_histogram_matches_legacy_under_oracle() {
        let reqs: Vec<Request> = (0..100).map(|i| req(i, 64 + i, 100 + 13 * i)).collect();
        let p = LengthPredictor::new(PredictorSpec::Oracle, 42, 131_072);
        let a = p.histogram(&reqs, 131_072);
        let b = LengthHistogram::from_requests(&reqs, 131_072);
        assert_eq!(a.count, b.count);
        assert_eq!(a.sum_final, b.sum_final);
        let noisy = LengthPredictor::new(PredictorSpec::Noisy { cv: 1.0 }, 42, 131_072);
        assert_eq!(noisy.histogram(&reqs, 131_072).total(), 100);
    }
}
